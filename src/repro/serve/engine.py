"""ServeEngine — request-level serving with continuous batching.

The engine turns the model zoo's prefill/decode steps into a *service*:
callers ``submit()`` :class:`Request` objects at any time, drive the engine
with ``step()`` (one scheduling round: admit waiting requests into free KV
slots, then one fused decode step for every active slot) or
``run_until_idle()``, and consume streaming :class:`Token` events plus a
final :class:`Completion` per request.

Design points, each load-bearing for the paper's "committed pattern in
operation" end state:

* **Continuous batching** — the KV cache is ``n_slots`` batch rows with
  *per-slot* write positions (``cache["index"]`` is (B,)); finished
  requests free their slot mid-flight and the next waiting request is
  prefilled straight into it while the other slots keep decoding.  A
  token budget (:class:`repro.serve.scheduler.Scheduler`) bounds how much
  prefill work any single step may inject ahead of the in-flight decodes.
* **Block-paged KV cache** — with ``page_size`` set, slot storage moves
  into a shared :class:`repro.serve.kv.PagePool`: K/V lives in fixed-size
  pages, each slot holds a page list (:class:`repro.serve.kv.PageTable`),
  and the decode program gathers K/V *through the page table*, which it
  receives as a traced ``(n_slots, max_pages)`` operand — admissions,
  evictions and page appends never retrace.  Capacity becomes
  ``n_pages x page_size`` shared tokens instead of a per-request
  ``max_len`` reservation; under page pressure the youngest request is
  preempted (pages reclaimed, request requeued, continuation
  token-identical).  ``page_size=max_len`` is the degenerate
  one-page-per-slot case — the contiguous layout as a special case of the
  paged one.
* **Chunked prefill** — ``prefill_chunk`` splits prompts longer than one
  chunk into chunk-sized pieces run on consecutive engine steps,
  interleaved with the in-flight decodes (pages allocated per chunk), so
  one long prompt no longer spikes every other request's inter-token
  latency or TTFT.
* **Plan-aware phase dispatch** — prefill and decode are *different
  programs* with different winning offload patterns, so each phase is
  traced under its own committed plan (``zoo:<arch>:prefill`` /
  ``zoo:<arch>:decode`` from a :class:`PlanStore`), bound with zero
  re-measurement exactly like ``OffloadSession.attach``.
* **Fused sampling** — logits never leave the device: the jitted phase
  programs end in :func:`repro.serve.sampler.sample_tokens`, so the
  per-step host transfer is (B,) token ids, not (B, V) logits.
* **Telemetry** — every phase call runs under ``metering.meter_window``
  and aggregates into per-phase :class:`PhaseTelemetry`; the decode loop
  feeds a ``runtime.StepMonitor``; :meth:`ServeEngine.metrics` reports
  KV-pool utilization, stranded capacity and page fragmentation.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.core import blocks as blocks_mod
from repro.metering import meter_window, resolve_meter
from repro.metering.meters import WindowTelemetry
from repro.models import lm
from repro.models.attention import cache_seq_axes, insert_pages
from repro.obs import MetricsRegistry, Tracer, get_tracer
from repro.offload import stored_binding
from repro.runtime.monitor import StepMonitor
from repro.serve.kv import PagePool, PageTable, PoolExhausted, pages_for
from repro.serve.request import Completion, Request, RequestState, Token
from repro.serve.sampler import Sampler, sample_tokens
from repro.serve.scheduler import Scheduler, request_track

PHASES = ("prefill", "decode")


@dataclasses.dataclass
class PhaseTelemetry:
    """Aggregate of every ``meter_window`` a phase ran under.

    With a ``registry`` (a :class:`repro.obs.MetricsRegistry`) attached,
    every :meth:`add` *also* writes through to the
    ``serve_phase_{calls,seconds,tokens,joules}_total{phase=...}``
    counters — one observation feeds both views, so the legacy aggregate
    and the exported metrics can never disagree.  The dataclass fields
    remain the compatibility surface; new consumers should read the
    registry.
    """

    phase: str
    calls: int = 0
    seconds: float = 0.0
    tokens: int = 0
    joules: float | None = None
    provenance: str | None = None
    registry: Any = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def __post_init__(self) -> None:
        self._counters = None
        if self.registry is not None:
            lab = {"phase": self.phase}
            reg = self.registry
            self._counters = (
                reg.counter(
                    "serve_phase_calls_total",
                    "phase program invocations", ("phase",),
                ).labels(**lab),
                reg.counter(
                    "serve_phase_seconds_total",
                    "wall seconds inside phase programs", ("phase",),
                ).labels(**lab),
                reg.counter(
                    "serve_phase_tokens_total",
                    "tokens processed per phase", ("phase",),
                ).labels(**lab),
                reg.counter(
                    "serve_phase_joules_total",
                    "metered energy per phase", ("phase",),
                ).labels(**lab),
            )

    def add(self, tele: WindowTelemetry, tokens: int) -> None:
        self.calls += 1
        self.seconds += tele.seconds
        self.tokens += tokens
        if tele.joules is not None:
            self.joules = (self.joules or 0.0) + tele.joules
            self.provenance = tele.provenance
        if self._counters is not None:
            calls_c, seconds_c, tokens_c, joules_c = self._counters
            calls_c.inc()
            seconds_c.inc(max(tele.seconds, 0.0))
            tokens_c.inc(tokens)
            if tele.joules is not None:
                joules_c.inc(max(tele.joules, 0.0))

    @property
    def tokens_per_second(self) -> float:
        return self.tokens / self.seconds if self.seconds else 0.0

    @property
    def joules_per_token(self) -> float | None:
        if self.joules is None or not self.tokens:
            return None
        return self.joules / self.tokens

    def summary(self) -> str:
        out = (
            f"{self.phase}: {self.tokens} tok in {self.seconds:.2f}s "
            f"({self.tokens_per_second:.1f} tok/s, {self.calls} calls)"
        )
        if self.joules is not None:
            out += (
                f", {self.joules:.1f} J"
                f" [{self.joules_per_token:.3g} J/tok, {self.provenance}]"
            )
        return out


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """One engine lifetime in numbers."""

    steps: int
    requests_submitted: int
    requests_completed: int
    prefill_calls: int
    decode_steps: int
    tokens_generated: int
    slot_reuses: int
    max_active: int
    preemptions: int = 0
    prefill_chunks: int = 0


@dataclasses.dataclass
class _PrefillProgress:
    """One request mid-chunked-prefill: the per-request working cache and
    how much of the context has been extended into it."""

    state: RequestState
    context: list[int]
    cache: Any
    pos: int = 0


class ServeEngine:
    """Request-level serving engine over the block-pattern LM.

    ``cfg`` is an :class:`ArchConfig` (or an arch name, resolved through
    ``get_config``).  ``plan_dir``/``plan_keys`` bind each phase to a
    committed offload plan: with ``plan_dir`` alone the stored
    ``zoo:<arch>:prefill`` / ``zoo:<arch>:decode`` plans apply when
    present (and compatible with this environment); ``plan_keys`` may name
    explicit keys per phase or one key for both.  ``sampler`` is the
    default :class:`Sampler` for requests that don't carry their own.
    ``meter`` (name or ``PowerMeter``) adds per-phase energy telemetry.

    ``page_size`` switches the KV cache to the block-paged layout;
    ``n_pages`` sizes the shared pool (default: capacity-equivalent to
    the contiguous layout, ``n_slots * ceil(max_len / page_size)``).
    Admission then gates on free pages, eviction returns pages, and a
    smaller pool *over-commits*: more slots than the pool could hold at
    worst case, safe because the youngest request is preempted (and later
    resumed token-identically) if the pool ever actually fills.

    ``prefill_chunk`` enables chunked prefill (attention-family archs
    only — a recurrent SSM scan cannot resume across chunk boundaries):
    prompts longer than the chunk extend the cache chunk-by-chunk on
    consecutive steps, interleaved with running decodes.

    ``prefill_bucket`` pads prompts up to a multiple of the bucket so
    prefill traces are shared across prompt lengths — attention-family
    archs only (padded tokens would corrupt a recurrent SSM state; the
    padded KV rows here are provably never attended: each decode step
    overwrites position ``index`` before the mask ever admits it).
    """

    def __init__(
        self,
        cfg: ArchConfig | str,
        *,
        params: Any = None,
        n_slots: int = 4,
        max_len: int = 256,
        sampler: Sampler | None = None,
        meter: Any = None,
        plan_dir: str | None = None,
        plan_keys: "dict[str, str | None] | str | None" = None,
        max_tokens_per_step: int | None = None,
        prefill_bucket: int | None = None,
        prefill_chunk: int | None = None,
        page_size: int | None = None,
        n_pages: int | None = None,
        decode_impl: str = "auto",
        kv_validate: bool = False,
        monitor: StepMonitor | None = None,
        tracer: Tracer | None = None,
        registry: MetricsRegistry | None = None,
        seed: int = 0,
        quiet: bool = True,
    ) -> None:
        if isinstance(cfg, str):
            cfg = get_config(cfg)
        if cfg.frontend == "patch_embed":
            raise ValueError(
                f"{cfg.name}: patch-embed frontends have no token prompt "
                "path; the serving engine takes token-id requests"
            )
        if prefill_bucket is not None and "m" in cfg.pattern():
            raise ValueError(
                "prefill_bucket pads prompts, which corrupts recurrent SSM "
                f"state — unsupported for '{cfg.name}' "
                f"(pattern {cfg.pattern()!r})"
            )
        if prefill_chunk is not None and "m" in cfg.pattern():
            raise ValueError(
                "prefill_chunk resumes the sequence mid-prompt, which an "
                f"SSM scan cannot do — unsupported for '{cfg.name}' "
                f"(pattern {cfg.pattern()!r})"
            )
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if n_pages is not None and page_size is None:
            raise ValueError("n_pages given without page_size")
        if decode_impl not in ("auto", "xla", "pallas"):
            raise ValueError(
                f"decode_impl must be auto|xla|pallas, got {decode_impl!r}"
            )
        if decode_impl != "auto" and page_size is None:
            raise ValueError(
                "decode_impl pins the paged_attention binding — it requires "
                "the paged KV cache (page_size)"
            )
        self.decode_impl = decode_impl
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.sampler = sampler or Sampler.greedy()
        self.meter = resolve_meter(meter)
        self.seed = seed
        self.quiet = quiet
        self.prefill_bucket = prefill_bucket
        self.prefill_chunk = prefill_chunk

        # -- observability -------------------------------------------------
        # tracer: request-lifecycle spans (defaults to the process tracer,
        # a disabled no-op unless someone enabled it); registry: the
        # metric families every telemetry write-through lands in
        self.tracer = tracer if tracer is not None else get_tracer()
        self.registry = registry if registry is not None else MetricsRegistry()
        self._queue_depth_g = self.registry.gauge(
            "serve_queue_depth", "requests waiting for a slot"
        )
        self._active_slots_g = self.registry.gauge(
            "serve_active_slots", "requests resident in KV slots"
        )
        self._kv_util_g = self.registry.gauge(
            "serve_kv_utilization_pct", "KV pool/slot utilization"
        )
        self._kv_stranded_g = self.registry.gauge(
            "serve_kv_stranded_pct", "reserved-but-unused KV capacity"
        )
        self._kv_frag_g = self.registry.gauge(
            "serve_kv_fragmentation_pct", "partial-page fragmentation"
        )
        self._capacity_fits_g = self.registry.gauge(
            "serve_capacity_fits",
            "1 when the last plan_capacity() verdict fit its envelope",
        )
        self._capacity_headroom_g = self.registry.gauge(
            "serve_capacity_headroom_bytes",
            "bytes of envelope headroom from the last plan_capacity()",
        )
        self._capacity_max_slots_g = self.registry.gauge(
            "serve_capacity_max_slots",
            "max slots the envelope fits at this max_len (plan_capacity)",
        )
        self._submitted_c = self.registry.counter(
            "serve_requests_submitted_total", "requests accepted by submit()"
        )
        self._completed_c = self.registry.counter(
            "serve_requests_completed_total", "requests finished"
        )
        self._generated_c = self.registry.counter(
            "serve_tokens_generated_total", "tokens sampled across requests"
        )
        self._step_hist = self.registry.histogram(
            "serve_step_seconds", "fused decode step latency"
        )
        self.monitor = monitor or StepMonitor()
        if self.monitor.histogram is None:
            self.monitor.histogram = self._step_hist

        # -- KV memory subsystem ------------------------------------------
        self.paged = page_size is not None
        if self.paged:
            if page_size < 1:
                raise ValueError("page_size must be >= 1")
            max_pages = pages_for(max_len, page_size)
            if n_pages is None:
                # capacity-equivalent default: the paged layout holds the
                # same tokens as the contiguous one, minus the stranding
                n_pages = n_slots * max_pages
            self.kv: PageTable | None = PageTable(
                n_slots, max_pages, PagePool(n_pages, page_size),
                validate=kv_validate,
            )
            self._slot_len = max_pages * page_size
            self._seq_axes = cache_seq_axes(cfg)
            self._group_kinds = {g.key: g.kind for g in lm.groups_of(cfg)}
            self.cache = lm.init_cache(
                cfg, n_slots, max_len, page_size=page_size, n_pages=n_pages
            )
        else:
            self.kv = None
            self._slot_len = max_len
            self.cache = lm.init_cache(cfg, n_slots, max_len)

        self.scheduler = Scheduler(
            n_slots,
            max_tokens_per_step,
            prompt_cost=self._admission_cost,
            kv=self.kv,
            admit_tokens=self._admission_tokens,
            tracer=self.tracer,
            metrics=self.registry,
        )

        self.params = (
            params if params is not None else lm.init_params(cfg, seed=seed)
        )

        # -- plan-aware phase dispatch ------------------------------------
        # keys the caller named explicitly must fail loudly when they
        # cannot bind (mirrors resolve_meter: an explicit request is a
        # contract, not a hint); store-derived defaults degrade silently
        explicit = plan_keys is not None
        if explicit and not plan_dir:
            raise ValueError(
                "plan_keys given without plan_dir — both are required to "
                "bind a committed plan"
            )
        self.plan_keys = self._resolve_plan_keys(plan_dir, plan_keys)
        self._bindings: dict[str, dict[str, str] | None] = {}
        for phase in PHASES:
            key = self.plan_keys[phase]
            mapping = (
                stored_binding(plan_dir, key)
                if plan_dir and key
                else None
            )
            if key and mapping is None:
                if explicit:
                    raise ValueError(
                        f"plan '{key}' for phase '{phase}' not "
                        f"found/compatible in {plan_dir}"
                    )
                if not quiet:
                    print(
                        f"serve: plan '{key}' not found/compatible in "
                        f"{plan_dir}; {phase} runs on default bindings"
                    )
            elif mapping and not quiet:
                print(f"serve: {phase} bound to plan '{key}': {mapping}")
            self._bindings[phase] = mapping
        # an explicit decode_impl overrides whatever the stored decode plan
        # (or the default preference order) would pick for the hot loop's
        # paged_attention block; "auto" leaves the planner's choice alone
        if decode_impl != "auto":
            base = self._bindings.get("decode") or {}
            self._bindings["decode"] = {
                **base, "paged_attention": decode_impl,
            }

        # the cache arguments are donated: the old cache is dead the moment
        # a step returns its successor, and without donation every decode
        # step / admission would copy the full multi-layer KV cache.
        # Every jitted program registers with the repro.analysis hot-path
        # pass: the wrapper records each call's abstract signature so
        # engine.lint() can verify the PR-4/5 contracts (decode's host
        # transfer is token ids only, recomposition never retraces).
        from repro.analysis.hotpath import ProgramSet

        self.programs = ProgramSet()
        # the ProgramSet shares the engine's obs attachments: new-signature
        # calls emit "compile" spans and feed the retrace counters, and the
        # hot-path lint can flag any program left without a span_kind
        self.programs.tracer = self.tracer
        self.programs.metrics = self.registry
        self._prefill_fn = self.programs.register(
            "prefill", jax.jit(self._build_prefill()),
            carry_outputs=(1,),  # the b1 cache goes to insert, not to host
            span_kind="prefill",
        )
        self._decode_fn = self.programs.register(
            "decode", jax.jit(self._build_decode(), donate_argnums=(2,)),
            loop=True,
            carry_outputs=(1,),  # the donated successor cache stays on device
            expected_signatures=1,  # recomposing the batch must not retrace
            span_kind="decode",
        )
        self._insert_fn = self.programs.register(
            "insert",
            jax.jit(
                self._insert_slot_paged if self.paged else self._insert_slot,
                donate_argnums=(0,),
            ),
            carry_outputs=(0,),  # the whole output is the engine cache
            expected_signatures=1,  # slot recomposition must not retrace
            span_kind="prefill",  # insert runs inside the prefill span
        )
        self._extend_fn = self.programs.register(
            "extend", jax.jit(self._build_extend(), donate_argnums=(2,)),
            carry_outputs=(0,),
            span_kind="prefill-chunk",
        )
        self._extend_sample_fn = self.programs.register(
            "extend_sample",
            jax.jit(self._build_extend_sample(), donate_argnums=(2,)),
            carry_outputs=(1,),
            span_kind="prefill-chunk",
        )

        # host-side per-slot state mirrors (pushed each decode step)
        self._last_tok = np.zeros((n_slots, 1), np.int32)
        self._seeds = np.zeros((n_slots,), np.int32)
        self._gen_counts = np.zeros((n_slots,), np.int32)
        self._temps = np.zeros((n_slots,), np.float32)
        self._topks = np.zeros((n_slots,), np.int32)
        self._lengths = np.zeros((n_slots,), np.int64)  # resident tokens

        #: slots mid-chunked-prefill (slot -> _PrefillProgress); these
        #: occupy a slot + pages but are excluded from decode until the
        #: final chunk samples their first token
        self._prefilling: dict[int, _PrefillProgress] = {}
        # device-resident page-table operand, re-uploaded only when the
        # table actually changed (steady-state decode recomposes nothing)
        self._pages_op: jax.Array | None = None
        self._pages_version = -1

        self.telemetry = {
            p: PhaseTelemetry(p, registry=self.registry) for p in PHASES
        }
        self.completions: dict[int, Completion] = {}
        self._finished: list[Completion] = []
        self._next_id = 0
        self._submitted = 0
        self._steps = 0
        self._max_active = 0
        self._chunk_calls = 0
        # per-step KV-health samples (while requests were resident):
        # (utilization_pct, stranded_pct, fragmentation_pct) running sums
        self._kv_samples = 0
        self._kv_sums = [0.0, 0.0, 0.0]

    # -- admission policy ------------------------------------------------------
    @staticmethod
    def _ctx_len(state: RequestState) -> int:
        """Tokens of context an admission must (re-)prefill: the prompt,
        plus any tokens already generated before a preemption."""
        return len(state.request.prompt) + len(state.tokens)

    def _is_chunked(self, ctx: int) -> bool:
        return self.prefill_chunk is not None and ctx > self.prefill_chunk

    def _admission_cost(self, state: RequestState) -> int:
        """Budget tokens the admission's first program call runs."""
        ctx = self._ctx_len(state)
        if self._is_chunked(ctx):
            return self.prefill_chunk
        return self._padded_len(ctx)

    def _admission_tokens(self, state: RequestState) -> int:
        """Tokens the admission must hold pages for right now."""
        ctx = self._ctx_len(state)
        if self._is_chunked(ctx):
            return min(ctx, self.prefill_chunk)
        return ctx

    # -- plan resolution ------------------------------------------------------
    def _resolve_plan_keys(
        self,
        plan_dir: str | None,
        plan_keys: "dict[str, str | None] | str | None",
    ) -> dict[str, str | None]:
        if isinstance(plan_keys, str):
            return {p: plan_keys for p in PHASES}
        if plan_keys is not None:
            unknown = set(plan_keys) - set(PHASES)
            if unknown:
                raise KeyError(
                    f"unknown serve phases {sorted(unknown)}; known: {PHASES}"
                )
            return {p: plan_keys.get(p) for p in PHASES}
        if plan_dir:
            from repro.offload.zoo import default_plan_key

            # zoo plans are keyed by the *base* arch — a reduced config
            # (verification-environment shape) binds the same plans
            arch = self.cfg.name.removesuffix("-reduced")
            return {
                p: default_plan_key(plan_dir, arch, p) for p in PHASES
            }
        return {p: None for p in PHASES}

    def _phase(self, phase: str):
        mapping = self._bindings.get(phase)
        if not mapping:
            return contextlib.nullcontext()
        return blocks_mod.registry.bind(mapping)

    # -- jitted programs -------------------------------------------------------
    def _build_prefill(self):
        cfg = self.cfg
        cache_metas = lm.cache_metas_tree(cfg, 1, self._slot_len)

        def prefill_fn(params, tokens, last_idx, seed, gen_step, temp, topk):
            """tokens (1, Lp) -> (sampled token (1,), filled b1 cache).

            The zero cache is built *inside* the program (XLA fuses it to
            nothing), only the *last real position*'s hidden state reaches
            the head — the (1, Lp, V) logits tensor is never materialised
            — and padded bucket positions past ``last_idx`` are ignored.
            ``gen_step`` is the sampled token's generation index: 0 for a
            fresh request, ``len(tokens)`` when a preempted request
            resumes (the (seed, index) PRNG key must keep its place).
            """
            from repro.models import params as pm

            cache = pm.init_params(cache_metas, 0)
            x, _, new_cache = lm.backbone(
                params, {"tokens": tokens}, cfg, "prefill", cache
            )
            x_last = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
            logits = lm.head(params, x_last, cfg)[:, 0, : cfg.vocab_size]
            tok = sample_tokens(
                logits,
                seed[None],
                gen_step[None],
                temp[None],
                topk[None],
            )
            new_cache["index"] = (last_idx + 1)[None].astype(jnp.int32)
            return tok, new_cache

        return prefill_fn

    def _build_decode(self):
        cfg = self.cfg
        paged = self.paged

        def decode_fn(params, tokens, cache, pages, seeds, steps, temps, topks):
            """One fused (logits -> token) step for the whole slot batch.
            ``pages`` is the page-table operand (paged mode; unused
            otherwise) — recomposing the batch never retraces."""
            if paged:
                cache = dict(cache, pages=pages)
            logits, new_cache = lm.decode_step(params, tokens, cfg, cache)
            new_cache.pop("pages", None)
            tok = sample_tokens(
                logits[:, 0, : cfg.vocab_size], seeds, steps, temps, topks
            )
            return tok, new_cache

        return decode_fn

    def _build_extend(self):
        cfg = self.cfg

        def extend_fn(params, tokens, cache):
            """One non-final prefill chunk: extend the per-request cache
            by ``tokens`` (1, C), no sampling, no head matmul."""
            _, _, new_cache = lm.backbone(
                params, {"tokens": tokens}, cfg, "extend", cache
            )
            new_cache["index"] = cache["index"] + tokens.shape[1]
            return new_cache

        return extend_fn

    def _build_extend_sample(self):
        cfg = self.cfg

        def extend_sample_fn(
            params, tokens, cache, last_off, seed, gen_step, temp, topk
        ):
            """The final prefill chunk: extend, project only the last real
            position and sample the request's first token."""
            x, _, new_cache = lm.backbone(
                params, {"tokens": tokens}, cfg, "extend", cache
            )
            x_last = jax.lax.dynamic_slice_in_dim(x, last_off, 1, axis=1)
            logits = lm.head(params, x_last, cfg)[:, 0, : cfg.vocab_size]
            tok = sample_tokens(
                logits, seed[None], gen_step[None], temp[None], topk[None]
            )
            new_cache["index"] = cache["index"] + last_off + 1
            return tok, new_cache

        return extend_sample_fn

    @staticmethod
    def _insert_slot(cache, b1_cache, slot, page_ids):
        """Write a batch-1 prefilled cache into slot ``slot`` of the engine
        cache.  Group leaves are (layers, B, ...); ``index`` is (B,).
        ``page_ids`` is unused (contiguous layout)."""
        out = {}
        for key, value in cache.items():
            if key == "index":
                out[key] = value.at[slot].set(b1_cache[key][0])
            else:
                out[key] = jax.tree.map(
                    lambda dst, src: dst.at[:, slot].set(src[:, 0]),
                    value,
                    b1_cache[key],
                )
        return out

    def _insert_slot_paged(self, cache, b1_cache, slot, page_ids):
        """Scatter a batch-1 prefilled cache into the page pool as whole
        pages (``page_ids`` is the slot's (max_pages,) page list; entries
        past the allocation absorb into the null page).  SSM state groups
        have no sequence axis — they stay slot-indexed."""
        out = {}
        for key, value in cache.items():
            if key == "index":
                out[key] = value.at[slot].set(b1_cache[key][0])
            elif self._group_kinds[key] == "m":
                out[key] = jax.tree.map(
                    lambda dst, src: dst.at[:, slot].set(src[:, 0]),
                    value,
                    b1_cache[key],
                )
            else:
                out[key] = {
                    leaf: insert_pages(
                        value[leaf],
                        b1_cache[key][leaf],
                        page_ids,
                        self._seq_axes[leaf],
                    )
                    for leaf in value
                }
        return out

    # -- public API ------------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request; returns its request id.  Admission happens on a
        subsequent ``step()`` when a slot and token budget are available."""
        total = len(request.prompt) + request.max_new_tokens
        if total > self.max_len:
            raise ValueError(
                f"request needs {total} cache positions "
                f"(prompt {len(request.prompt)} + {request.max_new_tokens} "
                f"new) but slots hold max_len={self.max_len}"
            )
        if self.kv is not None and (
            self.kv.pages_needed(total) > self.kv.pool.n_pages
        ):
            raise ValueError(
                f"request needs {self.kv.pages_needed(total)} pages "
                f"(prompt {len(request.prompt)} + {request.max_new_tokens} "
                f"new at page_size={self.kv.pool.page_size}) but the pool "
                f"holds {self.kv.pool.n_pages} — it could never be resident"
            )
        request_id = self._next_id
        self._next_id += 1
        self._submitted += 1
        self._submitted_c.inc()
        seed = (
            request.seed
            if request.seed is not None
            else (self.seed * 1_000_003 + request_id) & 0x7FFFFFFF
        )
        submitted_at = time.perf_counter()
        if self.tracer.enabled:
            self.tracer.event(
                "submit", tid=request_track(request_id),
                request=request_id, prompt=len(request.prompt),
                max_new=request.max_new_tokens,
            )
        self.scheduler.enqueue(
            RequestState(
                request_id=request_id,
                request=request,
                slot=-1,
                seed=seed,
                submitted_at=submitted_at,
            )
        )
        return request_id

    def step(self) -> list[Token | Completion]:
        """One scheduling round: in-flight prefill chunks, admissions
        (a prefill — or a first chunk — each), then one fused decode step
        over every decodable slot.  Returns the streamed events —
        ``Token`` per generated token, ``Completion`` per finished request
        — in generation order."""
        if not self.scheduler.has_work:
            return []
        self._steps += 1
        events: list[Token | Completion] = []

        decoding = sum(
            1 for slot in self.scheduler.active if slot not in self._prefilling
        )
        planned, reserved = self._plan_chunks(decoding)
        spent = decoding + sum(run for _, run in planned) + reserved
        for slot, run in planned:
            self._run_chunk(slot, run, events)

        admitted = self.scheduler.admissions(spent=spent)
        # concurrency peaks right after admission, before same-step
        # finishes release their slots — sample it here, not at step end
        self._max_active = max(self._max_active, len(self.scheduler.active))
        for state in admitted:
            events.extend(self._admit(state))
        if any(
            slot not in self._prefilling for slot in self.scheduler.active
        ):
            events.extend(self._decode_active())
        self._sample_kv_health()
        self._queue_depth_g.set(len(self.scheduler.waiting))
        self._active_slots_g.set(len(self.scheduler.active))
        return events

    def run_until_idle(self, max_steps: int | None = None) -> list[Completion]:
        """Drive ``step()`` until every submitted request has completed;
        returns the completions in finish order."""
        start = len(self._finished)
        steps = 0
        while self.scheduler.has_work:
            self.step()
            steps += 1
            if max_steps is not None and steps > max_steps:
                raise RuntimeError(
                    f"engine still busy after {max_steps} steps "
                    f"({len(self.scheduler.active)} active, "
                    f"{len(self.scheduler.waiting)} waiting)"
                )
        return self._finished[start:]

    def stream(
        self, requests: Iterable[Request]
    ) -> "Iterable[Token | Completion]":
        """Submit ``requests`` and yield events until idle (convenience)."""
        for request in requests:
            self.submit(request)
        while self.scheduler.has_work:
            yield from self.step()

    def reset_stats(self) -> None:
        """Zero every lifetime counter — telemetry, monitor, scheduler
        reuse accounting, completions — without touching the compiled
        programs or the cache.  For load generators that warm the traces
        up front and must not report the warmup as served traffic.  Only
        valid on an idle engine (no active or waiting requests)."""
        if self.scheduler.has_work:
            raise RuntimeError("reset_stats on a busy engine")
        # the registry resets in place (child handles stay valid — the
        # scheduler and phase-telemetry counters keep working) and the
        # tracer drops the warmup spans with the rest of the warmup stats
        self.registry.reset()
        self.tracer.clear()
        self.telemetry = {
            p: PhaseTelemetry(p, registry=self.registry) for p in PHASES
        }
        self.monitor = StepMonitor(
            window=self.monitor.window.maxlen or 32,
            threshold=self.monitor.threshold,
            patience=self.monitor.patience,
            on_straggler=self.monitor.on_straggler,
            histogram=self._step_hist,
        )
        self.scheduler.admitted_per_slot.clear()
        self.scheduler.preemptions = 0
        if self.kv is not None:
            self.kv.pool.peak_used = self.kv.pool.used_pages
        self.completions.clear()
        self._finished.clear()
        self._submitted = 0
        self._steps = 0
        self._max_active = 0
        self._chunk_calls = 0
        self._kv_samples = 0
        self._kv_sums = [0.0, 0.0, 0.0]

    @property
    def stats(self) -> EngineStats:
        return EngineStats(
            steps=self._steps,
            requests_submitted=self._submitted,
            requests_completed=len(self._finished),
            prefill_calls=self.telemetry["prefill"].calls,
            decode_steps=self.telemetry["decode"].calls,
            tokens_generated=sum(
                len(c.tokens) for c in self._finished
            ) + sum(
                len(s.tokens) for s in self.scheduler.active.values()
            ),
            slot_reuses=self.scheduler.slot_reuses,
            max_active=self._max_active,
            preemptions=self.scheduler.preemptions,
            prefill_chunks=self._chunk_calls,
        )

    def _kv_snapshot(self) -> tuple[float, float, float]:
        """(utilization %, stranded %, fragmentation %) right now."""
        if self.kv is not None:
            pool = self.kv.pool
            return (
                100.0 * pool.used_pages / pool.n_pages,
                self.kv.stranded_pct,
                self.kv.fragmentation_pct,
            )
        active = len(self.scheduler.active)
        resident = int(
            sum(self._lengths[slot] for slot in self.scheduler.active)
        )
        reserved = active * self.max_len
        return (
            100.0 * reserved / (self.n_slots * self.max_len),
            100.0 * (reserved - resident) / reserved if reserved else 0.0,
            0.0,
        )

    def _sample_kv_health(self) -> None:
        if not self.scheduler.active:
            return
        util, stranded, frag = self._kv_snapshot()
        self._kv_samples += 1
        self._kv_sums[0] += util
        self._kv_sums[1] += stranded
        self._kv_sums[2] += frag
        self._kv_util_g.set(util)
        self._kv_stranded_g.set(stranded)
        self._kv_frag_g.set(frag)

    def metrics(self) -> dict:
        """KV memory health: pool utilization, stranded capacity and page
        fragmentation (paged), or the contiguous equivalents — the numbers
        that justify (or size) the page pool.  The ``mean_*`` keys average
        one sample per engine step taken while requests were resident, so
        they describe the *served* traffic, not the idle end state."""
        active = len(self.scheduler.active)
        resident = int(
            sum(self._lengths[slot] for slot in self.scheduler.active)
        )
        n = max(self._kv_samples, 1)
        out: dict = {
            "mode": "paged" if self.paged else "contiguous",
            "n_slots": self.n_slots,
            "max_len": self.max_len,
            "active": active,
            "waiting": len(self.scheduler.waiting),
            "preemptions": self.scheduler.preemptions,
            "prefill_chunks": self._chunk_calls,
            "mean_utilization_pct": self._kv_sums[0] / n,
            "mean_stranded_pct": self._kv_sums[1] / n,
            "mean_fragmentation_pct": self._kv_sums[2] / n,
        }
        out["programs"] = self.programs.stats()
        if self.kv is not None:
            out["kv"] = self.kv.stats()
        else:
            # a contiguous slot strands its whole unused tail — the
            # number the page pool exists to reclaim
            util, stranded, _ = self._kv_snapshot()
            out["kv"] = {
                "token_capacity": self.n_slots * self.max_len,
                "resident_tokens": resident,
                "reserved_tokens": active * self.max_len,
                "utilization_pct": util,
                "stranded_pct": stranded,
            }
        return out

    def serve_metrics(self, port: int = 0, host: str = "127.0.0.1"):
        """Expose this engine's :class:`~repro.obs.MetricsRegistry` over
        HTTP (Prometheus text format at ``/metrics``) on a daemon thread.
        ``port=0`` picks a free port.  Returns the
        :class:`~repro.obs.MetricsServer`; call ``.close()`` to stop it."""
        from repro.obs import MetricsServer

        return MetricsServer(self.registry, port=port, host=host)

    def profile_steps(self, n_steps: int, logdir: str) -> bool:
        """Drive ``step()`` ``n_steps`` times under a ``jax.profiler``
        capture window written to ``logdir``.  Returns False (and still
        runs the steps) when the profiler is unavailable — the window is
        opt-in observability, never a hard dependency."""
        from repro.obs import profile_window

        with profile_window(
            logdir, tracer=self.tracer, name="serve-steps"
        ) as captured:
            for _ in range(n_steps):
                if not self.scheduler.has_work:
                    break
                self.step()
        return captured

    def lint(self, envelope: Any = None) -> list:
        """Run the ``repro.analysis`` hot-path pass over every program this
        engine has actually called (host-sync, retrace drift, callbacks,
        constant capture) plus the page-aliasing sanitizer over the current
        page-table operand.  With ``envelope`` (a ``DeviceEnvelope`` or
        static-table name), the static capacity plan's verdict joins the
        diagnostics — a deployment that cannot fit is a ratchetable
        ``capacity-oom`` warning.  Returns the diagnostics; empty means
        the serving contracts hold for the traffic served so far."""
        from repro.analysis.paging import check_page_table

        diags = list(self.programs.lint())
        if self.kv is not None:
            diags.extend(
                check_page_table(
                    self.kv,
                    live_slots=set(self.scheduler.active),
                    program=f"{self.cfg.name}:page-table",
                )
            )
        if envelope is not None:
            plan = self.plan_capacity(envelope)
            diags.extend(
                plan.diagnostics(program=f"serve:{self.cfg.name}:capacity")
            )
        return diags

    def plan_capacity(self, envelope: Any = None) -> Any:
        """Static capacity plan of *this* deployment against a device
        envelope (default: probe the live device) — the serve-side
        analogue of the paper's FPGA resource-fit pre-check.  The plan's
        pool-token figure is cross-checked against the live ``PagePool``
        so the static math can never drift from the engine's accounting,
        and fit/headroom land on the metrics registry for the re-planner
        to watch."""
        from repro.analysis.resources import plan_serve_capacity

        plan = plan_serve_capacity(
            self.cfg,
            n_slots=self.n_slots,
            max_len=self.max_len,
            page_size=self.kv.pool.page_size if self.kv is not None else None,
            n_pages=self.kv.pool.n_pages if self.kv is not None else None,
            envelope=envelope,
        )
        if self.kv is not None and plan.pool_tokens != self.kv.pool.token_capacity:
            raise AssertionError(
                f"capacity plan sized the pool at {plan.pool_tokens} tokens "
                f"but the live PagePool holds {self.kv.pool.token_capacity}"
            )
        self._capacity_fits_g.set(1.0 if plan.fits else 0.0)
        self._capacity_headroom_g.set(float(plan.headroom_bytes))
        self._capacity_max_slots_g.set(float(plan.max_slots))
        return plan

    # -- phase execution -------------------------------------------------------
    def _padded_len(self, length: int) -> int:
        if self.prefill_bucket:
            bucket = self.prefill_bucket
            length = min(-(-length // bucket) * bucket, self.max_len)
        return length

    def _padded_prompt(self, context: Sequence[int]) -> np.ndarray:
        out = np.zeros((1, self._padded_len(len(context))), np.int32)
        out[0, : len(context)] = context
        return out

    def _request_knobs(self, state: RequestState) -> tuple[float, int]:
        return (state.request.sampling or self.sampler).knobs

    def _slot_page_row(self, slot: int) -> jax.Array:
        """The slot's (max_pages,) page-id operand for the insert program
        (null-page filled past the allocation)."""
        assert self.kv is not None
        return jnp.asarray(self.kv.array()[slot])

    def _preempt_for_pages(self, needy_slot: int) -> bool:
        """Reclaim pages by preempting the youngest other request —
        decoding victims first, then mid-prefill ones, finally the needy
        slot itself (requeue beats deadlock).  Returns False when there is
        nothing left to preempt."""
        decoding = [
            slot
            for slot in self.scheduler.active
            if slot not in self._prefilling and slot != needy_slot
        ]
        prefilling = [
            slot for slot in self._prefilling if slot != needy_slot
        ]
        pool = decoding or prefilling or (
            [needy_slot] if needy_slot in self.scheduler.active else []
        )
        if not pool:
            return False
        victim = max(pool, key=lambda s: self.scheduler.active[s].admit_seq)
        self._prefilling.pop(victim, None)
        self.scheduler.preempt(victim)
        self._gen_counts[victim] = 0
        self._lengths[victim] = 0
        return True

    def _ensure_pages(self, slot: int, n_tokens: int) -> None:
        """Grow the slot to ``n_tokens`` of page capacity, preempting under
        pool pressure.  Raises only when preemption cannot free enough —
        impossible for requests submit() admitted (each fits the pool
        alone)."""
        if self.kv is None:
            return
        while True:
            try:
                added = self.kv.ensure(slot, n_tokens)
                if added and self.tracer.enabled and (
                    slot in self.scheduler.active
                ):
                    state = self.scheduler.active[slot]
                    self.tracer.event(
                        "kv-grow", tid=request_track(state.request_id),
                        request=state.request_id, slot=slot,
                        pages=len(added),
                    )
                return
            except PoolExhausted:
                if not self._preempt_for_pages(slot):
                    raise
                if slot not in self.scheduler.active:
                    return  # the needy slot preempted itself: it no longer
                    # holds pages, and allocating onto a freed slot would
                    # leak them (callers re-check liveness)

    # -- chunked prefill -------------------------------------------------------
    def _plan_chunks(self, decoding: int) -> tuple[list[tuple[int, int]], int]:
        """Pick which mid-prefill slots run a chunk this step, and how many
        tokens each: budget-capped, but guaranteed progress when nothing
        else runs this step.  Returns ``(planned, reserved)`` — skipped
        chunks *reserve* their budget tokens so this step's admissions
        cannot refill the budget and starve an in-flight prefill forever."""
        budget = self.scheduler.max_tokens_per_step
        planned: list[tuple[int, int]] = []
        reserved = 0
        spent = decoding
        for slot in sorted(self._prefilling):
            prog = self._prefilling[slot]
            run = min(self.prefill_chunk, len(prog.context) - prog.pos)
            if budget is not None and spent + reserved + run > budget:
                if spent or planned:
                    reserved += run  # held against new admissions
                    continue  # decode / earlier chunks run first
                # nothing else runs this step: progress beats the budget
            planned.append((slot, run))
            spent += run
        return planned, reserved

    def _run_chunk(
        self, slot: int, run: int, events: list[Token | Completion]
    ) -> None:
        """Extend one request's working cache by one chunk; the final chunk
        samples the first token and commits the cache into the slot."""
        if slot not in self._prefilling:
            return  # preempted by an earlier slot's page-ensure this step
        prog = self._prefilling[slot]
        state = prog.state
        final = prog.pos + run >= len(prog.context)
        # pages for this chunk (reserved now, written at the final insert)
        self._ensure_pages(slot, prog.pos + run)
        if slot not in self._prefilling:
            return  # self-preempted under extreme pool pressure
        # the final chunk runs at its exact width: padding it to the chunk
        # would write zero-token K/V past the context end — and past the
        # cache end for a near-max_len prompt, where dynamic_update_slice
        # clamps the write *backward* over correct prompt rows.  One trace
        # per distinct tail length, same policy as the prefill program.
        tokens = np.asarray(
            [prog.context[prog.pos : prog.pos + run]], np.int32
        )
        self._chunk_calls += 1
        t0 = time.perf_counter()
        with self._phase("prefill"), meter_window(self.meter) as tele:
            if final:
                temp, topk = self._request_knobs(state)
                tok, b1_cache = self._extend_sample_fn(
                    self.params,
                    jnp.asarray(tokens),
                    prog.cache,
                    jnp.asarray(run - 1, jnp.int32),
                    jnp.asarray(state.seed, jnp.int32),
                    jnp.asarray(len(state.tokens), jnp.int32),
                    jnp.asarray(temp, jnp.float32),
                    jnp.asarray(topk, jnp.int32),
                )
                self._commit_slot(state, tok, b1_cache, events)
                del self._prefilling[slot]
            else:
                prog.cache = self._extend_fn(
                    self.params, jnp.asarray(tokens), prog.cache
                )
                prog.pos += run
        self.telemetry["prefill"].add(tele, run)
        if self.tracer.enabled:
            self.tracer.add_span(
                "prefill-chunk", t0, time.perf_counter(),
                tid=request_track(state.request_id),
                request=state.request_id, slot=slot, tokens=run,
                final=final, step=self._steps,
            )

    def _fresh_b1_cache(self) -> Any:
        return lm.init_cache(self.cfg, 1, self._slot_len)

    # -- admission / decode ----------------------------------------------------
    def _admit(self, state: RequestState) -> list[Token | Completion]:
        context = list(state.request.prompt) + list(state.tokens)
        if self._is_chunked(len(context)):
            self._prefilling[state.slot] = _PrefillProgress(
                state, context, self._fresh_b1_cache()
            )
            events: list[Token | Completion] = []
            self._run_chunk(state.slot, self.prefill_chunk, events)
            return events

        temp, topk = self._request_knobs(state)
        tokens = self._padded_prompt(context)
        with self._phase("prefill"), meter_window(self.meter) as tele:
            tok, b1_cache = self._prefill_fn(
                self.params,
                jnp.asarray(tokens),
                jnp.asarray(len(context) - 1, jnp.int32),
                jnp.asarray(state.seed, jnp.int32),
                jnp.asarray(len(state.tokens), jnp.int32),
                jnp.asarray(temp, jnp.float32),
                jnp.asarray(topk, jnp.int32),
            )
            events = []
            self._commit_slot(state, tok, b1_cache, events)
        self.telemetry["prefill"].add(tele, len(context))
        return events

    def _commit_slot(
        self,
        state: RequestState,
        tok: jax.Array,
        b1_cache: Any,
        events: list[Token | Completion],
    ) -> None:
        """Insert a fully prefilled batch-1 cache into the slot, record the
        sampled token and arm the slot for decode."""
        slot = state.slot
        context = self._ctx_len(state)
        if self.paged:
            # pad the b1 cache's sequence up to whole pages so the insert
            # scatters complete pages (prefill already built it that long)
            page_row = self._slot_page_row(slot)
        else:
            page_row = jnp.zeros((1,), jnp.int32)  # unused operand
        self.cache = self._insert_fn(
            self.cache, b1_cache, jnp.asarray(slot, jnp.int32), page_row
        )
        first = int(np.asarray(tok)[0])  # blocks inside the meter window

        temp, topk = self._request_knobs(state)
        gen_index = len(state.tokens)
        self._last_tok[slot, 0] = first
        self._seeds[slot] = state.seed
        self._gen_counts[slot] = gen_index + 1
        self._temps[slot] = temp
        self._topks[slot] = topk
        # kv.lengths needs no sync: alloc_slot/ensure already tracked the
        # context through admission and the chunk loop
        self._lengths[slot] = context
        now = time.perf_counter()
        if self.tracer.enabled:
            track = request_track(state.request_id)
            # the prefill span covers admission -> first token, including
            # every chunk for chunked prompts (chunk sub-spans sit inside)
            self.tracer.add_span(
                "prefill", state.last_admitted_at or now, now, tid=track,
                request=state.request_id, slot=slot, tokens=context,
                step=self._steps,
            )
            if state.first_token_at is None:
                self.tracer.event(
                    "first-token", tid=track, request=state.request_id,
                    token=first,
                )
        if state.first_token_at is None:
            state.first_token_at = now
        state.tokens.append(first)
        events.append(
            Token(state.request_id, first, gen_index, "prefill", self._steps)
        )
        if state.done:
            events.append(self._finish(slot))

    def _decode_active(self) -> list[Token | Completion]:
        if self.paged:
            # grow page capacity for this step's writes up front; under
            # pool pressure this preempts the youngest request (which may
            # shrink the decoding set)
            for slot in sorted(self.scheduler.active):
                if slot in self._prefilling:
                    continue
                if slot not in self.scheduler.active:
                    continue  # preempted by an earlier slot's ensure
                self._ensure_pages(slot, int(self._lengths[slot]) + 1)
        active = {
            slot: state
            for slot, state in self.scheduler.active.items()
            if slot not in self._prefilling
        }
        if not active:
            return []
        if self.kv is None:
            pages = jnp.zeros((1,), jnp.int32)  # unused operand
        else:
            if self._pages_version != self.kv.version:
                self._pages_op = jnp.asarray(self.kv.array())
                self._pages_version = self.kv.version
            pages = self._pages_op
        t0 = time.perf_counter()
        self.monitor.start()
        with self._phase("decode"), meter_window(self.meter) as tele:
            tok, self.cache = self._decode_fn(
                self.params,
                jnp.asarray(self._last_tok),
                self.cache,
                pages,
                jnp.asarray(self._seeds),
                jnp.asarray(self._gen_counts),
                jnp.asarray(self._temps),
                jnp.asarray(self._topks),
            )
            toks = np.asarray(tok)  # the only device->host transfer: (B,)
        self.monitor.stop(self._steps)
        self.telemetry["decode"].add(tele, len(active))
        if self.tracer.enabled:
            t1 = time.perf_counter()
            # one fused-step span on the engine track, mirrored onto each
            # participating request's track so per-request timelines show
            # their decode cadence (and the gaps where they waited)
            self.tracer.add_span(
                "decode", t0, t1, batch=len(active), step=self._steps,
            )
            for state in active.values():
                self.tracer.add_span(
                    "decode", t0, t1, tid=request_track(state.request_id),
                    request=state.request_id, step=self._steps,
                )

        events: list[Token | Completion] = []
        for slot, state in active.items():
            token = int(toks[slot])
            self._last_tok[slot, 0] = token
            self._gen_counts[slot] += 1
            # kv.lengths needs no sync: _ensure_pages set it to this very
            # value before the step ran
            self._lengths[slot] += 1
            index = len(state.tokens)
            state.tokens.append(token)
            events.append(
                Token(state.request_id, token, index, "decode", self._steps)
            )
            if state.done:
                events.append(self._finish(slot))
        return events

    def _finish(self, slot: int) -> Completion:
        state = self.scheduler.release(slot)
        self._gen_counts[slot] = 0
        self._lengths[slot] = 0
        completion = Completion(
            request_id=state.request_id,
            prompt=state.request.prompt,
            tokens=tuple(state.tokens),
            finish_reason=state.finish_reason,
            submitted_at=state.submitted_at,
            first_token_at=state.first_token_at or time.perf_counter(),
            finished_at=time.perf_counter(),
            admitted_at=state.admitted_at,
        )
        self._completed_c.inc()
        self._generated_c.inc(len(completion.tokens))
        if self.tracer.enabled:
            self.tracer.event(
                "complete", tid=request_track(state.request_id),
                request=state.request_id, tokens=len(completion.tokens),
                reason=completion.finish_reason,
            )
        self.completions[state.request_id] = completion
        self._finished.append(completion)
        return completion
