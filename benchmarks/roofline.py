"""Roofline analysis from the dry-run's compiled artifacts.

Reads results/dryrun.json (produced by repro.launch.dryrun) and derives,
per (arch x shape x mesh):

    compute term    = HLO_FLOPs_global   / (chips * 197 TF/s)
    memory term     = HLO_bytes_global   / (chips * 819 GB/s)
    collective term = coll_bytes_global  / (chips * 50 GB/s)

(global = per-device value x chips; the dry-run records per-device numbers
from the post-SPMD module, loop-aware — see launch/hlo_cost.py.)

Also reports MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), the
useful-compute ratio MODEL_FLOPS / HLO_FLOPS, the dominant term, and the
roofline fraction = max(model-flops time) / (sum of the three terms) — the
"how close to the roofline would this run" score under a no-overlap
assumption (pessimistic; overlapped collectives only improve it).
"""

from __future__ import annotations

import argparse
import json
import pathlib

from repro.launch.mesh import HW


def analyze_record(r: dict) -> dict | None:
    if r.get("status") != "ok":
        return None
    chips = r["chips"]
    flops = r["hlo_flops_per_device"]
    hbm = r["hlo_bytes_per_device"]
    coll = r.get("collective_bytes_per_device", 0.0)
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = hbm / HW["hbm_bw"]
    t_coll = coll / HW["ici_bw"]
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    model_flops = r.get("model_flops", 0.0)
    t_model = model_flops / chips / HW["peak_flops_bf16"]
    total = t_compute + t_memory + t_coll
    # TPU projection: the CPU backend float-normalizes EVERY bf16 collective
    # to f32 (verified: zero bf16 collectives across all compiled cells), so
    # collective bytes measure 2x the native-bf16 TPU value.
    total_proj = t_compute + t_memory + t_coll / 2
    return {
        "roofline_fraction_tpu_proj": (t_model / total_proj) if total_proj else 0.0,
        "arch": r["arch"],
        "shape": r["shape"],
        "mesh": r["mesh"],
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": flops * chips,
        "useful_ratio": (model_flops / (flops * chips)) if flops else 0.0,
        "roofline_fraction": (t_model / total) if total else 0.0,
        "peak_gb": r.get("peak_bytes_per_device", 0) / 1e9,
        "fits_16gb": r.get("fits_16gb"),
    }


def run(path: str = "results/dryrun.json", mesh: str | None = "16x16",
        emit_csv: bool = True) -> list[dict]:
    from benchmarks.common import emit

    data = json.loads(pathlib.Path(path).read_text())
    rows = []
    for r in data:
        if mesh and r.get("mesh") != mesh:
            continue
        a = analyze_record(r)
        if a is None:
            continue
        rows.append(a)
        if emit_csv:
            emit(
                f"roofline.{a['arch']}.{a['shape']}.{a['mesh']}",
                a["compute_s"] + a["memory_s"] + a["collective_s"],
                f"dom={a['dominant']} comp={a['compute_s']:.3f}s "
                f"mem={a['memory_s']:.3f}s coll={a['collective_s']:.3f}s "
                f"useful={a['useful_ratio']:.2f} "
                f"roofline={a['roofline_fraction']:.3f} "
                f"tpu_proj={a['roofline_fraction_tpu_proj']:.3f}",
            )
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | useful ratio | roofline frac | tpu proj | peak GB |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for a in rows:
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['compute_s']:.3f} | {a['memory_s']:.3f} "
            f"| {a['collective_s']:.3f} | {a['dominant']} "
            f"| {a['useful_ratio']:.2f} | {a['roofline_fraction']:.3f} "
            f"| {a['roofline_fraction_tpu_proj']:.3f} | {a['peak_gb']:.1f} |"
        )
    return hdr + "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default="results/dryrun.json")
    ap.add_argument("--mesh", default=None, help="filter: 16x16 or 2x16x16")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = run(args.path, args.mesh, emit_csv=not args.markdown)
    if args.markdown:
        print(markdown_table(rows))


if __name__ == "__main__":
    main()
