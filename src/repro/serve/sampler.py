"""Pluggable token sampling, fused into the jitted serving steps.

The serving engine never ships logits to the host: the (B, V) logits tensor
stays on device and :func:`sample_tokens` reduces it to (B,) token ids
*inside* the jitted prefill/decode programs, so the per-step host transfer
is token ids only (the decode loop's classic sync bottleneck).

One program covers every sampler: the per-slot knobs — ``temperature`` and
``top_k`` — are *dynamic* (B,) inputs, not trace-time constants, so a batch
can mix a greedy request with a top-k request without retracing.  Greedy is
``temperature == 0``; ``top_k == 0`` disables the top-k filter.

Determinism: each slot's PRNG key is derived from (request seed, token
index) alone — never from the slot number, the engine step, or which other
requests share the batch — so a request replayed under a different batch
composition samples the identical token sequence.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class Sampler:
    """Sampling policy: greedy / temperature / top-k.

    ``kind`` exists for readability; the engine lowers every policy to the
    (temperature, top_k) pair consumed by :func:`sample_tokens`.
    """

    kind: str = "greedy"  # "greedy" | "temperature" | "top_k"
    temperature: float = 0.0
    top_k: int = 0

    def __post_init__(self) -> None:
        if self.kind not in ("greedy", "temperature", "top_k"):
            raise ValueError(
                f"unknown sampler kind '{self.kind}'; "
                "known: greedy, temperature, top_k"
            )
        if self.kind == "greedy" and self.temperature:
            raise ValueError("greedy sampling takes no temperature")
        if self.kind != "greedy" and self.temperature <= 0:
            raise ValueError(f"{self.kind} sampling needs temperature > 0")
        if self.kind == "top_k" and self.top_k < 1:
            raise ValueError("top_k sampling needs top_k >= 1")
        if self.kind != "top_k" and self.top_k:
            raise ValueError(f"{self.kind} sampling takes no top_k")

    # -- constructors --------------------------------------------------------
    @classmethod
    def greedy(cls) -> "Sampler":
        return cls("greedy")

    @classmethod
    def with_temperature(cls, temperature: float) -> "Sampler":
        return cls("temperature", temperature=temperature)

    @classmethod
    def with_top_k(cls, top_k: int, temperature: float = 1.0) -> "Sampler":
        return cls("top_k", temperature=temperature, top_k=top_k)

    @classmethod
    def parse(cls, spec: str) -> "Sampler":
        """CLI spelling: ``greedy`` | ``temperature:0.8`` | ``top_k:40:0.8``."""
        parts = spec.split(":")
        if parts == ["greedy"]:
            return cls.greedy()
        if parts[0] == "temperature" and len(parts) == 2:
            return cls.with_temperature(float(parts[1]))
        if parts[0] in ("top_k", "top-k") and len(parts) in (2, 3):
            t = float(parts[2]) if len(parts) > 2 else 1.0
            return cls.with_top_k(int(parts[1]), t)
        raise ValueError(f"unknown sampler spec '{spec}'")

    # -- lowering ------------------------------------------------------------
    @property
    def knobs(self) -> tuple[float, int]:
        """The dynamic (temperature, top_k) pair for :func:`sample_tokens`."""
        return (float(self.temperature), int(self.top_k))


def _slot_key(seed: jax.Array, step: jax.Array) -> jax.Array:
    base = jax.random.PRNGKey(0)
    return jax.random.fold_in(jax.random.fold_in(base, seed), step)


def sample_tokens(
    logits: jax.Array,  # (B, V) float
    seeds: jax.Array,  # (B,) int32: per-request sampling seed
    steps: jax.Array,  # (B,) int32: per-request token index
    temperatures: jax.Array,  # (B,) float32: 0 = greedy
    top_ks: jax.Array,  # (B,) int32: 0 = no top-k filter
) -> jax.Array:
    """(B,) sampled token ids — trace-time shape-stable for any policy mix.

    The expensive paths are gated on *runtime* batch predicates
    (``lax.cond``), so an all-greedy batch — the serving default — skips
    both the O(V log V) top-k threshold sort and the categorical draw
    entirely without needing a separate trace.
    """
    v = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)

    def topk_filter() -> jax.Array:
        # top-k with *dynamic* per-row k: threshold at the k-th largest
        # logit (a sort, not lax.top_k, because k is not a trace constant)
        sorted_desc = -jnp.sort(-lf, axis=-1)
        kth = jnp.clip(top_ks - 1, 0, v - 1)
        thresh = jnp.take_along_axis(sorted_desc, kth[:, None], axis=-1)
        return jnp.where((top_ks[:, None] > 0) & (lf < thresh), _NEG, lf)

    def draw() -> jax.Array:
        filtered = jax.lax.cond(jnp.any(top_ks > 0), topk_filter, lambda: lf)
        temps = jnp.maximum(temperatures, 1e-6)[:, None]
        keys = jax.vmap(_slot_key)(seeds, steps)
        sampled = jax.vmap(jax.random.categorical)(keys, filtered / temps)
        return jnp.where(
            temperatures <= 0, greedy, sampled.astype(jnp.int32)
        )

    return jax.lax.cond(
        jnp.any(temperatures > 0), draw, lambda: greedy
    )
