"""Prior-work GA loop-offload baseline (paper refs [32][33], Fig. 4)."""

import numpy as np
import pytest

from repro.core.ga import run_ga
from repro.apps import fourier, matrix


def test_ga_on_fft_stages_improves():
    x = fourier.make_input(64)  # power of two (radix-2 FFT)
    rep = run_ga(
        fourier.build_fft_variant,
        n_genes=len(fourier.FFT_STAGES),
        args=(x,),
        population=6,
        generations=4,
        repeats=1,
        seed=0,
    )
    assert rep.best_speedup > 1.5
    # Fig. 4 property: best-of-generation is monotonically non-decreasing
    # (elitism) and the history has one entry per generation
    assert len(rep.generations) == 4
    assert all(
        b2 >= b1 * 0.98 for b1, b2 in zip(rep.generations, rep.generations[1:])
    )


def test_ga_caches_repeat_genomes():
    x = fourier.make_input(32)
    rep = run_ga(
        fourier.build_fft_variant,
        n_genes=len(fourier.FFT_STAGES),
        args=(x,),
        population=4,
        generations=3,
        repeats=1,
        seed=1,
    )
    # evaluations must be well below pop*gens if the cache works
    assert rep.evaluations <= 4 * 3 + 1


def test_ga_genome_correctness_preserved():
    x = fourier.make_input(32)
    truth = np.fft.fft2(x)
    for genome in [(0,) * 6, (1,) * 6, (1, 0, 1, 0, 1, 0)]:
        out = fourier.build_fft_variant(genome)(x)
        np.testing.assert_allclose(out, truth, rtol=1e-4, atol=1e-5)


def test_lu_stage_variants_agree():
    a = matrix.make_input(64)
    det_truth = np.linalg.det(a)
    for genome in [(0, 0, 0), (1, 1, 1), (0, 1, 0), (1, 0, 1)]:
        det = float(matrix.build_lu_variant(genome)(a))
        assert abs(det - det_truth) < 1e-2, genome
