"""Page-aliasing sanitizer for the paged-KV scatter/gather programs.

The paged decode program scatter-writes K/V through the ``(n_slots,
max_pages)`` page-table operand.  Its safety argument is entirely a
property of that operand: if no two live batch rows name the same page,
the scatter cannot cross-corrupt requests, and if every freed row is
all-null, writes from dead rows land in the sacrificial null page.  This
module proves those properties *statically on the operand* — no device
execution — and backs the cheap runtime assertion mode of
``repro.serve.kv.PageTable(validate=True)``.

Checks (codes):

* ``page-range``      — a page id outside ``[0, n_pages]`` indexes out of
                        the device cache's page axis (error).
* ``page-alias``      — one non-null page named by two live rows (or twice
                        in one row): scatter-writes collide (error).
* ``freed-slot-write`` — a non-live row still names a real page: a decode
                        write from that row lands in a page another
                        request may now own (error).
* ``page-hole``       — a real page after a null entry in a live row: the
                        gather walks a prefix, so pages after the hole are
                        unreachable (warning).
* ``page-count``      — a live row's page count can't hold its resident
                        length (warning; with ``lengths`` provided).
"""

from __future__ import annotations

from typing import Any, Iterable, Sequence

import numpy as np

from repro.analysis.diagnostics import Diagnostic


def _as_table(table: Any) -> tuple[np.ndarray, int, int | None, list[int] | None]:
    """Normalise a ``PageTable`` or raw array into (array, null_page,
    page_size, lengths)."""
    if hasattr(table, "array") and hasattr(table, "pool"):
        return (
            np.asarray(table.array()),
            int(table.pool.null_page),
            int(table.pool.page_size),
            list(table.lengths),
        )
    return np.asarray(table), -1, None, None


def check_page_table(
    table: Any,
    live_slots: Iterable[int] | None = None,
    null_page: int | None = None,
    page_size: int | None = None,
    lengths: Sequence[int] | None = None,
    program: str = "page-table",
) -> list[Diagnostic]:
    """Statically verify the page-table operand of a paged-KV program.

    ``table`` is a ``repro.serve.kv.PageTable`` (null page, page size and
    lengths read off it) or the raw ``(n_slots, max_pages)`` int array (then
    ``null_page`` is required).  ``live_slots`` restricts which rows are
    expected to hold pages — rows outside it must be all-null; ``None``
    treats every row with any real page as live (pure aliasing check).
    """
    arr, np_null, np_psize, np_lengths = _as_table(table)
    if null_page is None:
        null_page = np_null
    if null_page < 0:
        raise ValueError("null_page required with a raw page-table array")
    page_size = page_size if page_size is not None else np_psize
    lengths = lengths if lengths is not None else np_lengths
    if arr.ndim != 2:
        raise ValueError(f"page table must be 2-D, got shape {arr.shape}")

    diags: list[Diagnostic] = []
    n_slots = arr.shape[0]
    live = (
        set(int(s) for s in live_slots)
        if live_slots is not None
        else {s for s in range(n_slots) if (arr[s] != null_page).any()}
    )

    bad = (arr < 0) | (arr > null_page)
    for slot, col in zip(*np.nonzero(bad)):
        diags.append(Diagnostic(
            pass_name="paging", code="page-range", severity="error",
            program=program, subject=f"slot{slot}[{col}]",
            message=(
                f"page id {int(arr[slot, col])} outside [0, {null_page}] "
                "indexes past the device cache's page axis"
            ),
        ))

    owner: dict[int, tuple[int, int]] = {}
    for slot in range(n_slots):
        row = arr[slot]
        real = row != null_page
        if slot not in live:
            if real.any():
                first = int(np.nonzero(real)[0][0])
                diags.append(Diagnostic(
                    pass_name="paging", code="freed-slot-write",
                    severity="error", program=program,
                    subject=f"slot{slot}",
                    message=(
                        f"freed/inactive slot {slot} still names page "
                        f"{int(row[first])}; its decode writes must land "
                        "in the null page"
                    ),
                ))
            continue
        # live row: real-page prefix, then null padding — a hole makes the
        # pages after it unreachable by the length-bounded gather
        if real.any():
            last_real = int(np.nonzero(real)[0][-1])
            holes = np.nonzero(~real[: last_real + 1])[0]
            if holes.size:
                diags.append(Diagnostic(
                    pass_name="paging", code="page-hole", severity="warning",
                    program=program,
                    subject=f"slot{slot}[{int(holes[0])}]",
                    message=(
                        f"null entry at position {int(holes[0])} precedes "
                        f"real page at {last_real} in live slot {slot}"
                    ),
                ))
        for col in np.nonzero(real)[0]:
            page = int(row[col])
            if page in owner:
                oslot, ocol = owner[page]
                diags.append(Diagnostic(
                    pass_name="paging", code="page-alias", severity="error",
                    program=program,
                    subject=f"page{page}:slot{oslot}+slot{slot}",
                    message=(
                        f"page {page} named by slot {oslot}[{ocol}] and "
                        f"slot {slot}[{int(col)}] — concurrent scatter-"
                        "writes collide"
                    ),
                ))
            else:
                owner[page] = (slot, int(col))
        if lengths is not None and page_size:
            n_real = int(real.sum())
            need = -(-int(lengths[slot]) // page_size)
            if n_real < need:
                diags.append(Diagnostic(
                    pass_name="paging", code="page-count", severity="warning",
                    program=program, subject=f"slot{slot}",
                    message=(
                        f"slot {slot} holds {n_real} pages but its "
                        f"{int(lengths[slot])} resident tokens need {need}"
                    ),
                ))
    return diags


class PageAliasError(AssertionError):
    """Raised by ``PageTable.check_invariants`` when the operand is unsafe."""


def assert_page_table(table: Any, **kwargs: Any) -> None:
    """Raise :class:`PageAliasError` on any error-severity finding."""
    errors = [
        d for d in check_page_table(table, **kwargs) if d.severity == "error"
    ]
    if errors:
        raise PageAliasError(
            "; ".join(str(d) for d in errors)
        )
