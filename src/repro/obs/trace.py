"""Low-overhead structured tracing: typed spans on a thread-safe ring.

The paper's loop decides *where* to offload from measurements of the
running system; this module records *what the system did and when*, so a
slow step has an explanation, not just an aggregate.  A :class:`Tracer`
collects :class:`SpanRecord`s — complete spans (``ph="X"``), instant
events (``ph="i"``) — into a bounded ring buffer (old records drop, the
serve loop never blocks on its own telemetry) and exports them as
Chrome/Perfetto ``trace_event`` JSON (open in https://ui.perfetto.dev) or
a plain JSONL stream.

Two usage shapes::

    with tracer.span("decode", step=12, batch=3):
        ...                                  # timed around the body

    tracer.add_span("queue", t0, t1, tid=track, request=7)   # retroactive

Retroactive spans let the engine place a request's whole lifecycle
(queued -> admitted -> prefill -> decode steps -> complete) on a virtual
per-request *track* from timestamps it already keeps, without holding a
span object open across scheduler callbacks.

**Disabled cost is the design constraint**: ``span()`` on a disabled
tracer returns one shared no-op singleton (no record, no buffer touch),
``event()``/``add_span()`` return immediately, and hot-path callers are
expected to guard argument construction behind ``tracer.enabled``.  The
serving benchmark's acceptance gate is that a disabled tracer is
unmeasurable in tok/s.

All timestamps are ``time.perf_counter()`` seconds — the same clock the
engine stamps on requests — made relative to the tracer's ``epoch`` at
export time.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque
from typing import Any, Iterable, TextIO

__all__ = [
    "NULL_SPAN",
    "SpanRecord",
    "Tracer",
    "get_tracer",
    "set_tracer",
]


@dataclasses.dataclass
class SpanRecord:
    """One trace record: a complete span (``ph="X"``, ``t0 <= t1``) or an
    instant event (``ph="i"``, ``t0 == t1``)."""

    name: str
    t0: float  # perf_counter seconds
    t1: float
    tid: int  # track: a real thread ident or a virtual per-request track
    args: dict | None = None
    ph: str = "X"

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class _NullSpan:
    """The shared no-op context manager a disabled tracer hands out."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """A live ``with tracer.span(...)`` body; records itself at exit."""

    __slots__ = ("_tracer", "name", "tid", "args", "_t0")

    def __init__(
        self, tracer: "Tracer", name: str, tid: int, args: dict | None
    ) -> None:
        self._tracer = tracer
        self.name = name
        self.tid = tid
        self.args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> bool:
        self._tracer._record(
            SpanRecord(
                self.name, self._t0, time.perf_counter(), self.tid, self.args
            )
        )
        return False


class Tracer:
    """Thread-safe span/event recorder with a bounded ring buffer.

    ``enabled=False`` (the default of the module-level tracer) makes every
    entry point a near-free no-op; flip :attr:`enabled` or install an
    enabled tracer with :func:`set_tracer` to start recording.  ``capacity``
    bounds memory: the ring keeps the newest records and counts the rest in
    :attr:`dropped` (reported by the exporters, never silently).
    """

    def __init__(self, capacity: int = 65536, enabled: bool = True) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.enabled = enabled
        self._buf: deque[SpanRecord] = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._track_names: dict[int, str] = {}
        self.dropped = 0
        self.epoch = time.perf_counter()
        self.epoch_unix = time.time()

    # -- recording ---------------------------------------------------------
    def span(self, name: str, tid: int | None = None, **args: Any):
        """Context manager timing its body into one complete span.  On a
        disabled tracer this returns the shared :data:`NULL_SPAN` singleton
        (callers with expensive args should guard on :attr:`enabled`)."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(
            self,
            name,
            tid if tid is not None else threading.get_ident(),
            args or None,
        )

    def event(self, name: str, tid: int | None = None, **args: Any) -> None:
        """Record one instant event."""
        if not self.enabled:
            return
        t = time.perf_counter()
        self._record(
            SpanRecord(
                name,
                t,
                t,
                tid if tid is not None else threading.get_ident(),
                args or None,
                ph="i",
            )
        )

    def add_span(
        self,
        name: str,
        t0: float,
        t1: float,
        tid: int | None = None,
        **args: Any,
    ) -> None:
        """Record a retroactive complete span from caller-held
        ``perf_counter`` timestamps (e.g. a request's queue wait)."""
        if not self.enabled:
            return
        self._record(
            SpanRecord(
                name,
                t0,
                max(t1, t0),
                tid if tid is not None else threading.get_ident(),
                args or None,
            )
        )

    def name_track(self, tid: int, name: str) -> None:
        """Label a track (thread or virtual id) in the exported trace."""
        if not self.enabled:
            return
        with self._lock:
            self._track_names[tid] = name

    def _record(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self._buf) == self._buf.maxlen:
                self.dropped += 1
            self._buf.append(rec)

    # -- reading / lifecycle ----------------------------------------------
    def records(self) -> list[SpanRecord]:
        """Snapshot of the ring, oldest first."""
        with self._lock:
            return list(self._buf)

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    def clear(self) -> None:
        """Drop everything recorded so far (e.g. after a warmup phase)."""
        with self._lock:
            self._buf.clear()
            self.dropped = 0

    # -- exporters ---------------------------------------------------------
    def _ts_us(self, t: float) -> float:
        return max(t - self.epoch, 0.0) * 1e6

    def to_chrome(self) -> dict:
        """Chrome/Perfetto ``trace_event`` JSON object (one process, one
        track per tid, microsecond timestamps relative to the tracer
        epoch).  Complete spans use ``ph="X"`` with ``dur``; instants use
        ``ph="i"`` with thread scope."""
        with self._lock:
            records = sorted(self._buf, key=lambda r: r.t0)
            track_names = dict(self._track_names)
            dropped = self.dropped
        events: list[dict] = []
        for tid, name in sorted(track_names.items()):
            events.append({
                "ph": "M", "pid": 0, "tid": tid, "name": "thread_name",
                "args": {"name": name},
            })
        for rec in records:
            ev: dict = {
                "name": rec.name,
                "ph": rec.ph,
                "pid": 0,
                "tid": rec.tid,
                "ts": self._ts_us(rec.t0),
            }
            if rec.ph == "X":
                ev["dur"] = max(rec.t1 - rec.t0, 0.0) * 1e6
            else:
                ev["s"] = "t"  # thread-scoped instant
            if rec.args:
                ev["args"] = rec.args
            events.append(ev)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "tracer": "repro.obs",
                "epoch_unix": self.epoch_unix,
                "dropped_records": dropped,
            },
        }

    def write_chrome(self, path: str) -> None:
        """Write :meth:`to_chrome` JSON — loadable in ``chrome://tracing``
        and https://ui.perfetto.dev."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
            f.write("\n")

    def iter_jsonl(self) -> Iterable[str]:
        for rec in sorted(self.records(), key=lambda r: r.t0):
            yield json.dumps({
                "name": rec.name,
                "ph": rec.ph,
                "tid": rec.tid,
                "ts": self._ts_us(rec.t0),
                "dur": max(rec.t1 - rec.t0, 0.0) * 1e6,
                "args": rec.args or {},
            })

    def write_jsonl(self, path_or_file: "str | TextIO") -> None:
        """One JSON record per line — the streaming/grep-friendly form."""
        if hasattr(path_or_file, "write"):
            for line in self.iter_jsonl():
                path_or_file.write(line + "\n")
            return
        with open(path_or_file, "w") as f:
            for line in self.iter_jsonl():
                f.write(line + "\n")


#: Module-level default tracer: disabled until someone opts in.  Library
#: code (engine, executors, session) records against this when not handed
#: an explicit tracer, so enabling observability is one `set_tracer` call.
_default_tracer = Tracer(capacity=1, enabled=False)


def get_tracer() -> Tracer:
    """The process-default tracer (disabled no-op unless installed)."""
    return _default_tracer


def set_tracer(tracer: Tracer | None) -> Tracer:
    """Install ``tracer`` as the process default (None restores the
    disabled no-op default).  Returns the installed tracer."""
    global _default_tracer
    if tracer is None:
        tracer = Tracer(capacity=1, enabled=False)
    _default_tracer = tracer
    return tracer
