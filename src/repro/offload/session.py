"""OffloadSession — one lifecycle for every offload path.

The paper's pipeline is a single flow: analyze the application, discover
offloadable function blocks, search candidate patterns in a verification
environment, verify the winner, deploy it.  Historically this repo exposed
that flow as three unrelated APIs (``OffloadEngine.adapt`` returning an
``AdaptedApp``, ``measure_block_pattern`` returning a bare tuple, and
``launch/plans.py`` hand-rolling plan loading).  ``OffloadSession`` subsumes
all of them behind explicit stages::

    session = OffloadSession(app_fn, args=(x,), objective=PerfPerWatt())
    session.analyze()    # Step 1: source / axis structure
    session.discover()   # Step 2: offloadable blocks -> SearchSpace
    session.plan()       # Step 3: store-first measured search
    session.verify()     # numerics check of the winner
    result = session.commit()   # persist + build the deployable callable

or, in one call, ``result = session.run()``.  Stages must run in order —
calling one before its prerequisite raises ``StageError`` — so "measured
before analyzed" bugs fail loudly instead of silently measuring the wrong
thing.

Three kinds of target are accepted:

* an **application callable** (the paper's existing-app path): Steps 1-2 run
  through an ``OffloadEngine`` and the search space is a ``SubsetSpace`` of
  source-substituted variants;
* a **SearchSpace** (power users, pre-built spaces);
* a **step builder** plus ``patterns=`` or ``blocks=`` (the framework-native
  model-zoo path): the space is a ``BindingSpace`` over registered targets.

Production startup never runs a session at all — ``OffloadSession.attach``
binds a previously committed plan with zero search or measurement.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Mapping, Sequence

from repro.core import blocks as blocks_mod
from repro.core import verify as verify_mod
from repro.core.planner import (
    BindingSpace,
    MeasurementCache,
    Objective,
    Plan,
    Planner,
    PlanReport,
    PlanStore,
    SearchSpace,
    SearchStrategy,
    SingleThenCombine,
    declared_pattern,  # noqa: F401 — re-exported lifecycle helper
    resolve_objective,
)
from repro.core.planner.strategies import to_verification_report


class StageError(RuntimeError):
    """A lifecycle stage was invoked before its prerequisite stage."""


@dataclasses.dataclass
class OffloadResult:
    """The one result type for every offload path.

    Replaces ``AdaptedApp`` (engine path) and the bare ``(best, results)``
    tuples (binding path): the chosen pattern, the per-candidate trials with
    their objective scores, the persisted ``Plan``, and the deployable
    callable.
    """

    plan: Plan
    report: PlanReport | None  # None when the plan came from the store
    mapping: dict[str, str]
    pattern: tuple[str, ...]
    objective: str
    fn: Callable[..., Any] | None
    numerics_ok: bool | None  # None when the verify stage was skipped
    discoveries: list[Any] | None  # engine path only
    skipped: list[Any] | None  # engine path only
    from_store: bool

    @property
    def trials(self) -> list[Any]:
        return [] if self.report is None else self.report.trials

    @property
    def baseline_seconds(self) -> float:
        return self.plan.baseline_seconds

    @property
    def best_seconds(self) -> float:
        return self.plan.best_seconds

    @property
    def speedup(self) -> float:
        return self.plan.speedup

    @property
    def verification(self) -> verify_mod.VerificationReport:
        """Legacy ``VerificationReport`` view (AdaptedApp compatibility)."""
        if self.report is not None:
            return to_verification_report(self.report)
        best = verify_mod.Trial(
            self.plan.pattern, self.plan.best_seconds, self.plan.speedup
        )
        return verify_mod.VerificationReport(
            baseline_seconds=self.plan.baseline_seconds,
            trials=[best],
            best=best,
            search_seconds=0.0,
        )

    def binding_context(self, registry: Any = None):
        """Context manager entering this result's block->target binding."""
        registry = registry or blocks_mod.registry
        return registry.bind(self.mapping)


def stored_binding(
    plan_dir: str,
    key: str,
    match_fingerprint: bool = True,
    registry: Any = None,
) -> dict[str, str] | None:
    """Fetch a committed plan's block->target mapping, or None when no plan
    (or a plan verified under a different environment) is available.

    The mapping is validated against the current block registry: a plan
    naming a block or target that no longer exists (kernel removed or
    renamed since the plan was verified) is treated as incompatible rather
    than binding something that would KeyError mid-trace.
    """
    if registry is None:
        registry = blocks_mod.registry
    plan = PlanStore(plan_dir).load(key, match_fingerprint=match_fingerprint)
    if plan is None:
        return None
    mapping = dict(plan.mapping)
    for block, target in mapping.items():
        if target not in registry.targets(block):
            return None
    return mapping


class OffloadSession:
    """One offload lifecycle: analyze -> discover -> plan -> verify -> commit."""

    def __init__(
        self,
        target: Callable[..., Any] | SearchSpace,
        *,
        args: Sequence[Any] = (),
        objective: Objective | str | None = None,
        strategy: SearchStrategy | None = None,
        store: PlanStore | str | None = None,
        key: str | None = None,
        cache: MeasurementCache | None = None,
        meter: Any = None,
        executor: Any = None,
        engine: Any = None,
        registry: Any = None,
        patterns: Sequence[Mapping[str, str]] | None = None,
        blocks: Mapping[str, Sequence[str]] | None = None,
        repeats: int = 3,
        min_seconds: float = 0.0,
        rtol: float = 1e-3,
        force_search: bool = False,
        legality: bool = False,
        resources: Any = False,
        resource_hints: Mapping[tuple[str, str], Any] | None = None,
        tracer: Any = None,
    ) -> None:
        self.target = target
        #: ``repro.obs.Tracer`` carrying one "stage:<name>" span per
        #: lifecycle stage (defaults to the process tracer, disabled
        #: unless someone turned it on)
        self.tracer = tracer
        self.args = tuple(args)
        self.objective = resolve_objective(objective)
        self.strategy = strategy or SingleThenCombine()
        self.store = PlanStore(store) if isinstance(store, str) else store
        self.key = key
        self._owns_cache = cache is None
        if cache is None:
            cache = MeasurementCache(meter=meter, executor=executor)
        else:
            if meter is not None:
                if cache.meter is not None and cache.meter is not meter:
                    raise ValueError(
                        "the shared MeasurementCache already carries a "
                        "different PowerMeter; wire the meter into the cache "
                        "itself (MeasurementCache(meter=...)) or give this "
                        "session its own cache"
                    )
                cache.meter = meter
            if executor is not None:
                self._set_cache_executor(cache, executor)
        self.cache = cache
        self.registry = registry or blocks_mod.registry
        self.repeats = repeats
        self.min_seconds = min_seconds
        self.rtol = rtol
        self.force_search = force_search
        self.legality = legality
        self.legality_report: Any = None
        #: Memory-envelope pre-filter (paper Step 5): False = off; True /
        #: "host" = probe the live device; a name = STATIC_ENVELOPES entry;
        #: or a DeviceEnvelope.  Statically-OOM bindings are pruned like
        #: illegal ones, with "memory:"-tagged reasons.
        self.resources = resources
        self.resource_hints = resource_hints
        self.resources_report: Any = None
        self._engine = engine
        self._patterns = patterns
        self._blocks = blocks

        if isinstance(target, SearchSpace):
            self.mode = "space"
            self._space: SearchSpace | None = target
        elif patterns is not None or blocks is not None:
            if not callable(target):
                raise TypeError(
                    "binding mode needs a zero-arg step builder as target"
                )
            self.mode = "binding"
            self._space = None
        elif callable(target):
            self.mode = "app"
            self._space = None
        else:
            raise TypeError(
                f"target must be a callable or a SearchSpace, got "
                f"{type(target).__name__}"
            )

        self._done: set[str] = set()
        self._analysis: Any = None
        self._discoveries: list[Any] | None = None
        self._skipped: list[Any] | None = None
        self._plan: Plan | None = None
        self._report: PlanReport | None = None
        self._from_store = False
        self._numerics_ok: bool | None = None
        self._built_fn: Callable[..., Any] | None = None

    def _set_cache_executor(self, cache: MeasurementCache, executor: Any) -> None:
        """Install an executor on a *shared* cache, refusing to silently
        displace a different one another session relies on (mirrors the
        PowerMeter conflict guard above)."""
        from repro.metering.executors import resolve_executor

        executor = resolve_executor(executor)
        current = cache.executor
        # equivalent configuration counts as the same executor: two
        # resolve_executor("serial") calls yield distinct-but-equal
        # instances and must not be treated as a conflict
        same = current is None or current is executor or (
            type(current) is type(executor)
            and current.__dict__ == executor.__dict__
        )
        if not same:
            raise ValueError(
                "the shared MeasurementCache already carries a different "
                "executor; wire the executor into the cache itself "
                "(MeasurementCache(executor=...)) or give this session "
                "its own cache"
            )
        cache.executor = executor

    # -- stage machinery -------------------------------------------------------
    def _stage_span(self, stage: str, **args: Any):
        """Context manager spanning one lifecycle stage on the session's
        tracer (or the process tracer) — no-op when tracing is off."""
        from repro.obs import get_tracer

        tracer = self.tracer if self.tracer is not None else get_tracer()
        if not tracer.enabled:
            return contextlib.nullcontext()
        return tracer.span(f"stage:{stage}", mode=self.mode, **args)

    def _require(self, stage: str, prerequisite: str) -> None:
        if prerequisite not in self._done:
            raise StageError(
                f"OffloadSession.{stage}() called before "
                f"{prerequisite}() — stages run in order "
                "analyze -> discover -> plan -> [verify] -> commit"
            )

    @property
    def space(self) -> SearchSpace:
        if self._space is None:
            raise StageError(
                "search space not built yet — run discover() first"
            )
        return self._space

    # -- Step 1 ----------------------------------------------------------------
    def analyze(self) -> Any:
        """Grasp the target's structure.

        App mode: AST source analysis (library calls, local defs, loops)
        via the engine.  Space/binding modes: the axis structure — every
        searchable position and its registered choices.
        """
        with self._stage_span("analyze"):
            if self.mode == "app":
                self._analysis = self._get_engine().analyze(self.target)
            elif self.mode == "binding":
                space = BindingSpace(
                    self.target,
                    blocks=self._blocks,
                    registry=self.registry,
                ) if self._patterns is None else BindingSpace.from_patterns(
                    self.target, self._patterns, registry=self.registry
                )
                self._space = space
                self._analysis = {a.name: a.choices for a in space.axes}
            else:  # space
                self._analysis = {a.name: a.choices for a in self.space.axes}
            self._done.add("analyze")
        return self._analysis

    def _get_engine(self) -> Any:
        if self._engine is None:
            from repro.core.engine import OffloadEngine

            self._engine = OffloadEngine()
        return self._engine

    # -- Step 2 ----------------------------------------------------------------
    def discover(self) -> list[Any]:
        """Find what can move.

        App mode: DB name matching + similarity discovery, interface
        reconciliation, and construction of the ``SubsetSpace`` of
        source-substituted variants.  Space/binding modes: the axes with
        more than one choice.

        With ``legality=True`` (and a ``BindingSpace``) the
        ``repro.analysis`` legality pass then classifies every (block,
        target) choice and marks the illegal ones on the space, so the
        plan stage's strategy prunes them instead of measuring — the
        paper's static pre-filter, run before any timing is spent.
        """
        self._require("discover", "analyze")
        with self._stage_span("discover"):
            if self.mode == "app":
                prepared = self._get_engine().prepare(
                    self.target, self.args, report=self._analysis
                )
                self._space = prepared.space
                self._discoveries = prepared.discoveries
                self._skipped = prepared.skipped
                found: list[Any] = prepared.discoveries
            else:
                found = [
                    a.name for a in self.space.axes if len(a.choices) > 1
                ]
            if self.legality and isinstance(self._space, BindingSpace):
                from repro.analysis.legality import check_binding_space

                report = check_binding_space(self._space, self.args)
                self._space.mark_illegal(report.illegal)
                self.legality_report = report
            if (
                self.resources is not False
                and self.resources is not None
                and isinstance(self._space, BindingSpace)
            ):
                from repro.analysis.resources import (
                    check_binding_space_resources,
                )

                rreport = check_binding_space_resources(
                    self._space,
                    self.args,
                    envelope=self.resources,
                    hints=self.resource_hints,
                )
                self._space.mark_illegal(rreport.oom)
                self.resources_report = rreport
            self._done.add("discover")
        return found

    # -- Step 3 ----------------------------------------------------------------
    def plan(self, executor: Any = None) -> Plan:
        """Store-first measured search: a compatible stored plan (same
        space signature, same objective) short-cuts to zero measurements,
        otherwise the strategy searches the space and ranks candidates
        with the session objective.

        ``executor`` (a ``repro.metering`` executor instance or name)
        overrides how this search's trials are timed — e.g.
        ``plan(executor=DeviceParallelExecutor())`` measures independent
        candidates concurrently, one per device.

        One plan-lifecycle policy exists — ``Planner.plan`` — and this
        stage delegates to it; persistence is deferred to ``commit``.
        """
        self._require("plan", "discover")
        if executor is not None:
            if self._owns_cache:
                self.cache.executor = executor
            else:
                self._set_cache_executor(self.cache, executor)
        with self._stage_span("plan", key=self.key):
            planner = Planner(
                self.space,
                strategy=self.strategy,
                cache=self.cache,
                store=self.store,
                objective=self.objective,
            )
            self._plan, self._report = planner.plan(
                self.args,
                key=self.key,
                repeats=self.repeats,
                min_seconds=self.min_seconds,
                force_search=self.force_search,
                save=False,  # the commit stage persists
            )
            self._from_store = self._report is None
            self._done.add("plan")
        return self._plan

    # -- verification ----------------------------------------------------------
    def verify(self) -> bool:
        """Functional check: the winning pattern must reproduce the baseline
        results (within ``rtol``) before it may be deployed."""
        self._require("verify", "plan")
        plan = self._plan
        assert plan is not None
        with self._stage_span("verify"):
            if not plan.mapping:  # winner is baseline: trivially faithful
                self._numerics_ok = True
            else:
                best_fn = self._winning_fn()
                if self.mode == "app":
                    reference: Callable[..., Any] = self.target  # type: ignore[assignment]
                else:
                    reference = self.space.build(self.space.baseline())
                self._numerics_ok = verify_mod.verify_numerics(
                    reference, best_fn, self.args,
                    rtol=self.rtol, atol=self.rtol,
                )
            self._done.add("verify")
        return bool(self._numerics_ok)

    def _winning_fn(self) -> Callable[..., Any]:
        """Build the winning variant once; verify and commit share it."""
        if self._built_fn is None:
            assert self._plan is not None
            cand = self.space.candidate_from_mapping(self._plan.mapping)
            self._built_fn = self.space.build(cand)
        return self._built_fn

    # -- deployment ------------------------------------------------------------
    def commit(self, build: bool = True) -> OffloadResult:
        """Persist the plan (when a store+key are configured) and build the
        deployable callable for the winning pattern.

        A plan whose verify stage FAILED numerics is never persisted —
        ``attach`` would otherwise bind a numerically-wrong pattern in
        production with zero re-verification.  ``build=False`` skips
        constructing the callable (measurement-only callers that consume
        just the trials; ``result.fn`` is then None).
        """
        self._require("commit", "plan")
        plan = self._plan
        assert plan is not None
        with self._stage_span("commit", key=self.key):
            if (
                self.store is not None
                and self.key is not None
                and not self._from_store
                and self._numerics_ok is not False
            ):
                self.store.save(plan)
            fn: Callable[..., Any] | None
            if not build:
                fn = None
            elif plan.mapping or self.mode != "app":
                fn = self._winning_fn()
            else:
                fn = self.target  # type: ignore[assignment]
            self._done.add("commit")
        return OffloadResult(
            plan=plan,
            report=self._report,
            mapping=dict(plan.mapping),
            pattern=tuple(plan.pattern),
            objective=plan.objective,
            fn=fn,
            numerics_ok=self._numerics_ok,
            discoveries=self._discoveries,
            skipped=self._skipped,
            from_store=self._from_store,
        )

    def run(self, verify: bool = True, build: bool = True) -> OffloadResult:
        """The whole lifecycle in order.  ``verify=False`` skips the
        numerics stage and ``build=False`` the deployable callable
        (measurement-only callers, e.g. binding sweeps)."""
        self.analyze()
        self.discover()
        self.plan()
        if verify:
            self.verify()
        return self.commit(build=build)

    # -- production attach (zero search) ---------------------------------------
    @classmethod
    def attach(
        cls,
        plan_dir: str | None,
        key: str | None,
        registry: Any = None,
        quiet: bool = False,
    ):
        """Binding context for a previously committed plan: the zero-search
        production path used by ``launch/serve.py`` / ``launch/train.py``.

        A no-op context when unset or when the plan is missing/incompatible
        (default bindings then apply)."""
        def say(msg: str) -> None:
            if not quiet:
                print(msg)

        if not plan_dir or not key:
            if plan_dir or key:
                say(
                    "offload plan ignored: both a plan dir and a plan key "
                    f"are required (got plan_dir={plan_dir!r}, "
                    f"plan_key={key!r})"
                )
            return contextlib.nullcontext()
        mapping = stored_binding(plan_dir, key, registry=registry)
        if mapping is None:
            say(
                f"plan '{key}' not found/compatible in {plan_dir}; "
                "running with default bindings"
            )
            return contextlib.nullcontext()
        say(f"bound offload plan '{key}': {mapping} (no re-measurement)")
        registry = registry or blocks_mod.registry
        return registry.bind(mapping)

    # -- zoo-wide planning ------------------------------------------------------
    @classmethod
    def plan_zoo(cls, *args: Any, **kwargs: Any):
        """Search a BindingSpace over real train/prefill/decode steps for
        every requested (arch, shape) cell and persist a plan per cell.
        See ``repro.offload.zoo.plan_zoo`` for parameters."""
        from repro.offload.zoo import plan_zoo

        return plan_zoo(*args, **kwargs)
