"""Blocked LU vs reconstruction + scipy-style oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import matrix
from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [64, 128, 200, 256])
def test_lu_reconstruction(n, rng):
    a = jnp.asarray(matrix.make_input(n, seed=n), jnp.float32)
    lu, piv = ops.lu(a, backend="xla")
    rec = ref.lu_reconstruct(lu, piv)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(a), atol=5e-5)


@pytest.mark.parametrize("n", [128, 192])
def test_lu_pallas_schur_path(n, rng):
    a = jnp.asarray(matrix.make_input(n, seed=n + 1), jnp.float32)
    lu, piv = ops.lu(a, backend="pallas", interpret=True, nb=64)
    rec = ref.lu_reconstruct(lu, piv)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(a), atol=5e-5)


def test_lu_matches_lapack_factorization(rng):
    # same pivoting convention as getrf => same packed LU on generic input
    a = jnp.asarray(rng.standard_normal((96, 96)), jnp.float32)
    lu_ours, piv_ours = ops.lu(a, backend="xla")
    lu_ref, piv_ref = ref.lu_ref(a)
    np.testing.assert_array_equal(np.asarray(piv_ours), np.asarray(piv_ref))
    np.testing.assert_allclose(
        np.asarray(lu_ours), np.asarray(lu_ref), rtol=2e-4, atol=2e-4
    )


def test_lu_nr_compat_interface(rng):
    a = matrix.make_input(80)
    lu, indx, d = ops.lu_nr_compat(jnp.asarray(a, jnp.float32))
    assert indx.dtype == jnp.int32
    det = float(d) * float(np.prod(np.diag(np.asarray(lu))))
    assert abs(det - np.linalg.det(a)) < 1e-2


def test_lu_identity_padding_never_pivots_into_pad(rng):
    # n=100 pads to 128; factorisation must equal the unpadded one
    a = jnp.asarray(matrix.make_input(100), jnp.float32)
    lu_p, piv_p = ops.lu(a)
    assert int(jnp.max(piv_p)) < 100
    rec = ref.lu_reconstruct(lu_p, piv_p)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(a), atol=5e-5)
