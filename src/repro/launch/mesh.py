"""Production mesh definitions.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16) — the pod axis
carries pure data parallelism (gradient all-reduce crosses the DCI links;
everything bandwidth-hungry stays inside a pod).

Defined as functions, not module constants, so importing this module never
touches jax device state (the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count before first jax use).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests use small ones, e.g. (2,4) on 8 host devices)."""
    return jax.make_mesh(shape, axes)


def mesh_shape_dict(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# TPU v5e hardware model used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link
}
