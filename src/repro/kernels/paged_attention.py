"""Paged decode/extend attention over the block-paged KV pool.

The serving hot loop reads K/V through a page table: slot ``b``'s logical
position ``t`` lives in pool page ``pages[b, t // page_size]`` at row
``t % page_size`` (entries past the allocation point at the shared *null
page*, whose garbage rows the validity mask ``t <= index`` always hides).
This module owns both shelf implementations of that read:

* :func:`paged_attention_xla` — the scatter-then-gather formulation: a
  *rolled* ``fori_loop`` page walk (:func:`gather_kv_pages`) materialises
  a contiguous ``(B, ..., max_pages * page_size, ...)`` view per K/V leaf,
  then dense masked softmax.  Peak live bytes ~= gathered view + one page
  block per leaf (the old advanced-index gather + ``moveaxis`` kept two
  full copies of the view live).
* :func:`paged_attention_pallas` — the fused kernel: a Pallas grid walks
  the page list *inside* the kernel via a scalar-prefetch index map
  (``pages[b, j]`` picks page ``j``'s pool block), accumulating
  flash-style online softmax (running max / sum / weighted accumulator in
  VMEM scratch) across pages.  No gathered view exists at any point — the
  working set is one ``(page_size, head_dim)`` block per operand — which
  is why its ``BLOCK_RESOURCES`` hint carries *no* gather multiplier and
  the resources pass scores the fused decode program strictly below the
  gather path.

Both support decode (S=1) and ``extend`` (S>=1 chunked prefill, causal
within the chunk: row ``s`` of the chunk attends positions
``<= index + s``), GQA head layouts, and — through the
``q_rope``/``kr_pool`` operands — MLA's absorbed decode, which is
structurally GQA with one KV head whose "keys" are the latent cache
``c`` (+ a separate rope channel) and whose "values" are ``c`` itself:

    scores = (q_abs . c  +  q_rope . k_rope) * scale,  out = probs . c

The page-walk loop stays *rolled* (``fori_loop`` on the XLA side, the
grid's page axis on the Pallas side) so the traced program size is
independent of ``max_pages`` — see SNIPPETS.md on loop primitives.

Pool layouts (as produced by ``repro.models.attention.cache_metas_paged``):
GQA ``(P_total, KH, page_size, D)``; MLA latent ``(P_total, page_size, r)``
reshaped by the caller to ``(P_total, 1, page_size, r)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

_NEG = -1e30


# -- page-table plumbing (shared by both targets and the serve engine) ---------


def gather_kv_pages(
    pool: jax.Array, pages: jax.Array, seq_axis: int
) -> jax.Array:
    """Gather a per-slot contiguous K/V view from the page pool.

    ``pool`` (P_total, ..., page_size @ seq_axis, ...), ``pages``
    (B, max_pages) -> (B, ..., max_pages * page_size @ seq_axis, ...).

    The walk is a rolled ``fori_loop`` writing one page block per step
    into a preallocated view — the traced program holds the view plus a
    single ``(B, ..., page_size, ...)`` block, instead of the advanced-
    index gather + ``moveaxis`` pair that kept two full copies of the
    gathered view live.
    """
    b, mp = pages.shape
    ps = pool.shape[seq_axis]
    if mp == 1:  # a single page IS the view; no walk to roll
        return pool[pages[:, 0]]
    out_shape = (
        (b,) + pool.shape[1:seq_axis] + (mp * ps,) + pool.shape[seq_axis + 1 :]
    )

    def walk(j, acc):
        blk = pool[pages[:, j]]  # (B, ..., page_size @ seq_axis, ...)
        return jax.lax.dynamic_update_slice_in_dim(
            acc, blk, j * ps, axis=seq_axis
        )

    return jax.lax.fori_loop(0, mp, walk, jnp.zeros(out_shape, pool.dtype))


def scatter_token_pages(
    pool: jax.Array,
    val: jax.Array,
    pages: jax.Array,
    index: jax.Array,
    seq_axis: int,
) -> jax.Array:
    """Scatter each row's new token into its current page.

    ``val`` is the token slice with the sequence axis squeezed out (GQA
    (B, KH, D), MLA (B, r)); ``index`` (B,) is the logical write position.
    Rows whose table entry is the null page (freed slots, slots still
    prefilling) write into the sacrificial page.
    """
    ps = pool.shape[seq_axis]
    pid = jnp.take_along_axis(
        pages, (index[:, None] // ps).astype(jnp.int32), axis=1, mode="clip"
    )[:, 0]
    off = index % ps
    idx = (pid,) + (slice(None),) * (seq_axis - 1) + (off,)
    return pool.at[idx].set(val.astype(pool.dtype))


def scatter_chunk_pages(
    pool: jax.Array,
    val: jax.Array,
    pages: jax.Array,
    index: jax.Array,
    seq_axis: int,
) -> jax.Array:
    """Scatter an S-token ``extend`` chunk into each row's page list.

    ``val`` keeps the chunk axis at ``seq_axis`` (GQA (B, KH, S, D), MLA
    (B, S, r)); token ``i`` of the chunk lands at logical position
    ``index + i``.  Rolled over the chunk so the traced program is
    independent of S.
    """
    s = val.shape[seq_axis]

    def write(i, acc):
        tok = jax.lax.dynamic_index_in_dim(
            val, i, axis=seq_axis, keepdims=False
        )
        return scatter_token_pages(acc, tok, pages, index + i, seq_axis)

    return jax.lax.fori_loop(0, s, write, pool)


def insert_pages(
    pool: jax.Array, b1: jax.Array, page_ids: jax.Array, seq_axis: int
) -> jax.Array:
    """Scatter a prefilled batch-1 slot cache into the pool as whole pages.

    ``pool`` (L, P_total, ..., page_size, ...), ``b1`` (L, 1, ..., S, ...)
    with ``S == max_pages * page_size``; ``page_ids`` (max_pages,) is the
    slot's page list, null-page entries absorbing the unallocated tail.
    ``seq_axis`` positions are per-layer (batch leading), as from
    ``repro.models.attention.cache_seq_axes``.
    """
    ps = pool.shape[seq_axis + 1]
    x = jnp.squeeze(b1, axis=1)  # (L, ..., S, ...): seq back at seq_axis
    shp = x.shape
    n = shp[seq_axis] // ps
    x = x.reshape(shp[:seq_axis] + (n, ps) + shp[seq_axis + 1 :])
    x = jnp.moveaxis(x, seq_axis, 1)  # (L, max_pages, ..., ps, ...)
    return pool.at[:, page_ids].set(x.astype(pool.dtype))


# -- the XLA target: rolled gather, then dense masked softmax ------------------


def paged_attention_xla(
    q: jax.Array,  # (B, H, S, Dk) — S=1 decode, S>1 extend
    k_pool: jax.Array,  # (P_total, KH, page_size, Dk)
    v_pool: jax.Array,  # (P_total, KH, page_size, Dv)
    pages: jax.Array,  # (B, max_pages) int32 page table
    index: jax.Array,  # (B,) first new-token position per slot
    *,
    q_rope: jax.Array | None = None,  # MLA: (B, H, S, Dr)
    kr_pool: jax.Array | None = None,  # MLA: (P_total, 1, page_size, Dr)
    scale: float | None = None,
) -> jax.Array:
    b, h, s, dk = q.shape
    kh = k_pool.shape[1]
    g = h // kh
    dv = v_pool.shape[-1]
    k_view = gather_kv_pages(k_pool, pages, seq_axis=2)  # (B, KH, T, Dk)
    v_view = gather_kv_pages(v_pool, pages, seq_axis=2)
    smax = k_view.shape[2]
    qpos = index[:, None] + jnp.arange(s)  # (B, S)
    if q_rope is None:
        # division (not multiply-by-reciprocal) to stay bit-identical with
        # the contiguous decode path serving tests compare against
        qg = q.reshape(b, kh, g, s, dk).astype(jnp.float32)
        qg = qg * scale if scale is not None else qg / (dk ** 0.5)
        sc = jnp.einsum("bkgqd,bktd->bkgqt", qg, k_view.astype(jnp.float32))
    else:
        if scale is None:
            scale = 1.0 / (dk ** 0.5)
        qg = q.reshape(b, kh, g, s, dk).astype(jnp.float32)
        qr = q_rope.reshape(b, kh, g, s, -1).astype(jnp.float32)
        kr_view = gather_kv_pages(kr_pool, pages, seq_axis=2)
        sc = (
            jnp.einsum("bkgqd,bktd->bkgqt", qg, k_view.astype(jnp.float32))
            + jnp.einsum(
                "bkgqd,bktd->bkgqt", qr, kr_view.astype(jnp.float32)
            )
        ) * scale
    valid = (
        jnp.arange(smax)[None, None, None, None, :]
        <= qpos[:, None, None, :, None]
    )
    sc = jnp.where(valid, sc, _NEG)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", p, v_view.astype(jnp.float32))
    return o.reshape(b, h, s, dv).astype(q.dtype)


# -- the Pallas target: fused page walk, online softmax ------------------------


def paged_attention_pallas(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    pages: jax.Array,
    index: jax.Array,
    *,
    q_rope: jax.Array | None = None,
    kr_pool: jax.Array | None = None,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused paged attention: grid (B, KH, max_pages), page ``j``'s pool
    block selected by the scalar-prefetched table (``pages[b, j]`` in the
    BlockSpec index map) — the page walk is the grid's innermost axis, so
    the loop stays rolled and no gathered K/V view is ever materialised.
    Running max/sum/accumulator live in VMEM scratch across the walk;
    masked rows (ragged lengths, the final partial page, null pages) drop
    out of both the sum and the accumulator, and pages entirely past a
    slot's newest position skip their compute.
    """
    b, h, s, dk = q.shape
    _, kh, ps, _ = k_pool.shape
    dv = v_pool.shape[-1]
    g = h // kh
    mp = pages.shape[1]
    if scale is None:
        scale = 1.0 / (dk ** 0.5)
    r = g * s  # fused (group, chunk) rows per (b, kh) program
    has_rope = q_rope is not None

    def body(pages_ref, index_ref, q_ref, k_ref, v_ref, qr_ref, kr_ref,
             o_ref, acc_ref, m_ref, l_ref):
        bb = pl.program_id(0)
        j = pl.program_id(2)

        @pl.when(j == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, _NEG)
            l_ref[...] = jnp.zeros_like(l_ref)

        newest = index_ref[bb] + (s - 1)  # last valid position this chunk

        @pl.when(j * ps <= newest)  # pages fully past the slot: skip
        def _accumulate():
            qb = q_ref[0, 0].astype(jnp.float32)  # (R, Dk)
            kb = k_ref[0, 0].astype(jnp.float32)  # (ps, Dk)
            sc = jax.lax.dot_general(
                qb, kb, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (R, ps)
            if has_rope:
                sc = sc + jax.lax.dot_general(
                    qr_ref[0, 0].astype(jnp.float32),
                    kr_ref[0, 0].astype(jnp.float32),
                    (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )
            sc = sc * scale
            # position of pool row vs. the row's own query position:
            # row r = g*S + s_idx queries position index + s_idx (causal
            # within the extend chunk; S=1 decode degenerates to t<=index)
            t = j * ps + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
            qpos = index_ref[bb] + (
                jax.lax.broadcasted_iota(jnp.int32, sc.shape, 0) % s
            )
            valid = t <= qpos
            sc = jnp.where(valid, sc, _NEG)
            m_prev = m_ref[:, :1]  # (R, 1)
            m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1, keepdims=True))
            # explicit re-mask: guards exp(_NEG - m) rounding when a row
            # has seen nothing but masked positions
            p = jnp.where(valid, jnp.exp(sc - m_new), 0.0)
            alpha = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * alpha + jnp.sum(
                p, axis=-1, keepdims=True
            )
            acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
                p, v_ref[0, 0].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

        @pl.when(j == mp - 1)
        def _flush():
            lv = l_ref[:, :1]
            lv = jnp.where(lv == 0.0, 1.0, lv)
            o_ref[0, 0] = (acc_ref[...] / lv).astype(o_ref.dtype)

    # q rows fuse (group, chunk): row r <-> (g_idx = r // S, s_idx = r % S)
    q_rows = q.reshape(b, kh, r, dk)
    page_block = lambda b_, k_, j, pages_, index_: (pages_[b_, j], k_, 0, 0)
    row_block = lambda b_, k_, j, pages_, index_: (b_, k_, 0, 0)
    in_specs = [
        pl.BlockSpec((1, 1, r, dk), row_block),
        pl.BlockSpec((1, 1, ps, dk), page_block),
        pl.BlockSpec((1, 1, ps, dv), page_block),
    ]
    operands = [q_rows, k_pool, v_pool]
    if has_rope:
        dr = q_rope.shape[-1]
        in_specs += [
            pl.BlockSpec((1, 1, r, dr), row_block),
            pl.BlockSpec((1, 1, ps, dr), page_block),
        ]
        operands += [q_rope.reshape(b, kh, r, dr), kr_pool]

        def kernel(pages_ref, index_ref, q_ref, k_ref, v_ref, qr_ref,
                   kr_ref, o_ref, acc_ref, m_ref, l_ref):
            body(pages_ref, index_ref, q_ref, k_ref, v_ref, qr_ref, kr_ref,
                 o_ref, acc_ref, m_ref, l_ref)
    else:

        def kernel(pages_ref, index_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref):
            body(pages_ref, index_ref, q_ref, k_ref, v_ref, None, None,
                 o_ref, acc_ref, m_ref, l_ref)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, kh, mp),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, r, dv), row_block),
            scratch_shapes=[
                pltpu.VMEM((r, dv), jnp.float32),
                pltpu.VMEM((r, 128), jnp.float32),
                pltpu.VMEM((r, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kh, r, dv), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pages.astype(jnp.int32), index.astype(jnp.int32), *operands)
    return out.reshape(b, h, s, dv)
