"""Store-diff reports and search traces — quantifying the paper's trade-off.

The paper ranks offload winners on wall time; the follow-up power work
(arXiv:2110.11520) ranks them on measured draw.  Once both searches have
run (e.g. a ``Latency`` zoo and a ``PerfPerWatt`` zoo committed to two
``PlanStore`` directories), this module diffs them into a per-(arch, kind)
table: winner pattern on each side, speedups, joules (with their
``measured``/``estimated`` provenance marked), and what switching winners
costs in seconds vs saves in joules — the power/performance trade-off as
one table.

  PYTHONPATH=src python -m repro.metering.report \\
      results/plans_latency results/plans_ppw \\
      --label-a latency --label-b perf_per_watt

``search_trace`` reconstructs the paper's Fig. 4 curve (trials measured vs
best-so-far) from a ``PlanReport``'s trials or a ``MeasurementCache``'s
records.  ``--selftest`` builds two tiny stores in-process and diffs them —
the CI smoke path (``make report``).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Any, Iterable, Sequence

from repro.core.planner.objectives import resolve_objective
from repro.core.planner.store import Plan, PlanStore


def parse_zoo_key(key: str) -> tuple[str, str]:
    """(arch, kind) of a ``zoo:<arch>:<kind>`` key; other keys map to the
    whole key as "arch" with kind "-" so non-zoo stores still diff."""
    parts = key.split(":")
    if len(parts) == 3 and parts[0] == "zoo":
        return parts[1], parts[2]
    return key, "-"


@dataclasses.dataclass
class DiffRow:
    """One (arch, kind) cell's winners side by side."""

    key: str
    arch: str
    kind: str
    pattern_a: dict[str, str]
    pattern_b: dict[str, str]
    agree: bool  # both sides picked the same binding
    objective_a: str
    objective_b: str
    speedup_a: float
    speedup_b: float
    seconds_a: float
    seconds_b: float
    joules_a: float | None
    joules_b: float | None
    provenance_a: str | None
    provenance_b: str | None
    # relative cost of deploying B's winner instead of A's:
    # >0 means B's winner is slower / hungrier on that axis
    seconds_delta_pct: float | None
    joules_delta_pct: float | None

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def _pct(b: float | None, a: float | None) -> float | None:
    if a is None or b is None or a <= 0:
        return None
    return (b / a - 1.0) * 100.0


@dataclasses.dataclass
class _PlanCost:
    """Score-able view of a Plan's winner (duck-types a PlanTrial)."""

    seconds: float
    energy_joules: float | None


def plan_score(plan: Plan, objective: Any = None) -> float:
    """Score a stored plan's winner under any objective (defaults to the
    plan's own) — lets a diff compare both winners on one scale."""
    obj = resolve_objective(objective if objective is not None else plan.objective)
    return obj.score(_PlanCost(plan.best_seconds, plan.best_energy_joules))


def diff_stores(
    store_a: PlanStore | str,
    store_b: PlanStore | str,
    keys: Sequence[str] | None = None,
) -> list[DiffRow]:
    """Diff two plan stores key by key (keys present in both sides).

    Fingerprints are deliberately not matched: the whole point is comparing
    plans searched under different configurations (objective, meter), and
    the caller already chose the two stores.
    """
    store_a = PlanStore(store_a) if isinstance(store_a, str) else store_a
    store_b = PlanStore(store_b) if isinstance(store_b, str) else store_b
    if keys is None:
        keys = sorted(set(store_a.keys()) & set(store_b.keys()))
    rows: list[DiffRow] = []
    for key in keys:
        a = store_a.load(key, match_fingerprint=False)
        b = store_b.load(key, match_fingerprint=False)
        if a is None or b is None:
            continue
        arch, kind = parse_zoo_key(key)
        rows.append(
            DiffRow(
                key=key,
                arch=arch,
                kind=kind,
                pattern_a=dict(a.mapping),
                pattern_b=dict(b.mapping),
                agree=dict(a.mapping) == dict(b.mapping),
                objective_a=a.objective,
                objective_b=b.objective,
                speedup_a=a.speedup,
                speedup_b=b.speedup,
                seconds_a=a.best_seconds,
                seconds_b=b.best_seconds,
                joules_a=a.best_energy_joules,
                joules_b=b.best_energy_joules,
                provenance_a=a.best_energy_provenance,
                provenance_b=b.best_energy_provenance,
                seconds_delta_pct=_pct(b.best_seconds, a.best_seconds),
                joules_delta_pct=_pct(b.best_energy_joules, a.best_energy_joules),
            )
        )
    return rows


def _fmt_mapping(mapping: dict[str, str]) -> str:
    if not mapping:
        return "(baseline)"
    return ",".join(f"{k}={v}" for k, v in sorted(mapping.items()))


def _fmt_joules(joules: float | None, provenance: str | None) -> str:
    if joules is None:
        return "-"
    tag = {"measured": "J*", "estimated": "J~"}.get(provenance or "", "J?")
    return f"{joules:.3g}{tag}"


def _fmt_pct(pct: float | None) -> str:
    return "-" if pct is None else f"{pct:+.1f}%"


def render_table(
    rows: Iterable[DiffRow], label_a: str = "A", label_b: str = "B"
) -> str:
    """Fixed-width trade-off table.  Joules provenance is marked on every
    energy cell: ``J*`` measured (hardware counter), ``J~`` estimated
    (modelled / apportioned)."""
    rows = list(rows)
    header = [
        "arch",
        "kind",
        f"winner[{label_a}]",
        f"winner[{label_b}]",
        f"speedup[{label_a}]",
        f"speedup[{label_b}]",
        f"joules[{label_a}]",
        f"joules[{label_b}]",
        "d_seconds",
        "d_joules",
    ]
    body = [
        [
            r.arch,
            r.kind,
            _fmt_mapping(r.pattern_a),
            _fmt_mapping(r.pattern_b) if not r.agree else "(same)",
            f"{r.speedup_a:.2f}x",
            f"{r.speedup_b:.2f}x",
            _fmt_joules(r.joules_a, r.provenance_a),
            _fmt_joules(r.joules_b, r.provenance_b),
            _fmt_pct(r.seconds_delta_pct),
            _fmt_pct(r.joules_delta_pct),
        ]
        for r in rows
    ]
    widths = [
        max(len(header[i]), *(len(row[i]) for row in body)) if body else len(header[i])
        for i in range(len(header))
    ]
    lines = [
        "  ".join(h.ljust(w) for h, w in zip(header, widths)).rstrip(),
        "  ".join("-" * w for w in widths),
    ]
    for row in body:
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip())
    if not body:
        lines.append("(no keys present in both stores)")
    lines.append("")
    lines.append(
        "joules provenance: J* = measured (hardware counter), "
        "J~ = estimated (modelled draw); d_* = B relative to A"
    )
    return "\n".join(lines)


# -- search traces (paper Fig. 4) ---------------------------------------------


@dataclasses.dataclass
class TracePoint:
    trial: int  # 1-based measurement index
    pattern: tuple[str, ...]
    seconds: float
    best_seconds: float  # best-so-far after this trial
    cached: bool = False


def search_trace(source: Any) -> list[TracePoint]:
    """Trials-measured vs best-so-far (the paper's Fig. 4 x/y), from a
    ``PlanReport`` (or its ``trials`` list) or a ``MeasurementCache``.

    Cache records are replayed in measurement order; cached trials (replays)
    are included but never newly measured, so plotting ``cached=False``
    points reproduces the true evaluation curve.
    """
    points: list[TracePoint] = []
    if hasattr(source, "records"):  # MeasurementCache
        # a record's key ends with the space's canonical candidate — a
        # sorted tuple of (axis, choice) pairs; render it as axis=choice
        # labels so the trace identifies what each measurement was
        entries = [
            (
                tuple(
                    f"{axis}={choice}" for axis, choice in rec.key[-1]
                ) if isinstance(rec.key, tuple) and rec.key else (),
                rec.measurement.seconds,
                False,
            )
            for rec in source.records()
        ]
    else:
        trials = getattr(source, "trials", source)
        entries = [
            (tuple(t.pattern), t.seconds, bool(t.cached)) for t in trials
        ]
    best = float("inf")
    for i, (pattern, seconds, cached) in enumerate(entries, start=1):
        best = min(best, seconds)
        points.append(
            TracePoint(
                trial=i,
                pattern=pattern,
                seconds=seconds,
                best_seconds=best,
                cached=cached,
            )
        )
    return points


def render_trace(points: Sequence[TracePoint]) -> str:
    lines = ["trial  seconds      best_so_far  pattern"]
    for p in points:
        tag = " (cached)" if p.cached else ""
        lines.append(
            f"{p.trial:5d}  {p.seconds:11.6f}  {p.best_seconds:11.6f}  "
            f"{','.join(p.pattern) or '(baseline)'}{tag}"
        )
    return "\n".join(lines)


# -- selftest (CI smoke) ------------------------------------------------------


def _selftest_stores(root: str) -> tuple[str, str]:
    """Build a Latency store and a PerfPerWatt store by really searching a
    tiny deterministic space with a candidate-dependent power model, such
    that the two objectives pick different winners."""
    import time

    from repro.core.planner import (
        ExhaustiveSearch,
        MeasurementCache,
        Planner,
        PlanStore,
        SubsetSpace,
    )
    from repro.core.planner.objectives import PowerMeter

    # fast-but-hungry vs slow-but-frugal: the classic trade-off cell
    costs = {
        frozenset(): (0.008, 40.0),
        frozenset({"fft"}): (0.002, 300.0),  # latency winner
        frozenset({"lu"}): (0.004, 60.0),  # perf-per-watt winner
        frozenset({"fft", "lu"}): (0.003, 250.0),
    }

    def build(subset):
        seconds, _watts = costs[frozenset(subset)]

        def fn(_x):
            time.sleep(seconds)
            return _x

        return fn

    class CandidateWatts(PowerMeter):
        """Charges each candidate its modelled board draw."""

        provenance = "measured"  # stands in for a counter in the selftest
        exclusive = False

        def end(self, measurement, space=None, candidate=None):
            subset = space.subset_of(candidate)
            return costs[frozenset(subset)][1] * measurement.seconds

    dirs = (f"{root}/latency", f"{root}/perf_per_watt")
    for objective, plan_dir in zip(("latency", "perf_per_watt"), dirs):
        space = SubsetSpace(build, ["fft", "lu"], tag="selftest")
        planner = Planner(
            space,
            strategy=ExhaustiveSearch(),
            cache=MeasurementCache(meter=CandidateWatts()),
            store=PlanStore(plan_dir),
            objective=objective,
        )
        planner.plan((0,), key="zoo:selftest:app", repeats=1)
    return dirs


def selftest() -> int:
    """End-to-end smoke: search two tiny zoos under different objectives,
    diff the stores, and verify the table is non-empty with provenance
    marked.  Returns a process exit code."""
    import tempfile

    with tempfile.TemporaryDirectory() as root:
        dir_a, dir_b = _selftest_stores(root)
        rows = diff_stores(dir_a, dir_b)
        table = render_table(rows, label_a="latency", label_b="perf_per_watt")
        print(table)
        if not rows:
            print("selftest FAILED: empty diff")
            return 1
        row = rows[0]
        if row.joules_a is None or row.joules_b is None:
            print("selftest FAILED: joules missing from plans")
            return 1
        if row.provenance_a is None or row.provenance_b is None:
            print("selftest FAILED: joules provenance not marked")
            return 1
        if row.agree:
            print("selftest FAILED: objectives should disagree on winner")
            return 1
    print("selftest OK")
    return 0


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff two offload plan stores (power/performance "
        "trade-off per (arch, kind) cell)."
    )
    ap.add_argument("store_a", nargs="?", help="first PlanStore directory")
    ap.add_argument("store_b", nargs="?", help="second PlanStore directory")
    ap.add_argument("--label-a", default="A")
    ap.add_argument("--label-b", default="B")
    ap.add_argument("--json", action="store_true", help="emit rows as JSON")
    ap.add_argument(
        "--fail-empty",
        action="store_true",
        help="exit non-zero when the diff has no rows (CI guard: an empty "
        "table usually means the zoos upstream failed to build)",
    )
    ap.add_argument(
        "--selftest",
        action="store_true",
        help="build two tiny stores in-process and diff them (CI smoke)",
    )
    args = ap.parse_args(argv)

    if args.selftest:
        return selftest()
    if not args.store_a or not args.store_b:
        ap.error("two store directories are required (or --selftest)")
    rows = diff_stores(args.store_a, args.store_b)
    if args.json:
        print(json.dumps([r.to_json() for r in rows], indent=1))
    else:
        print(render_table(rows, label_a=args.label_a, label_b=args.label_b))
    if args.fail_empty and not rows:
        print("error: diff is empty (--fail-empty)", flush=True)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
