"""Fourier-transform application (paper §5.1.1).

Naive CPU port of the *Numerical Recipes in C* 2-D FFT: iterative radix-2
Cooley-Tukey (bit-reversal + Danielson-Lanczos butterflies) applied along
rows then columns — written as loop-heavy "translated C".  The paper's
verification workload is the 2048x2048 2-D FFT sample test.

Offload paths exercised by the engine:
  * A-1/B-1: ``fourier_app_libcall`` calls the library routine ``fft2d_nr``
    whose name is on the pattern-DB external-library list -> replaced by the
    accelerated ``repro.kernels.ops:fft2d`` (the cuFFT analogue).
  * A-2/B-2: ``fourier_app_copied`` contains ``my_fft2d`` — a copied and
    lightly modified clone of the library code (renames + comments), found by
    the Deckard-style similarity detector.
  * loop-GA baseline: ``FFT_STAGES`` / ``build_fft_variant`` split the app
    into 4 loop nests, each offloadable individually (paper refs [32][33]).
"""

from __future__ import annotations

import math

import numpy as np


def _bit_reverse_indices(n: int) -> list[int]:
    bits = n.bit_length() - 1
    out = []
    for i in range(n):
        r = 0
        x = i
        for _ in range(bits):
            r = (r << 1) | (x & 1)
            x >>= 1
        out.append(r)
    return out


def fft1d_nr(row):
    """Radix-2 in-place FFT of one complex vector (Numerical Recipes four1)."""
    n = len(row)
    if n & (n - 1):
        raise ValueError("length must be a power of two")
    data = row.copy()
    # bit-reversal permutation
    j = 0
    for i in range(n):
        if j > i:
            data[i], data[j] = data[j], data[i]
        m = n >> 1
        while m >= 1 and j >= m:
            j -= m
            m >>= 1
        j += m
    # Danielson-Lanczos butterflies
    size = 2
    while size <= n:
        half = size >> 1
        theta = -2.0 * math.pi / size
        wstep = complex(math.cos(theta), math.sin(theta))
        for start in range(0, n, size):
            w = complex(1.0, 0.0)
            for k in range(half):
                u = data[start + k]
                t = w * data[start + k + half]
                data[start + k] = u + t
                data[start + k + half] = u - t
                w *= wstep
        size <<= 1
    return data


def fft2d_nr(x):
    """Naive 2-D FFT: row FFT loop then column FFT loop (the library code)."""
    x = np.asarray(x, dtype=np.complex128)
    n, m = x.shape
    out = x.copy()
    for i in range(n):
        out[i, :] = fft1d_nr(out[i, :])
    for jcol in range(m):
        out[:, jcol] = fft1d_nr(out[:, jcol])
    return out


# The source registered in the Code-Pattern DB for similarity matching (B-2).
# It is the library implementation above, as a literal (the DB stores
# comparison code, not a live object).
REFERENCE_CODE = '''
def fft2d_nr(x):
    x = np.asarray(x, dtype=np.complex128)
    n, m = x.shape
    out = x.copy()
    for i in range(n):
        out[i, :] = fft1d_nr(out[i, :])
    for jcol in range(m):
        out[:, jcol] = fft1d_nr(out[:, jcol])
    return out

def fft1d_nr(row):
    n = len(row)
    if n & (n - 1):
        raise ValueError("length must be a power of two")
    data = row.copy()
    j = 0
    for i in range(n):
        if j > i:
            data[i], data[j] = data[j], data[i]
        m = n >> 1
        while m >= 1 and j >= m:
            j -= m
            m >>= 1
        j += m
    size = 2
    while size <= n:
        half = size >> 1
        theta = -2.0 * math.pi / size
        wstep = complex(math.cos(theta), math.sin(theta))
        for start in range(0, n, size):
            w = complex(1.0, 0.0)
            for k in range(half):
                u = data[start + k]
                t = w * data[start + k + half]
                data[start + k] = u + t
                data[start + k + half] = u - t
                w *= wstep
        size <<= 1
    return data
'''


def fourier_app_libcall(x):
    """The application, library-call flavour: calls fft2d_nr by name."""
    spectrum = fft2d_nr(x)
    return spectrum


# --- copied-code flavour (A-2/B-2 discovery path) ---------------------------


def my_fft1d(vec):
    # local copy of the textbook routine, tweaked while debugging
    npts = len(vec)
    if npts & (npts - 1):
        raise ValueError("length must be a power of two")
    buf = vec.copy()
    jj = 0
    for ii in range(npts):
        # swap into bit-reversed position
        if jj > ii:
            buf[ii], buf[jj] = buf[jj], buf[ii]
        half_n = npts >> 1
        while half_n >= 1 and jj >= half_n:
            jj -= half_n
            half_n >>= 1
        jj += half_n
    span = 2
    while span <= npts:
        half_span = span >> 1
        ang = -2.0 * math.pi / span
        wdelta = complex(math.cos(ang), math.sin(ang))
        for base in range(0, npts, span):
            tw = complex(1.0, 0.0)
            for kk in range(half_span):
                top = buf[base + kk]
                bot = tw * buf[base + kk + half_span]
                buf[base + kk] = top + bot
                buf[base + kk + half_span] = top - bot
                tw *= wdelta
        span <<= 1
    return buf


def my_fft2d(img):
    # copied 2-D transform: rows first, then columns
    img = np.asarray(img, dtype=np.complex128)
    rows, cols = img.shape
    work = img.copy()
    for r in range(rows):
        work[r, :] = my_fft1d(work[r, :])
    for c in range(cols):
        work[:, c] = my_fft1d(work[:, c])
    return work


def fourier_app_copied(x):
    """The application, copied-code flavour: a local clone of the library."""
    return my_fft2d(x)


def unrelated_helper(records):
    """Negative control: independent code that must NOT match the DB."""
    table = {}
    for line in records:
        key, _, value = line.partition("=")
        key = key.strip()
        if not key:
            continue
        table.setdefault(key, []).append(value.strip())
    summary = []
    for key in sorted(table):
        summary.append(f"{key}:{len(table[key])}")
    return ";".join(summary)


# --- staged decomposition for the loop-offload GA baseline -------------------


def _naive_bitrev_rows(x):
    x = np.asarray(x, dtype=np.complex128)
    n, m = x.shape
    idx = _bit_reverse_indices(m)
    out = np.empty_like(x)
    for i in range(n):
        for jcol in range(m):
            out[i, idx[jcol]] = x[i, jcol]
    return out


def _naive_butterfly_rows(x):
    x = np.asarray(x, dtype=np.complex128)
    n, m = x.shape
    out = x.copy()
    for i in range(n):
        row = out[i, :]
        size = 2
        while size <= m:
            half = size >> 1
            theta = -2.0 * math.pi / size
            wstep = complex(math.cos(theta), math.sin(theta))
            for start in range(0, m, size):
                w = complex(1.0, 0.0)
                for k in range(half):
                    u = row[start + k]
                    t = w * row[start + k + half]
                    row[start + k] = u + t
                    row[start + k + half] = u - t
                    w *= wstep
            size <<= 1
        out[i, :] = row
    return out


def _naive_transpose(x):
    x = np.asarray(x)
    n, m = x.shape
    out = np.empty((m, n), dtype=x.dtype)
    for i in range(n):
        for jcol in range(m):
            out[jcol, i] = x[i, jcol]
    return out


def _dev_bitrev_rows(x):
    import jax.numpy as jnp

    m = x.shape[1]
    idx = jnp.asarray(np.argsort(_bit_reverse_indices(m)))
    return x[:, idx]


def _dev_butterfly_rows(x):
    import jax.numpy as jnp

    n, m = x.shape
    size = 2
    while size <= m:
        half = size >> 1
        w = jnp.exp(-2j * jnp.pi * jnp.arange(half) / size).astype(x.dtype)
        xr = x.reshape(n, m // size, 2, half)
        even = xr[:, :, 0, :]
        odd = xr[:, :, 1, :] * w
        x = jnp.concatenate([even + odd, even - odd], axis=-1).reshape(n, m)
        size <<= 1
    return x


def _dev_transpose(x):
    import jax.numpy as jnp

    return jnp.transpose(x)


from repro.apps.common import Stage  # noqa: E402


FFT_STAGES = (
    Stage("row_bitrev", _naive_bitrev_rows, _dev_bitrev_rows),
    Stage("row_butterfly", _naive_butterfly_rows, _dev_butterfly_rows),
    Stage("transpose", _naive_transpose, _dev_transpose),
    Stage("col_bitrev", _naive_bitrev_rows, _dev_bitrev_rows),
    Stage("col_butterfly", _naive_butterfly_rows, _dev_butterfly_rows),
    Stage("transpose_back", _naive_transpose, _dev_transpose),
)


def build_fft_variant(genome):
    """Loop-offload variant of the FFT app selected by a 6-bit genome."""
    from repro.apps.common import build_staged_variant

    return build_staged_variant(FFT_STAGES, genome)


def make_input(n: int = 256, m: int | None = None, seed: int = 0):
    rng = np.random.default_rng(seed)
    m = m or n
    return (rng.standard_normal((n, m)) + 1j * rng.standard_normal((n, m))).astype(
        np.complex128
    )
