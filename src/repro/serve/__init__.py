"""``repro.serve`` — the request-level serving subsystem.

Production surface for a *committed* offload pattern: a
:class:`ServeEngine` accepts :class:`Request` objects via ``submit()``,
schedules them with continuous batching over a slot-managed KV cache, and
emits streaming :class:`Token` events plus a final :class:`Completion` per
request.  Prefill and decode each trace under their own committed
``zoo:<arch>:<phase>`` plan, with per-phase power telemetry and a decode
:class:`~repro.runtime.monitor.StepMonitor`.

Quickstart::

    from repro.serve import Request, Sampler, ServeEngine

    engine = ServeEngine("llama3.2-1b", plan_dir="results/plans",
                         n_slots=4, max_len=256, meter="auto")
    engine.submit(Request(prompt, max_new_tokens=32,
                          sampling=Sampler.with_top_k(40, 0.8)))
    for event in engine.step():      # or engine.run_until_idle()
        ...                          # Token / Completion events

``python -m repro.launch.serve`` is the CLI over this engine and
``benchmarks/serve_load.py`` the Poisson load generator.
"""

from repro.serve.engine import (  # noqa: F401
    EngineStats,
    PhaseTelemetry,
    ServeEngine,
)
from repro.serve.kv import (  # noqa: F401
    PagePool,
    PageTable,
    PoolExhausted,
)
from repro.serve.request import (  # noqa: F401
    Completion,
    Request,
    Token,
)
from repro.serve.sampler import Sampler, sample_tokens  # noqa: F401
from repro.serve.scheduler import Scheduler  # noqa: F401
