"""Planner — store-first search orchestration.

``Planner.plan`` is the one entry point every search path routes through:
check the PlanStore for a previously verified plan (zero measurements on
hit), otherwise run the configured SearchStrategy over the SearchSpace via
the shared MeasurementCache, persist the winner, and return it.
"""

from __future__ import annotations

from typing import Any, Sequence

from repro.core.planner.cache import MeasurementCache
from repro.core.planner.objectives import Objective, resolve_objective
from repro.core.planner.space import SearchSpace
from repro.core.planner.store import Plan, PlanStore, plan_from_report
from repro.core.planner.strategies import (
    PlanReport,
    SearchStrategy,
    SingleThenCombine,
)


def plan_compatible(space: SearchSpace, plan: Plan) -> bool:
    """A stored plan is usable when every chosen (axis, target) still
    exists in the space being planned over."""
    by_name = {a.name: a for a in space.axes}
    for name, label in plan.mapping.items():
        axis = by_name.get(name)
        if axis is None or label not in axis.choices:
            return False
    return True


def declared_pattern(
    environment: str,
    blocks: Sequence[str] | None = None,
    registry: Any = None,
) -> dict[str, str]:
    """Declared-environment binding selection (the dry-run case: no machine
    to measure on, only a target environment declaration).

    environment: "cpu" -> prefer XLA formulations; "tpu" -> prefer the
    Pallas shelf where registered.
    """
    if registry is None:
        from repro.core.blocks import registry as registry_mod

        registry = registry_mod
    pattern: dict[str, str] = {}
    names = blocks if blocks is not None else registry.blocks()
    for b in names:
        targets = registry.targets(b)
        if environment == "tpu" and "pallas" in targets:
            pattern[b] = "pallas"
        elif "xla" in targets:
            pattern[b] = "xla"
        elif targets:
            pattern[b] = targets[0]
    return pattern


class Planner:
    def __init__(
        self,
        space: SearchSpace,
        strategy: SearchStrategy | None = None,
        cache: MeasurementCache | None = None,
        store: PlanStore | None = None,
        objective: "Objective | str | None" = None,
    ) -> None:
        self.space = space
        self.strategy = strategy or SingleThenCombine()
        self.cache = MeasurementCache() if cache is None else cache
        self.store = store
        self.objective = objective

    def _compatible(self, plan: Plan) -> bool:
        return plan_compatible(self.space, plan)

    def plan(
        self,
        args: Sequence[Any],
        key: str | None = None,
        repeats: int = 3,
        min_seconds: float = 0.0,
        force_search: bool = False,
        save: bool = True,
    ) -> tuple[Plan, PlanReport | None]:
        """Return ``(plan, report)``.

        ``report`` is None when the plan came straight from the store —
        the zero-measurement production path.  ``save=False`` defers
        persistence to the caller (the session persists at its commit
        stage, not its plan stage).
        """
        if self.store is not None and key is not None and not force_search:
            cached = self.store.load(key)
            # a stored plan only short-cuts the search when it answers the
            # same question: same space (axes AND workload tag, via the
            # signature) ranked by the same objective — otherwise a
            # latency-selected plan would silently satisfy a PerfPerWatt
            # caller, or a plan searched over one workload would silently
            # satisfy a session planning a different one
            if (
                cached is not None
                and self._compatible(cached)
                and cached.space == self.space.signature()
                and cached.objective == resolve_objective(self.objective).name
            ):
                return cached, None
        report = self.strategy.search(
            self.space,
            args,
            cache=self.cache,
            repeats=repeats,
            min_seconds=min_seconds,
            objective=self.objective,
        )
        plan = plan_from_report(
            key or self.space.signature(), self.space.signature(), report
        )
        # the deployable binding may pin more axes than the offload pattern
        # (BindingSpace: baseline choices are explicit bindings too)
        plan.mapping = dict(self.space.deploy_mapping(report.best.candidate))
        if save and self.store is not None and key is not None:
            self.store.save(plan)
        return plan, report
