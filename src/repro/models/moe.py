"""Mixture-of-Experts FFN: top-k routing with per-row capacity, index-based
dispatch.

Routing is computed per batch row (the GShard "group"): every row routes its
S tokens into an (E, C) index table (C = S*top_k/E*capacity_factor), tokens
beyond capacity are dropped (their index points at the out-of-range sentinel
and the gather/scatter drop it).  Dispatch is a *gather* and combine is a
*scatter-add* — no dense (tokens, E, C) one-hot tensor is ever materialised,
which is what lets arctic-480b's 128-expert layers run at 1M tokens/step
(a dense dispatch would be ~21 TB).

Sharding: rows over "data", experts over "model"; the dispatch gather is
row-local (no cross-device gather); the expert einsum aligns token shards
with expert shards, which GSPMD lowers to the expected all-to-alls.

Supports shared experts (DeepSeek: always-on) and a dense residual FFN in
parallel (Arctic).  Aux loss is the Switch load-balancing loss.
"""

from __future__ import annotations

import math

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import mlp_forward, mlp_metas, tp_out_einsum
from repro.models.params import ParamMeta
from repro.sharding.utils import constrain


def moe_metas(cfg: ArchConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    dt = cfg.param_dtype
    metas = {
        "router": ParamMeta((d, m.n_experts), ("embed", None), dt, scale=0.02),
        "w_gate": ParamMeta(
            (m.n_experts, d, m.d_expert), ("experts", "expert_in", "expert_ffn"), dt
        ),
        "w_up": ParamMeta(
            (m.n_experts, d, m.d_expert), ("experts", "expert_in", "expert_ffn"), dt
        ),
        "w_down": ParamMeta(
            (m.n_experts, m.d_expert, d), ("experts", "expert_ffn", "expert_in"), dt
        ),
    }
    if m.n_shared:
        metas["shared"] = mlp_metas(d, m.d_expert * m.n_shared, dt)
    if m.dense_residual:
        metas["dense"] = mlp_metas(d, cfg.d_ff, dt)
    return metas


def capacity_of(seq: int, m: MoEConfig) -> int:
    c = int(math.ceil(seq * m.top_k / m.n_experts * m.capacity_factor))
    return max(4, ((c + 3) // 4) * 4)


def route_row(gates: jax.Array, top_k: int, capacity: int):
    """Route one row of S tokens.  gates (S, E) f32.

    Returns (idx (E, C) int32 — token id per expert slot, S = empty slot;
             w (E, C) f32 — combine weight per slot;
             frac (E,) — fraction of tokens dispatched per expert).
    """
    s, e = gates.shape
    topv, topi = jax.lax.top_k(gates, top_k)  # (S, k)
    topv = topv / (jnp.sum(topv, axis=-1, keepdims=True) + 1e-9)

    idx_flat = jnp.full((e * capacity + 1,), s, dtype=jnp.int32)
    w_flat = jnp.zeros((e * capacity + 1,), jnp.float32)
    counts = jnp.zeros((e,), jnp.int32)
    token_ids = jnp.arange(s, dtype=jnp.int32)
    for slot in range(top_k):
        eidx = topi[:, slot]  # (S,)
        onehot = jax.nn.one_hot(eidx, e, dtype=jnp.int32)  # (S, E)
        pos = jnp.cumsum(onehot, axis=0) - 1 + counts[None, :]  # (S, E)
        counts = counts + jnp.sum(onehot, axis=0)
        pos_tok = jnp.sum(pos * onehot, axis=-1)  # (S,)
        keep = pos_tok < capacity
        flat = jnp.where(keep, eidx * capacity + pos_tok, e * capacity)
        idx_flat = idx_flat.at[flat].set(token_ids)
        w_flat = w_flat.at[flat].set(topv[:, slot])

    idx = idx_flat[: e * capacity].reshape(e, capacity)
    w = w_flat[: e * capacity].reshape(e, capacity)
    frac = jnp.minimum(counts, capacity).astype(jnp.float32) / max(s, 1)
    return idx, w, frac


def moe_forward(
    p: dict, x: jax.Array, cfg: ArchConfig, compute_dtype
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    xc = x.astype(compute_dtype)
    logits = jnp.einsum(
        "bsd,de->bse", xc.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    gates = jax.nn.softmax(logits, axis=-1)  # (B,S,E)
    cap = capacity_of(s, m)

    idx, w, frac = jax.vmap(lambda g: route_row(g, m.top_k, cap))(gates)
    # idx, w: (B, E, C); row-local token ids (S = empty)

    # dispatch: row-local gather
    def gather_row(xr, ir):  # (S,D), (E,C) -> (E,C,D)
        return jnp.take(xr, ir, axis=0, mode="fill", fill_value=0)

    xin = jax.vmap(gather_row)(xc, idx)  # (B,E,C,D)
    xin = constrain(xin, "act_batch", "experts_act", None, None)

    g = jnp.einsum("becd,edf->becf", xin, p["w_gate"].astype(compute_dtype))
    u = jnp.einsum("becd,edf->becf", xin, p["w_up"].astype(compute_dtype))
    h = jax.nn.silu(g) * u
    h = constrain(h, "act_batch", "experts_act", None, None)
    eo = tp_out_einsum("becf,efd->becd", h,
                       p["w_down"].astype(compute_dtype), compute_dtype)
    eo = eo * w[..., None].astype(compute_dtype)

    # combine: row-local scatter-add (empty slots dropped)
    def scatter_row(er, ir):  # (E,C,D), (E,C) -> (S,D)
        out = jnp.zeros((s, d), er.dtype)
        return out.at[ir.reshape(-1)].add(
            er.reshape(-1, er.shape[-1]), mode="drop"
        )

    out = jax.vmap(scatter_row)(eo, idx)
    out = constrain(out, "act_batch", None, None)

    # Switch aux loss: E * sum_e f_e * mean_gate_e
    mean_gate = jnp.mean(gates, axis=(0, 1))
    aux = m.n_experts * jnp.sum(jnp.mean(frac, axis=0) * mean_gate)

    if m.n_shared:
        out = out + mlp_forward(p["shared"], xc, compute_dtype)
    if m.dense_residual:
        out = out + mlp_forward(p["dense"], xc, compute_dtype)
    out = jax.ad_checkpoint.checkpoint_name(out, "moe_out")
    return out, aux.astype(jnp.float32)
