"""zamba2-7b [hybrid] — Mamba-2 backbone with a shared-parameter attention
block applied every 6th layer (the Zamba2 shared-block design; per-site LoRA
deltas omitted, see DESIGN.md §Arch-applicability).  arXiv:2411.15242."""

from repro.configs.base import ArchConfig, SSMConfig


def _pattern(n_layers: int, period: int = 6) -> str:
    return "".join("s" if i % period == period - 1 else "m" for i in range(n_layers))


CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_head=112,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, head_dim=64, expand=2, chunk=128),
    block_pattern=_pattern(81),
    rope_theta=10000.0,
    subquadratic=True,
)
