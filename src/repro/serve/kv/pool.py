"""Host-side page allocator for the paged KV cache.

The device arrays hold ``n_pages + 1`` pages per cache leaf; this module
owns *which request holds which page*.  All accounting is exact: a page is
either on the free list or held by exactly one slot, ``free`` of a page
that is not held raises, and reuse order is deterministic (LIFO — the most
recently freed page is reallocated first, which keeps traces and tests
reproducible and is friendly to whatever allocator cache sits below).

The extra page at index ``n_pages`` is the **null page**: page-table
entries beyond a slot's allocation point at it, so the decode program's
scatter-writes from freed or still-prefilling batch rows land in a
sacrificial page instead of corrupting a neighbour's KV.  It is never
allocated and never counted.
"""

from __future__ import annotations


class PoolExhausted(RuntimeError):
    """Raised when an allocation asks for more pages than are free."""


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` tokens (ceil division)."""
    if n_tokens < 0:
        raise ValueError(f"negative token count {n_tokens}")
    return -(-n_tokens // page_size)


class PagePool:
    """Exact accounting for ``n_pages`` fixed-size KV pages.

    ``alloc(n)`` pops ``n`` page ids (all-or-nothing: raises
    :class:`PoolExhausted` without side effects when fewer are free),
    ``free(pages)`` returns them.  ``null_page`` is the sacrificial page
    id (``== n_pages``); device cache leaves are sized ``n_pages + 1`` on
    the page axis to hold it.
    """

    def __init__(self, n_pages: int, page_size: int) -> None:
        if n_pages < 1:
            raise ValueError("need at least one page")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.n_pages = n_pages
        self.page_size = page_size
        self.null_page = n_pages
        # LIFO free list; start ordered so page 0 is allocated first
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._held: set[int] = set()
        self.peak_used = 0

    # -- queries ---------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._held)

    @property
    def token_capacity(self) -> int:
        """Total resident-token bound of the pool."""
        return self.n_pages * self.page_size

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    # -- transitions -----------------------------------------------------------
    def alloc(self, n: int) -> list[int]:
        if n < 0:
            raise ValueError(f"negative page count {n}")
        if n > len(self._free):
            raise PoolExhausted(
                f"asked for {n} pages with {len(self._free)} free "
                f"(pool: {self.n_pages} x {self.page_size} tokens)"
            )
        pages = [self._free.pop() for _ in range(n)]
        self._held.update(pages)
        self.peak_used = max(self.peak_used, len(self._held))
        return pages

    def free(self, pages: "list[int]") -> None:
        for page in pages:
            if page not in self._held:
                raise ValueError(
                    f"freeing page {page} that is not held "
                    "(double free or foreign page)"
                )
            self._held.discard(page)
            self._free.append(page)

    def check_leaks(self) -> None:
        """Raise if accounting ever drifted (a test/debug hook)."""
        if len(self._free) + len(self._held) != self.n_pages:
            raise AssertionError(
                f"page accounting drift: {len(self._free)} free + "
                f"{len(self._held)} held != {self.n_pages}"
            )


class PageTable:
    """Per-slot page lists and resident-token lengths over a :class:`PagePool`.

    The table is the indirection the paged decode program reads K/V
    through: :meth:`array` materialises it as the ``(n_slots, max_pages)``
    int32 operand (entries beyond a slot's allocation point at the null
    page), and the engine re-pushes it whenever an admission, append or
    eviction changes it — batch recomposition never retraces.

    ``lengths[slot]`` tracks tokens actually resident (for stranded /
    fragmentation stats); the capacity of a slot is
    ``len(pages[slot]) * page_size``.
    """

    def __init__(
        self,
        n_slots: int,
        max_pages: int,
        pool: PagePool,
        validate: bool = False,
    ) -> None:
        if max_pages < 1:
            raise ValueError("max_pages must be >= 1")
        self.n_slots = n_slots
        self.max_pages = max_pages
        self.pool = pool
        #: run :meth:`check_invariants` after every mutation — the runtime
        #: assertion mode of the ``repro.analysis.paging`` sanitizer
        self.validate = validate
        self._pages: list[list[int]] = [[] for _ in range(n_slots)]
        self.lengths: list[int] = [0] * n_slots
        #: bumped on every page-list mutation — consumers (the engine's
        #: decode operand) cache ``array()`` per version, so steady-state
        #: decode steps don't rebuild or re-upload an unchanged table
        self.version = 0
        self._array_cache: tuple[int, "object"] | None = None

    # -- views -----------------------------------------------------------------
    def array(self):
        """(n_slots, max_pages) int32 page-id operand (null-page filled);
        cached until the next page-list mutation."""
        import numpy as np

        if self._array_cache is not None and (
            self._array_cache[0] == self.version
        ):
            return self._array_cache[1]
        out = np.full(
            (self.n_slots, self.max_pages), self.pool.null_page, np.int32
        )
        for slot, pages in enumerate(self._pages):
            out[slot, : len(pages)] = pages
        out.setflags(write=False)
        self._array_cache = (self.version, out)
        return out

    def slot_pages(self, slot: int) -> "list[int]":
        return list(self._pages[slot])

    def capacity(self, slot: int) -> int:
        """Tokens the slot's allocated pages can hold."""
        return len(self._pages[slot]) * self.pool.page_size

    def pages_needed(self, n_tokens: int) -> int:
        return pages_for(n_tokens, self.pool.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        return self.pool.can_alloc(self.pages_needed(n_tokens))

    # -- transitions -----------------------------------------------------------
    def alloc_slot(self, slot: int, n_tokens: int) -> "list[int]":
        """Give a fresh slot pages for ``n_tokens`` tokens (admission)."""
        if self._pages[slot]:
            raise ValueError(f"slot {slot} already holds pages")
        n = self.pages_needed(n_tokens)
        if n > self.max_pages:
            raise ValueError(
                f"{n_tokens} tokens need {n} pages "
                f"> max_pages {self.max_pages}"
            )
        pages = self.pool.alloc(n)
        self._pages[slot] = pages
        self.lengths[slot] = n_tokens
        self.version += 1
        self._check()
        return pages

    def ensure(self, slot: int, n_tokens: int) -> "list[int]":
        """Append pages until the slot holds capacity for ``n_tokens``;
        returns the newly allocated page ids (may be empty).  Raises
        :class:`PoolExhausted` (no partial allocation) when the pool
        cannot cover the growth — the engine's preemption hook."""
        need = self.pages_needed(n_tokens) - len(self._pages[slot])
        if self.pages_needed(n_tokens) > self.max_pages:
            raise ValueError(
                f"{n_tokens} tokens exceed the slot's max_pages "
                f"({self.max_pages} x {self.pool.page_size})"
            )
        added = self.pool.alloc(max(need, 0))
        if added:
            self._pages[slot].extend(added)
            self.version += 1
        self.lengths[slot] = n_tokens
        self._check()
        return added

    def free_slot(self, slot: int) -> int:
        """Evict: return every page to the pool; returns how many."""
        pages = self._pages[slot]
        n = len(pages)
        self.pool.free(pages)
        self._pages[slot] = []
        self.lengths[slot] = 0
        if n:
            self.version += 1
        self._check()
        return n

    # -- invariants --------------------------------------------------------------
    def _check(self) -> None:
        if self.validate:
            self.check_invariants()

    def check_invariants(self) -> None:
        """Prove the table safe for the paged scatter/gather programs:
        pool accounting exact, held pages exactly the union of slot page
        lists, and the ``repro.analysis.paging`` static checks (no page
        aliasing, no out-of-range ids, page counts cover lengths) clean.
        Raises :class:`repro.analysis.paging.PageAliasError` otherwise —
        the runtime assertion mode behind ``validate=True``."""
        from repro.analysis.paging import PageAliasError, check_page_table

        self.pool.check_leaks()
        held: set[int] = set()
        for slot, pages in enumerate(self._pages):
            for page in pages:
                if page in held:
                    break  # reported precisely by check_page_table below
                held.add(page)
        if held != self.pool._held:
            raise PageAliasError(
                f"table/pool drift: table rows name {sorted(held)} but the "
                f"pool holds {sorted(self.pool._held)}"
            )
        problems = [
            d for d in check_page_table(self)
            if d.severity in ("error", "warning")
        ]
        if problems:
            raise PageAliasError("; ".join(str(d) for d in problems))

    # -- stats -----------------------------------------------------------------
    @property
    def resident_tokens(self) -> int:
        return sum(self.lengths)

    @property
    def allocated_tokens(self) -> int:
        return sum(len(p) for p in self._pages) * self.pool.page_size

    @property
    def stranded_pct(self) -> float:
        """Allocated-but-unused token capacity as a % of allocation —
        with paging only the tail of each slot's *last page* can strand,
        vs the tail of a whole ``max_len`` slot in the contiguous layout."""
        alloc = self.allocated_tokens
        if not alloc:
            return 0.0
        return 100.0 * (alloc - self.resident_tokens) / alloc

    @property
    def partial_pages(self) -> int:
        """Allocated pages that are not completely filled."""
        ps = self.pool.page_size
        return sum(
            1
            for pages, length in zip(self._pages, self.lengths)
            if pages and length % ps
        )

    @property
    def fragmentation_pct(self) -> float:
        """Partially filled pages as a % of allocated pages."""
        used = self.pool.used_pages
        if not used:
            return 0.0
        return 100.0 * self.partial_pages / used

    def stats(self) -> dict:
        pool = self.pool
        return {
            "page_size": pool.page_size,
            "n_pages": pool.n_pages,
            "used_pages": pool.used_pages,
            "free_pages": pool.free_pages,
            "peak_used_pages": pool.peak_used,
            "utilization_pct": 100.0 * pool.used_pages / pool.n_pages,
            "resident_tokens": self.resident_tokens,
            "token_capacity": pool.token_capacity,
            "stranded_pct": self.stranded_pct,
            "partial_pages": self.partial_pages,
            "fragmentation_pct": self.fragmentation_pct,
        }
