"""Call-site substitution (paper §3.4 C-1/C-2, §4.2 implementation step).

The paper's implementation deletes the original library call / copied code
from the C source and writes the replacement invocation in its place, then
compiles (PGI for GPU, Intel HLS for FPGA).  For Python applications the
analogue is an AST rewrite + recompile:

* ``rewrite_calls`` — replaces ``Call`` nodes whose (dotted) target matches a
  mapping key with a call to an injected replacement binding, recompiles the
  module AST and returns the new namespace.  This handles A-1 hits, including
  attribute calls like ``np.fft.fft2`` that cannot be shadowed.
* ``shadow_functions`` — for A-2 hits (a *local* def judged similar to DB
  reference code): rebinds the module-level name to the adapted replacement,
  which is exactly "delete the original definition and use the accelerated
  block instead".

Both return plain callables, so the verification environment can measure
original vs substituted variants side by side.
"""

from __future__ import annotations

import ast
import textwrap
from typing import Any, Callable, Mapping

_REPL_PREFIX = "__repro_offload_"


class _CallRewriter(ast.NodeTransformer):
    def __init__(self, mapping: Mapping[str, str]) -> None:
        # mapping: dotted source call name -> replacement binding name
        self.mapping = dict(mapping)
        self.tails = {k.rsplit(".", 1)[-1]: v for k, v in mapping.items()}
        self.rewritten: list[str] = []

    def visit_Call(self, node: ast.Call) -> ast.AST:
        self.generic_visit(node)
        name = _dotted(node.func)
        if name is None:
            return node
        target = self.mapping.get(name) or self.tails.get(name.rsplit(".", 1)[-1])
        if target is None:
            return node
        self.rewritten.append(name)
        new = ast.Call(
            func=ast.Name(id=target, ctx=ast.Load()),
            args=node.args,
            keywords=node.keywords,
        )
        return ast.copy_location(new, node)


def _dotted(node: ast.AST) -> str | None:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def rewrite_calls(
    source: str,
    replacements: Mapping[str, Callable[..., Any]],
    globalns: dict[str, Any] | None = None,
) -> dict[str, Any]:
    """Rewrite matching call sites in ``source`` and execute the result.

    ``replacements`` maps the *source call name* (as written, or its tail) to
    the adapted replacement callable.  Returns the executed namespace, which
    contains the rewritten functions plus ``__offload_rewritten__`` — the list
    of call names actually replaced.
    """

    source = textwrap.dedent(source)
    tree = ast.parse(source)
    binding_names = {
        name: f"{_REPL_PREFIX}{i}" for i, name in enumerate(replacements)
    }
    rewriter = _CallRewriter(binding_names)
    new_tree = rewriter.visit(tree)
    ast.fix_missing_locations(new_tree)
    code = compile(new_tree, filename="<repro-offload>", mode="exec")
    ns: dict[str, Any] = dict(globalns or {})
    for name, binding in binding_names.items():
        ns[binding] = replacements[name]
    exec(code, ns)
    ns["__offload_rewritten__"] = list(rewriter.rewritten)
    return ns


def shadow_functions(
    namespace: dict[str, Any], replacements: Mapping[str, Callable[..., Any]]
) -> dict[str, Any]:
    """A-2 substitution: rebind local definition names to replacements."""
    ns = dict(namespace)
    for name, fn in replacements.items():
        ns[name] = fn
    return ns


def extract_function(ns: Mapping[str, Any], name: str) -> Callable[..., Any]:
    fn = ns[name]
    if not callable(fn):
        raise TypeError(f"{name} is not callable after substitution")
    return fn
