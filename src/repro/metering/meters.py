"""Counter-backed PowerMeter implementations + autodetection.

The follow-up power-saving work (arXiv:2110.11520) ranks offload winners on
*measured* power draw, not wall time alone.  ``repro.core.planner`` ships
only ``TimeProportionalPower`` (energy = runtime x nominal watts, provenance
``"estimated"``); this module adds meters that read real telemetry:

  NvmlMeter       NVIDIA board draw via pynvml, sampled on a background
                  thread and integrated over the trial window.
  TpuMeter        TPU board draw via libtpu's monitoring SDK, sampled the
                  same way (probed ahead of the CPU meters on TPU hosts).
  RaplMeter       Intel RAPL package energy counters
                  (``/sys/class/powercap/intel-rapl:*/energy_uj``).
  PsutilCpuMeter  CPU utilisation x TDP model via psutil — a last-resort
                  *estimate* for hosts with no energy counter at all.

``autodetect()`` probes them in that order and degrades gracefully to
``TimeProportionalPower``, so ``MeasurementCache(meter=autodetect())`` is
always safe to write.  Every meter declares its ``provenance``
(``"measured"`` vs ``"estimated"``) — stamped on each ``Measurement`` so a
ranking that mixes metered and modelled joules stays auditable — and its
``exclusive`` flag (device-global counters force parallel executors to
serialise metered sections).

All meters report energy *per call*: they integrate average draw over the
begin/end window and charge ``avg_watts x measurement.seconds``, matching
the ``TimeProportionalPower`` contract.
"""

from __future__ import annotations

import contextlib
import dataclasses
import glob
import threading
import time
from typing import Any

from repro.core.planner.objectives import (
    DEFAULT_DEVICE_WATTS,
    PowerMeter,
    TimeProportionalPower,
)


class _SampledPowerMeter(PowerMeter):
    """Shared machinery for meters that *sample* an instantaneous-watts
    counter: ``begin`` starts a daemon thread polling ``_read_now()``
    every ``1/sample_hz`` seconds; ``end`` stops it, integrates the
    samples trapezoidally into average watts over the window, and charges
    ``avg_watts x seconds`` per call."""

    provenance = "measured"
    exclusive = True  # one device counter answers for every concurrent trial

    def __init__(self, sample_hz: float = 50.0) -> None:
        self.sample_hz = max(sample_hz, 1.0)
        self._samples: list[tuple[float, float]] = []
        self._stop: threading.Event | None = None
        self._thread: threading.Thread | None = None

    def _read_now(self) -> float:
        """Instantaneous draw in watts (may raise transiently)."""
        raise NotImplementedError

    def _sample_loop(self, stop: threading.Event) -> None:
        period = 1.0 / self.sample_hz
        while not stop.is_set():
            try:
                watts = self._read_now()
            except Exception:  # noqa: BLE001 — transient driver error
                watts = None
            if watts is not None:
                self._samples.append((time.perf_counter(), watts))
            stop.wait(period)

    def begin(self) -> None:
        # a transient driver error here must degrade this trial's reading
        # to None, not abort a search that may be hours in
        self._samples = []
        with contextlib.suppress(Exception):
            self._samples.append((time.perf_counter(), self._read_now()))
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._sample_loop, args=(self._stop,), daemon=True
        )
        self._thread.start()

    def end(
        self, measurement: Any, space: Any = None, candidate: Any = None
    ) -> float | None:
        if self._stop is None or self._thread is None:
            return None
        self._stop.set()
        self._thread.join(timeout=2.0)
        with contextlib.suppress(Exception):
            self._samples.append((time.perf_counter(), self._read_now()))
        samples = self._samples
        self._stop = self._thread = None
        if len(samples) < 2:
            return None
        joules = 0.0
        for (t0, w0), (t1, w1) in zip(samples, samples[1:]):
            joules += (w0 + w1) / 2.0 * (t1 - t0)
        window = samples[-1][0] - samples[0][0]
        if window <= 0:
            return None
        avg_watts = joules / window
        return avg_watts * measurement.seconds


class NvmlMeter(_SampledPowerMeter):
    """Sampled NVIDIA board draw (``nvmlDeviceGetPowerUsage``, milliwatts)
    integrated over the trial window."""

    def __init__(self, index: int = 0, sample_hz: float = 50.0) -> None:
        import pynvml

        self._nvml = pynvml
        pynvml.nvmlInit()
        self._handle = pynvml.nvmlDeviceGetHandleByIndex(index)
        super().__init__(sample_hz)

    @classmethod
    def available(cls) -> bool:
        try:
            import pynvml

            pynvml.nvmlInit()
            return pynvml.nvmlDeviceGetCount() > 0
        except Exception:  # noqa: BLE001 — no driver / no lib / no device
            return False

    def _read_now(self) -> float:
        return self._nvml.nvmlDeviceGetPowerUsage(self._handle) / 1000.0


class TpuMeter(_SampledPowerMeter):
    """TPU board draw via libtpu's monitoring SDK (ROADMAP open item).

    Probes the ``libtpu.sdk.tpumonitoring`` surface for a power metric
    (the exact metric name varies by libtpu release, so the reader scans
    ``list_supported_metrics()`` for a ``power`` gauge) and samples it on
    the shared background thread.  On hosts without libtpu — like this
    CPU container — ``available()`` is simply False and ``autodetect``
    falls through to the CPU meters; asking for ``"tpu"`` explicitly
    raises, matching every other named meter.  When telemetry is present
    the readings are hardware counters: provenance ``"measured"``,
    slotted ahead of the CPU models in the probe order.
    """

    def __init__(self, sample_hz: float = 10.0) -> None:
        reader = self._power_reader()
        if reader is None:
            raise RuntimeError(
                "no TPU power telemetry (libtpu monitoring) on this host"
            )
        self._reader = reader
        super().__init__(sample_hz)

    @staticmethod
    def _power_reader():
        """A zero-arg watts reader over libtpu monitoring, or None."""
        try:
            from libtpu.sdk import tpumonitoring
        except Exception:  # noqa: BLE001 — no libtpu on this host
            return None
        try:
            names = list(tpumonitoring.list_supported_metrics())
        except Exception:  # noqa: BLE001 — SDK present, service not up
            return None
        for name in names:
            if "power" not in str(name).lower():
                continue

            def read(name=str(name)) -> float:
                data = tpumonitoring.get_metric(name).data()
                if not isinstance(data, (list, tuple)):
                    data = [data]
                return float(sum(float(v) for v in data))

            try:
                read()
            except Exception:  # noqa: BLE001 — metric listed but unreadable
                continue
            return read
        return None

    @classmethod
    def available(cls) -> bool:
        try:
            return cls._power_reader() is not None
        except Exception:  # noqa: BLE001 — defensive: probing must not raise
            return False

    def _read_now(self) -> float:
        return self._reader()


@dataclasses.dataclass
class _RaplDomain:
    path: str  # .../energy_uj
    max_uj: int  # counter wrap point


class RaplMeter(PowerMeter):
    """Intel RAPL package-energy counters under ``/sys/class/powercap``.

    Reads every top-level ``intel-rapl:<n>`` package domain's ``energy_uj``
    at ``begin`` and ``end``, sums the (wrap-corrected) deltas into window
    joules, and charges average watts x per-call seconds.
    """

    provenance = "measured"
    exclusive = True  # package counter, shared by every core

    GLOB = "/sys/class/powercap/intel-rapl:[0-9]*"

    def __init__(self, domains: list[_RaplDomain] | None = None) -> None:
        self._domains = domains if domains is not None else self._discover()
        if not self._domains:
            raise RuntimeError("no readable RAPL package domains")
        self._t0 = 0.0
        self._readings0: list[int] = []

    @classmethod
    def _discover(cls) -> list[_RaplDomain]:
        domains = []
        for d in sorted(glob.glob(cls.GLOB)):
            # top-level packages only: subdomains (core/uncore/dram) are
            # nested as intel-rapl:N:M and would double-count the package
            if d.count(":") != 1:
                continue
            try:
                with open(f"{d}/energy_uj") as f:
                    int(f.read())
                try:
                    with open(f"{d}/max_energy_range_uj") as f:
                        max_uj = int(f.read())
                except OSError:
                    max_uj = 2**62
                domains.append(_RaplDomain(f"{d}/energy_uj", max_uj))
            except (OSError, ValueError):  # unreadable (permissions) / junk
                continue
        return domains

    @classmethod
    def available(cls) -> bool:
        try:
            return bool(cls._discover())
        except Exception:  # noqa: BLE001 — defensive: probing must not raise
            return False

    def _read(self) -> list[int]:
        out = []
        for dom in self._domains:
            with open(dom.path) as f:
                out.append(int(f.read()))
        return out

    def begin(self) -> None:
        self._t0 = time.perf_counter()
        self._readings0 = self._read()

    def end(
        self, measurement: Any, space: Any = None, candidate: Any = None
    ) -> float | None:
        if not self._readings0:
            return None
        window = time.perf_counter() - self._t0
        try:
            readings1 = self._read()
        except OSError:
            return None
        uj = 0
        for dom, r0, r1 in zip(self._domains, self._readings0, readings1):
            delta = r1 - r0
            if delta < 0:  # counter wrapped during the window
                delta += dom.max_uj
            uj += delta
        self._readings0 = []
        if window <= 0:
            return None
        avg_watts = uj / 1e6 / window
        return avg_watts * measurement.seconds


class PsutilCpuMeter(PowerMeter):
    """CPU-utilisation x TDP model (psutil) — an *estimate*, not a counter.

    Utilisation is taken from *this process's* CPU time over the
    begin/end window (``Process.cpu_times``), normalised by core count —
    trials run in-process, so this attributes exactly the trial's own
    compute, and it keeps working in containers whose host-wide
    ``/proc/stat`` is masked (where ``cpu_percent`` reads 0).  Charges
    ``idle_watts + tdp_watts x util`` x per-call seconds.  The idle floor
    keeps sub-tick windows (process CPU time advances in ~10 ms ticks)
    from reading 0 J — a machine never draws nothing.  Last resort before
    the time-proportional fallback: it at least responds to how hard the
    trial drove the CPU.
    """

    provenance = "estimated"
    exclusive = True  # one process-wide window at a time

    def __init__(
        self,
        tdp_watts: float = DEFAULT_DEVICE_WATTS,
        idle_watts: float = 10.0,
    ) -> None:
        import psutil

        if tdp_watts <= 0:
            raise ValueError("tdp_watts must be positive")
        self._process = psutil.Process()
        self._ncpu = psutil.cpu_count() or 1
        self.tdp_watts = tdp_watts
        self.idle_watts = idle_watts
        self._t0 = 0.0
        self._busy0: float | None = None

    @classmethod
    def available(cls) -> bool:
        try:
            import psutil

            psutil.Process().cpu_times()
            return True
        except Exception:  # noqa: BLE001 — no psutil / no proc access
            return False

    def _busy(self) -> float:
        t = self._process.cpu_times()
        return t.user + t.system

    def begin(self) -> None:
        self._t0 = time.perf_counter()
        self._busy0 = self._busy()

    def end(
        self, measurement: Any, space: Any = None, candidate: Any = None
    ) -> float | None:
        if self._busy0 is None:
            return None
        window = time.perf_counter() - self._t0
        busy = self._busy() - self._busy0
        self._busy0 = None
        if window <= 0:
            return None
        util = min(busy / (window * self._ncpu), 1.0)
        watts = self.idle_watts + self.tdp_watts * util
        return watts * measurement.seconds


#: Autodetection order: accelerator counters first (NVML board draw, then
#: libtpu telemetry ahead of the CPU models), CPU counters next, models last.
METER_PROBE_ORDER: tuple[tuple[str, type], ...] = (
    ("nvml", NvmlMeter),
    ("tpu", TpuMeter),
    ("rapl", RaplMeter),
    ("psutil", PsutilCpuMeter),
)


def autodetect(fallback_watts: float = DEFAULT_DEVICE_WATTS) -> PowerMeter:
    """Best available power meter for this host.

    Probes ``nvml -> tpu -> rapl -> psutil`` and degrades gracefully to
    ``TimeProportionalPower(fallback_watts)`` — the returned meter is
    always usable, so callers never need an availability check of their
    own.
    """
    for _name, cls in METER_PROBE_ORDER:
        try:
            if cls.available():
                return cls()
        except Exception:  # noqa: BLE001 — a broken probe must not abort
            continue
    return TimeProportionalPower(watts=fallback_watts)


@dataclasses.dataclass
class WindowTelemetry:
    """What :func:`meter_window` observed: whole-window energy."""

    seconds: float = 0.0
    joules: float | None = None
    watts: float | None = None
    provenance: str | None = None

    def summary(self) -> str:
        if self.joules is None:
            return f"{self.seconds:.2f}s (no power reading)"
        tag = self.provenance or "unknown"
        return (
            f"{self.seconds:.2f}s, {self.joules:.1f} J "
            f"({self.watts:.1f} W avg, {tag})"
        )


@contextlib.contextmanager
def meter_window(meter: PowerMeter | None):
    """Meter an arbitrary code window (production run telemetry).

    Yields a ``WindowTelemetry`` filled in at exit — the launch drivers use
    this to report the joules of a whole serve/train run, with the same
    provenance marking the planner stamps on search trials.  A None meter
    yields an empty telemetry (timing only).
    """
    import time as _time

    from repro.core.verify import Measurement

    tele = WindowTelemetry()
    t0 = _time.perf_counter()
    if meter is not None:
        meter.begin()
    try:
        yield tele
    finally:
        tele.seconds = _time.perf_counter() - t0
        if meter is not None:
            window = Measurement(
                seconds=max(tele.seconds, 1e-9), compile_seconds=0.0, repeats=1
            )
            tele.joules = meter.end(window)
            if tele.joules is not None:
                tele.watts = tele.joules / max(tele.seconds, 1e-9)
                tele.provenance = getattr(meter, "provenance", None)


def resolve_meter(meter: "PowerMeter | str | None") -> PowerMeter | None:
    """Accept a meter instance, a name, or None.

    Names: ``"auto"`` (autodetect), ``"none"`` (no metering),
    ``"time"``/``"time-proportional"``, ``"nvml"``, ``"rapl"``,
    ``"psutil"``.  Asking for a specific unavailable meter raises rather
    than silently substituting — explicit requests should fail loudly.
    """
    if meter is None:
        return None
    if not isinstance(meter, str):
        return meter
    name = meter.lower()
    if name == "none":
        return None
    if name == "auto":
        return autodetect()
    if name in ("time", "time-proportional", "time_proportional"):
        return TimeProportionalPower()
    for probe_name, cls in METER_PROBE_ORDER:
        if name == probe_name:
            if not cls.available():
                raise RuntimeError(
                    f"power meter '{name}' is not available on this host"
                )
            return cls()
    known = ["auto", "none", "time"] + [n for n, _ in METER_PROBE_ORDER]
    raise KeyError(f"unknown power meter '{meter}'; known: {known}")
