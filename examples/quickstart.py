"""Quickstart: the paper's pipeline in 60 seconds, as one OffloadSession.

1. Take a CPU application (naive Numerical-Recipes 2-D FFT).
2. Run the lifecycle stage by stage: analyze the source, discover the
   offloadable function block via the Code-Pattern DB, search offload
   patterns by measurement, verify numerics, commit the winner.
3. Compare with the prior-work GA loop offloader (paper Fig. 4/5).

  PYTHONPATH=src python examples/quickstart.py [--fast]
"""

import argparse
import sys
import warnings

warnings.filterwarnings("ignore")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller input")
    args = ap.parse_args()
    n = 64 if args.fast else 192

    from repro.apps import fourier
    from repro.core import run_ga
    from repro.offload import OffloadSession

    x = fourier.make_input(n)

    print(f"=== function-block offload (the paper) — {n}x{n} 2-D FFT ===")
    session = OffloadSession(fourier.fourier_app_libcall, args=(x,), repeats=1)
    session.analyze()
    for d in session.discover():
        print(f"  discovered: {d.source_name} -> {d.entry.name} "
              f"({d.kind}, target {d.entry.target})")
    session.plan()
    session.verify()
    res = session.commit()
    for t in res.trials:
        print(f"  trial {t.pattern or '(baseline)'}: {t.seconds*1e3:.1f} ms "
              f"({t.speedup:.1f}x)")
    print(f"  best offload pattern: {res.pattern} "
          f"speedup {res.speedup:.1f}x, "
          f"numerics verified: {res.numerics_ok}, "
          f"search took {res.report.search_seconds:.1f}s")

    print("=== prior-work loop offload (GA) on the same app ===")
    ga = run_ga(
        fourier.build_fft_variant, n_genes=len(fourier.FFT_STAGES),
        args=(x,), population=6, generations=3 if args.fast else 5,
        repeats=1, seed=0,
    )
    print(f"  GA best genome {ga.best_genome}: {ga.best_speedup:.1f}x "
          f"after {ga.evaluations} measured trials "
          f"({ga.search_seconds:.1f}s search)")

    ratio = ga.best_seconds / res.best_seconds
    print(f"=== function-block offload is {ratio:.1f}x faster than the best "
          f"loop-offload pattern (paper Fig. 5, in kind) ===")


if __name__ == "__main__":
    sys.exit(main())
