"""Paged KV-cache tests: allocator accounting, engine parity, over-commit.

The allocator tests are property-style round-trips on the host-side
accounting (no jax involved); the engine tests pin the acceptance
criteria — paged serving is token-for-token identical to contiguous
serving under greedy sampling, admits request mixes the contiguous layout
cannot hold resident, reclaims pages on eviction mid-decode, and resumes
preempted requests token-identically.
"""

import dataclasses

import pytest

from repro.configs import get_config
from repro.serve import PagePool, PageTable, PoolExhausted, Request, ServeEngine
from repro.serve.kv import pages_for

CFG = get_config("llama3.2-1b").reduced()
# parity tests compare token sequences across different programs: f32
# keeps argmax ties deterministic across program shapes
F32 = dataclasses.replace(CFG, compute_dtype="float32", remat="none")


def _prompt(rng, n):
    return rng.integers(0, CFG.vocab_size, n).tolist()


# -- allocator accounting ------------------------------------------------------


def test_page_pool_alloc_free_roundtrip():
    pool = PagePool(n_pages=8, page_size=16)
    assert pool.free_pages == 8 and pool.used_pages == 0
    assert pool.null_page == 8
    a = pool.alloc(3)
    b = pool.alloc(2)
    assert sorted(a + b) == [0, 1, 2, 3, 4]  # ordered first allocation
    assert pool.free_pages == 3 and pool.used_pages == 5
    pool.free(a)
    # deterministic LIFO reuse: the pages just freed come back first,
    # last-freed first
    c = pool.alloc(3)
    assert c == a[::-1]
    pool.free(b + c)
    pool.check_leaks()
    assert pool.free_pages == 8 and pool.used_pages == 0
    assert pool.peak_used == 5


def test_page_pool_exhaustion_and_double_free():
    pool = PagePool(n_pages=4, page_size=8)
    held = pool.alloc(4)
    with pytest.raises(PoolExhausted):
        pool.alloc(1)
    assert pool.used_pages == 4  # failed alloc has no side effects
    pool.free(held[:1])
    with pytest.raises(ValueError, match="double free|not held"):
        pool.free(held[:1])  # double free
    with pytest.raises(ValueError, match="not held"):
        pool.free([pool.null_page])  # the null page is never allocatable
    pool.free(held[1:])
    pool.check_leaks()


def test_page_table_slot_lifecycle_and_stats():
    table = PageTable(n_slots=3, max_pages=4, pool=PagePool(12, 8))
    assert pages_for(17, 8) == 3
    table.alloc_slot(0, 17)  # 3 pages, 17 resident
    table.alloc_slot(2, 8)  # exactly one full page
    with pytest.raises(ValueError, match="already holds"):
        table.alloc_slot(0, 1)
    arr = table.array()
    assert arr.shape == (3, 4)
    assert list(arr[1]) == [table.pool.null_page] * 4  # empty slot -> null
    assert (arr[0, :3] != table.pool.null_page).all()
    assert arr[0, 3] == table.pool.null_page
    # append across a boundary: ensure() grows only when capacity runs out
    assert table.ensure(0, 24) == []  # 3 pages already hold 24
    grown = table.ensure(0, 25)
    assert len(grown) == 1 and table.capacity(0) == 32
    with pytest.raises(ValueError, match="max_pages"):
        table.ensure(0, 40)  # beyond the slot's table row
    # stats: slot0 holds 25/32, slot2 holds 8/8
    assert table.resident_tokens == 33
    assert table.partial_pages == 1  # only slot0's last page is partial
    assert 0 < table.stranded_pct < 100
    stats = table.stats()
    assert stats["used_pages"] == 5
    assert stats["utilization_pct"] == pytest.approx(5 / 12 * 100)
    # eviction returns everything; no leaked pages, table row nulls out
    table.free_slot(0)
    table.free_slot(2)
    table.pool.check_leaks()
    assert table.pool.used_pages == 0
    assert (table.array() == table.pool.null_page).all()


# -- engine parity (acceptance criteria) ---------------------------------------


def _run_trace(engine, prompts, gens, max_steps=800):
    ids = [
        engine.submit(Request(p, max_new_tokens=g))
        for p, g in zip(prompts, gens)
    ]
    engine.run_until_idle(max_steps=max_steps)
    return [engine.completions[i].tokens for i in ids]


def test_paged_matches_contiguous_greedy_staggered(rng):
    """The acceptance bar: a staggered 4-request greedy trace is
    token-for-token identical between the contiguous and paged engines —
    across page boundaries, slot reuse and mixed lengths — and the
    degenerate page_size=max_len case matches too."""
    prompts = [_prompt(rng, n) for n in (5, 9, 4, 7)]
    gens = (6, 3, 8, 2)
    expected = _run_trace(
        ServeEngine(F32, n_slots=2, max_len=64, seed=0), prompts, gens
    )
    paged = ServeEngine(F32, n_slots=2, max_len=64, seed=0, page_size=8)
    assert _run_trace(paged, prompts, gens) == expected
    assert paged.kv.pool.used_pages == 0  # everything reclaimed at idle
    paged.kv.pool.check_leaks()
    degenerate = ServeEngine(
        F32, n_slots=2, max_len=64, seed=0, page_size=64
    )
    assert _run_trace(degenerate, prompts, gens) == expected
    assert degenerate.kv.max_pages == 1  # one page per slot == contiguous


def test_paged_admits_mix_contiguous_capacity_defers(rng):
    """Capacity decoupling: with the same token memory (256), the paged
    engine keeps 8 short requests resident at once where the contiguous
    layout only fits 4 slots of max_len=64."""
    prompts = [_prompt(rng, 20) for _ in range(8)]
    gens = [8] * 8
    # contiguous: 256 tokens of memory = 4 slots -> concurrency capped at 4
    cont = ServeEngine(F32, n_slots=4, max_len=64, seed=0)
    _run_trace(cont, prompts, gens)
    assert cont.stats.max_active == 4
    # paged: same 256 tokens = 16 pages shared by 8 slots; each request
    # needs <= 28 tokens = 2 pages, so all 8 fit resident simultaneously
    paged = ServeEngine(
        F32, n_slots=8, max_len=64, seed=0, page_size=16, n_pages=16
    )
    _run_trace(paged, prompts, gens)
    assert paged.stats.max_active == 8
    assert paged.stats.preemptions == 0  # it genuinely fit, no thrashing
    assert paged.kv.pool.peak_used <= 16


def test_eviction_mid_decode_reclaims_pages(rng):
    """Finished requests return their pages while neighbours keep
    decoding: peak pool usage stays well under the sum of all requests'
    worst cases, and the pool drains to zero at idle."""
    engine = ServeEngine(F32, n_slots=2, max_len=64, seed=0, page_size=8)
    seen_used = []
    ids = [
        engine.submit(Request(_prompt(rng, p), max_new_tokens=g))
        for p, g in [(5, 12), (9, 2), (6, 9), (12, 3), (4, 6)]
    ]
    while engine.scheduler.has_work:
        engine.step()
        seen_used.append(engine.kv.pool.used_pages)
    assert len(engine.completions) == len(ids)
    # mid-flight the pool was in use, at idle everything was reclaimed
    assert max(seen_used) >= 2
    assert engine.kv.pool.used_pages == 0
    engine.kv.pool.check_leaks()
    # 5 requests churned through 2 slots: eviction freed pages mid-run,
    # otherwise the pool (16 pages) could not have served sum(worst cases)
    assert engine.stats.slot_reuses >= 3


def test_preemption_resumes_token_identically(rng):
    """An over-committed pool forces preemption mid-decode; the preempted
    request re-prefills (prompt + generated tokens) and continues with
    the exact token sequence of an unpressured run."""
    prompts = [_prompt(rng, 20) for _ in range(3)]
    gens = [12] * 3
    relaxed = ServeEngine(F32, n_slots=3, max_len=64, seed=0, page_size=8)
    expected = _run_trace(relaxed, prompts, gens)
    assert relaxed.stats.preemptions == 0
    # 6 pages = 48 tokens for 3 requests needing 32 each at the end
    tight = ServeEngine(
        F32, n_slots=3, max_len=64, seed=0, page_size=8, n_pages=6
    )
    got = _run_trace(tight, prompts, gens, max_steps=2000)
    assert tight.stats.preemptions > 0
    assert got == expected
    tight.kv.pool.check_leaks()
    assert tight.kv.pool.used_pages == 0


def test_submit_rejects_request_larger_than_pool(rng):
    engine = ServeEngine(
        CFG, n_slots=2, max_len=64, seed=0, page_size=8, n_pages=4
    )
    with pytest.raises(ValueError, match="never be resident"):
        engine.submit(Request(_prompt(rng, 30), max_new_tokens=10))
    # a request that fits the pool is accepted
    engine.submit(Request(_prompt(rng, 20), max_new_tokens=10))
    assert len(engine.run_until_idle(max_steps=100)) == 1


# -- chunked prefill -----------------------------------------------------------


def test_chunked_prefill_parity_and_interleaving(rng):
    """A long prompt split into chunks produces the identical greedy
    tokens, runs multiple prefill program calls, and — the TTFT point —
    an in-flight short request keeps decoding between the chunks."""
    long_prompt = _prompt(rng, 40)
    short_prompt = _prompt(rng, 4)
    base = ServeEngine(F32, n_slots=2, max_len=64, seed=0)
    expected = _run_trace(base, [long_prompt], [6])

    for kw in ({}, {"page_size": 8}):
        engine = ServeEngine(
            F32, n_slots=2, max_len=64, seed=0, prefill_chunk=8,
            max_tokens_per_step=10, **kw
        )
        short_id = engine.submit(Request(short_prompt, max_new_tokens=20))
        engine.step()  # short request admitted and decoding
        long_id = engine.submit(Request(long_prompt, max_new_tokens=6))
        decode_during_chunks = 0
        while long_id not in engine.completions:
            events = engine.step()
            if engine.scheduler.active and any(
                t.request_id == short_id
                for t in events
                if hasattr(t, "phase") and t.phase == "decode"
            ) and long_id not in engine.completions and (
                len(engine._prefilling) > 0
            ):
                decode_during_chunks += 1
        engine.run_until_idle(max_steps=500)
        assert engine.completions[long_id].tokens == expected[0]
        assert engine.stats.prefill_chunks >= 5  # 40 tokens / 8 per chunk
        # the short request decoded while the long prompt was mid-prefill
        assert decode_during_chunks > 0


def test_chunked_prefill_partial_tail_at_cache_end(rng):
    """A prompt whose padded final chunk would overrun max_len: the tail
    chunk must run at its exact width — a chunk-padded write at the cache
    end clamps backward and corrupts already-written prompt K/V."""
    prompt = _prompt(rng, 63)  # 63 = 6*10 + 3: partial tail at row 60/64
    base = ServeEngine(F32, n_slots=1, max_len=64, seed=0)
    expected = _run_trace(base, [prompt], [1])
    for kw in ({}, {"page_size": 8}):
        engine = ServeEngine(
            F32, n_slots=1, max_len=64, seed=0, prefill_chunk=10, **kw
        )
        assert _run_trace(engine, [prompt], [1]) == expected


def test_chunked_prefill_rejected_for_ssm():
    with pytest.raises(ValueError, match="SSM"):
        ServeEngine(get_config("mamba2-2.7b").reduced(), prefill_chunk=8)


# -- metrics -------------------------------------------------------------------


def test_metrics_report_pool_health(rng):
    paged = ServeEngine(CFG, n_slots=2, max_len=64, seed=0, page_size=8)
    paged.submit(Request(_prompt(rng, 9), max_new_tokens=4))
    paged.submit(Request(_prompt(rng, 5), max_new_tokens=6))
    paged.run_until_idle(max_steps=100)
    m = paged.metrics()
    assert m["mode"] == "paged"
    kv = m["kv"]
    assert kv["n_pages"] == 16 and kv["page_size"] == 8
    assert kv["peak_used_pages"] >= 2
    assert kv["used_pages"] == 0  # idle again
    assert 0 < m["mean_utilization_pct"] <= 100
    assert 0 <= m["mean_stranded_pct"] < 100
    assert 0 <= m["mean_fragmentation_pct"] <= 100

    cont = ServeEngine(CFG, n_slots=2, max_len=64, seed=0)
    cont.submit(Request(_prompt(rng, 9), max_new_tokens=4))
    cont.run_until_idle(max_steps=100)
    m = cont.metrics()
    assert m["mode"] == "contiguous"
    assert m["kv"]["token_capacity"] == 128
    # the contiguous layout strands most of the slot on short requests —
    # the number the page pool exists to reclaim
    assert m["mean_stranded_pct"] > 50


def test_abstract_cache_lowers_paged_decode_program():
    """The dry-run contract: the paged abstract cache (pool leaves + the
    pages operand) must lower the exact decode program the engine runs."""
    import jax

    from repro.configs.base import ShapeConfig
    from repro.launch import steps
    from repro.models import lm
    from repro.models import params as pm

    shape = ShapeConfig("decode_paged", 64, 4, "decode")
    cache = steps.abstract_cache(CFG, shape, page_size=16, n_pages=16)
    assert cache["pages"].shape == (4, 4)  # (n_slots, max_pages)
    params = pm.abstract_params(lm.build_metas(CFG))
    out = jax.eval_shape(
        steps.make_decode_step(CFG), params, cache,
        steps.input_specs(CFG, shape),
    )
    assert out[0].shape[0] == 4  # (B, V) logits
    assert out[1]["index"].shape == (4,)


# -- the scalar-index fallback is gone -----------------------------------------


def test_scalar_index_cache_rejected():
    import jax.numpy as jnp

    from repro.models import lm

    params = lm.init_params(CFG, 0)
    cache = lm.init_cache(CFG, 2, 16)
    cache["index"] = jnp.asarray(3, jnp.int32)  # legacy scalar position
    with pytest.raises(ValueError, match="per-slot"):
        lm.decode_step(
            params, jnp.zeros((2, 1), jnp.int32), CFG, cache
        )
