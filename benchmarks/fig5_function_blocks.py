"""Paper Fig. 5: performance improvement of loop offloading vs function-block
offloading, for the Fourier-transform and matrix-calculation applications.

Three variants per app, as in the paper:
  cpu     — all-CPU naive code (Numerical Recipes port, interpreted loops)
  loop    — best loop-offload pattern found by the prior-work GA [33]
  block   — function-block offload (this paper): pattern-DB substitution of
            the whole block with the accelerated library implementation

The paper measures 2048^2 inputs against C code; interpreted-Python naive
loops make that size infeasible for the *baseline* here, so the default
measures at --n (256 fft / 192 lu) where all three variants are measurable,
and additionally times the offloaded block at 2048^2 (block_full_2048) so
the absolute capability is on record.  Speedup ratios are size-matched.
"""

from __future__ import annotations

import argparse
import warnings

import numpy as np

from benchmarks.common import emit, time_call


def run(n_fft: int = 256,  # must be a power of two (radix-2 FFT)
         n_lu: int = 192, repeats: int = 2,
        full: bool = False) -> dict:
    warnings.filterwarnings("ignore")
    import jax.numpy as jnp

    from repro.apps import fourier, matrix
    from repro.core import planner
    from repro.offload import OffloadSession

    def loop_ga(build_variant, n_genes, args, population, generations, seed=0):
        """Prior-work loop-offload GA via the planner (binary genome)."""
        space = planner.SubsetSpace.from_genome_builder(build_variant, n_genes)
        return planner.GeneticSearch(
            population=population, generations=generations, seed=seed
        ).search(space, args, cache=planner.MeasurementCache(), repeats=1)

    def block_offload(app_fn, args):
        """Function-block offload (this paper) as one session lifecycle."""
        return OffloadSession(app_fn, args=args, repeats=repeats).run()

    out: dict = {}

    # ---- Fourier transform application --------------------------------
    x = fourier.make_input(n_fft)
    t_cpu = time_call(fourier.fourier_app_libcall, (x,), repeats=repeats)
    emit(f"fig5.fft.cpu.n{n_fft}", t_cpu, "naive NR loops")

    ga = loop_ga(
        fourier.build_fft_variant, len(fourier.FFT_STAGES), (x,),
        population=6, generations=4,
    )
    t_loop = ga.best.seconds
    emit(f"fig5.fft.loop.n{n_fft}", t_loop,
         f"GA best genome={''.join(map(str, ga.best.candidate))} "
         f"speedup={t_cpu/t_loop:.1f}x search={ga.search_seconds:.1f}s")

    res = block_offload(fourier.fourier_app_libcall, (x,))
    t_block = res.best_seconds
    emit(f"fig5.fft.block.n{n_fft}", t_block,
         f"pattern={res.pattern} speedup={t_cpu/t_block:.1f}x "
         f"search={res.report.search_seconds:.1f}s "
         f"numerics_ok={res.numerics_ok}")
    out["fft"] = dict(cpu=t_cpu, loop=t_loop, block=t_block,
                      loop_speedup=t_cpu / t_loop, block_speedup=t_cpu / t_block,
                      ga_search_s=ga.search_seconds,
                      block_search_s=res.report.search_seconds)

    # ---- matrix-calculation (LU) application ---------------------------
    a = matrix.make_input(n_lu)
    t_cpu = time_call(matrix.matrix_app_libcall, (a,), repeats=repeats)
    emit(f"fig5.lu.cpu.n{n_lu}", t_cpu, "naive NR ludcmp")

    ga = loop_ga(
        matrix.build_lu_variant, len(matrix.LU_STAGES), (a,),
        population=5, generations=3,
    )
    t_loop = ga.best.seconds
    emit(f"fig5.lu.loop.n{n_lu}", t_loop,
         f"GA best genome={''.join(map(str, ga.best.candidate))} "
         f"speedup={t_cpu/t_loop:.1f}x search={ga.search_seconds:.1f}s")

    res = block_offload(matrix.matrix_app_libcall, (a,))
    t_block = res.best_seconds
    emit(f"fig5.lu.block.n{n_lu}", t_block,
         f"pattern={res.pattern} speedup={t_cpu/t_block:.1f}x "
         f"numerics_ok={res.numerics_ok}")
    out["lu"] = dict(cpu=t_cpu, loop=t_loop, block=t_block,
                     loop_speedup=t_cpu / t_loop, block_speedup=t_cpu / t_block)

    # ---- paper-scale block timings (2048^2) -----------------------------
    if full:
        from repro.kernels import ops

        x_full = fourier.make_input(2048).astype(np.complex64)
        t = time_call(
            lambda z: ops.fft2d(jnp.asarray(z), backend="xla"), (x_full,),
            repeats=repeats,
        )
        emit("fig5.fft.block_full_2048", t, "offloaded fft2d at paper scale")
        a_full = matrix.make_input(2048).astype(np.float32)
        t = time_call(
            lambda z: ops.lu_nr_compat(jnp.asarray(z)), (a_full,),
            repeats=max(repeats, 1),
        )
        emit("fig5.lu.block_full_2048", t, "offloaded blocked LU at paper scale")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-fft", type=int, default=256)
    ap.add_argument("--n-lu", type=int, default=192)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(args.n_fft, args.n_lu, args.repeats, args.full)


if __name__ == "__main__":
    main()
