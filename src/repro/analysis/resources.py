"""Static memory/resource-envelope verifier and capacity planner.

The paper's FPGA flow rejects an offload pattern whose HLS resource
estimate exceeds the board *before* spending any measurement (Step 5).
This module is the GPU/TPU analogue over traced JAX programs:

* :func:`estimate_memory` — peak-live-bytes of a traced program via a
  jaxpr liveness walk: operands + captured consts + the peak of the
  intermediate live set (recursing into pjit/scan/while bodies), with
  donated-buffer credit.  Pure trace inspection, no compilation.
* :func:`check_binding_space_resources` — per-``BindingSpace``-candidate
  verdicts against a :class:`~repro.analysis.devices.DeviceEnvelope`;
  the OOM subset feeds ``BindingSpace.mark_illegal`` so all search
  strategies prune statically-OOM candidates exactly like legality
  prunes illegal ones.
* :func:`plan_serve_capacity` — static serve-engine sizing from
  ``ParamMeta`` trees (no materialisation, so full-size configs plan in
  milliseconds): params + KV bytes, max slots / pages that fit, a
  prefill-chunk width bound, cross-checked against ``PagePool`` math.
* :func:`lint_shelf_coverage` — every shelf implementation must declare
  both a ``BLOCK_LEGALITY`` envelope and a ``BLOCK_RESOURCES`` hint.

Estimates are deliberately *upper* bounds: XLA fuses intermediates away,
so a program this pass admits may use less memory at runtime, but one it
rejects cannot plausibly fit.  That asymmetry is what makes pruning safe.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping

import jax
import jax.extend.core as jex_core

from repro.analysis.devices import DeviceEnvelope, MiB, resolve_envelope
from repro.analysis.diagnostics import Diagnostic
from repro.analysis.features import _collect_consts, _nbytes
from repro.core import jaxpr_analysis
from repro.core.planner.space import DEFAULT_TARGET, BindingSpace


def _aval_bytes(aval: Any) -> int:
    """Bytes of one abstract value; 0 for avals without static shape."""
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    try:
        n = math.prod(int(d) for d in shape)
    except (TypeError, ValueError):  # dynamic dims — can't size statically
        return 0
    return n * getattr(dtype, "itemsize", 4)


def _var_bytes(v: Any) -> int:
    if isinstance(v, jex_core.Literal):
        return 0
    return _aval_bytes(getattr(v, "aval", None))


def jaxpr_peak_bytes(jaxpr: Any) -> int:
    """Peak bytes of *equation-produced* values live at any program point.

    A liveness walk in program order: each equation's outputs go live
    when produced; an input produced by an earlier equation dies at its
    last use; jaxpr outputs stay live to the end.  Sub-jaxprs (pjit /
    scan / while / cond bodies) contribute their own recursive peak on
    top of the live set at their call site — a conservative overcount
    (call operands are counted in both frames), which is fine for an
    upper-bound pass.  Jaxpr invars and consts are *not* counted here;
    :func:`estimate_memory` adds them once for the whole program.
    """
    if hasattr(jaxpr, "jaxpr"):  # ClosedJaxpr
        jaxpr = jaxpr.jaxpr

    last_use: dict[Any, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if not isinstance(v, jex_core.Literal):
                last_use[v] = i
    for v in jaxpr.outvars:
        if not isinstance(v, jex_core.Literal):
            last_use[v] = len(jaxpr.eqns)  # live to end

    live: dict[Any, int] = {}
    live_bytes = 0
    peak = sum(_var_bytes(v) for v in jaxpr.outvars)  # empty-eqn programs
    for i, eqn in enumerate(jaxpr.eqns):
        inner = 0
        for sub in jaxpr_analysis._sub_jaxprs(eqn):
            inner = max(inner, jaxpr_peak_bytes(sub))
        produced = 0
        for v in eqn.outvars:
            if v in live or isinstance(v, jex_core.Literal):
                continue
            b = _var_bytes(v)
            live[v] = b
            produced += b
        live_bytes += produced
        peak = max(peak, live_bytes + inner)
        for v in list(live):
            if last_use.get(v, -1) <= i:
                live_bytes -= live.pop(v)
    return peak


@dataclasses.dataclass(frozen=True)
class MemoryEstimate:
    """Static memory footprint of one traced program (upper bound)."""

    operand_bytes: int  # program inputs (params, batch, cache, ...)
    const_bytes: int  # captured/baked-in constants, incl. nested pjit
    output_bytes: int  # program outputs
    peak_intermediate_bytes: int  # liveness-walk peak (includes outputs)
    donated_bytes: int = 0  # inputs whose buffers may be reused

    @property
    def peak_live_bytes(self) -> int:
        """Operands + consts + peak intermediates, minus donation credit
        (a donated input buffer can back an output of the same size)."""
        credit = min(self.donated_bytes, self.output_bytes)
        return max(
            0,
            self.operand_bytes
            + self.const_bytes
            + self.peak_intermediate_bytes
            - credit,
        )

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["peak_live_bytes"] = self.peak_live_bytes
        return d

    def __str__(self) -> str:
        return (
            f"peak ~{self.peak_live_bytes / MiB:.1f} MiB "
            f"(operands {self.operand_bytes / MiB:.1f}, "
            f"consts {self.const_bytes / MiB:.1f}, "
            f"intermediates {self.peak_intermediate_bytes / MiB:.1f}, "
            f"donated {self.donated_bytes / MiB:.1f} MiB)"
        )


def _tree_bytes(tree: Any) -> int:
    return sum(_nbytes(leaf) for leaf in jax.tree.leaves(tree))


def estimate_memory(
    fn: Callable[..., Any],
    *example_args: Any,
    donate_argnums: tuple[int, ...] = (),
) -> MemoryEstimate:
    """Trace ``fn`` abstractly and size its working set.

    ``donate_argnums`` mirrors ``jax.jit``'s: those positional arguments'
    buffers are assumed reusable for outputs (the serve engine donates
    its cache), and are credited against the peak up to ``output_bytes``.
    """
    closed = jax.make_jaxpr(fn)(*example_args)
    jaxpr = closed.jaxpr
    operand_bytes = sum(_var_bytes(v) for v in jaxpr.invars)
    output_bytes = sum(_var_bytes(v) for v in jaxpr.outvars)
    consts: list[Any] = []
    _collect_consts(closed, consts)
    donated = 0
    for argnum in donate_argnums:
        if 0 <= argnum < len(example_args):
            donated += _tree_bytes(example_args[argnum])
    return MemoryEstimate(
        operand_bytes=operand_bytes,
        const_bytes=sum(_nbytes(c) for c in consts),
        output_bytes=output_bytes,
        peak_intermediate_bytes=jaxpr_peak_bytes(jaxpr),
        donated_bytes=donated,
    )


@dataclasses.dataclass(frozen=True)
class ResourceHint:
    """Per-(block, target) adjustment over the baseline program estimate.

    Candidate bindings share the baseline's shapes, so their working sets
    differ only by implementation overheads: an explicit scratch
    workspace, a multiplicative factor (e.g. a formulation that keeps an
    extra copy of its operands), and the resident tile footprint a tiled
    kernel needs in fast on-chip memory (checked against the envelope's
    ``vmem_bytes`` when both are known).
    """

    workspace_bytes: int = 0
    memory_multiplier: float = 1.0
    vmem_tile_bytes: int | None = None
    notes: str = ""

    def need_bytes(self, base_peak: int) -> int:
        return int(base_peak * self.memory_multiplier) + self.workspace_bytes


@dataclasses.dataclass(frozen=True)
class ResourceVerdict:
    """Fit verdict for one (block, target) binding against one envelope."""

    block: str
    target: str
    status: str  # "fits" | "oom" | "vmem-oom"
    need_bytes: int
    headroom_bytes: int
    reason: str = ""

    @property
    def fits(self) -> bool:
        return self.status == "fits"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ResourceReport:
    """Resource verdicts for every candidate binding of one program."""

    program: str
    envelope: DeviceEnvelope
    base: MemoryEstimate
    verdicts: dict[tuple[str, str], ResourceVerdict] = dataclasses.field(
        default_factory=dict
    )

    @property
    def oom(self) -> dict[tuple[str, str], str]:
        """(block, target) -> reason, for bindings that do not fit.
        Reasons carry the ``memory:`` tag so a prune surfaced through
        ``PlanReport.pruned_reasons`` is attributable to this pass."""
        return {
            pair: f"memory: {v.reason}"
            for pair, v in self.verdicts.items()
            if not v.fits
        }

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for v in self.verdicts.values():
            out[v.status] = out.get(v.status, 0) + 1
        return out

    def min_headroom_bytes(self) -> int:
        fitting = [v.headroom_bytes for v in self.verdicts.values() if v.fits]
        if fitting:
            return min(fitting)
        return self.envelope.headroom_bytes(self.base.peak_live_bytes)

    def diagnostics(self) -> list[Diagnostic]:
        """Info-severity diagnostics (fit depends on the chosen envelope,
        not on the code), stamped with the envelope name as platform."""
        out = []
        for (block, target), v in sorted(self.verdicts.items()):
            code = "resource-fit" if v.fits else f"resource-{v.status}"
            msg = v.reason or (
                f"needs ~{v.need_bytes / MiB:.1f} MiB, "
                f"headroom {v.headroom_bytes / MiB:.1f} MiB"
            )
            out.append(
                Diagnostic(
                    pass_name="resources",
                    code=code,
                    severity="info",
                    program=self.program,
                    subject=f"{block}->{target}",
                    message=msg,
                    platform=self.envelope.name,
                )
            )
        return out

    def to_dict(self) -> dict:
        return {
            "program": self.program,
            "envelope": self.envelope.to_dict(),
            "base": self.base.to_dict(),
            "verdicts": [v.to_dict() for _, v in sorted(self.verdicts.items())],
            "counts": self.counts(),
            "min_headroom_bytes": self.min_headroom_bytes(),
        }


def shelf_resources() -> dict[tuple[str, str], ResourceHint]:
    """The kernel shelf's declared hints (lazy import — kernels imports
    this module for the :class:`ResourceHint` type)."""
    try:
        from repro import kernels

        return dict(kernels.BLOCK_RESOURCES)
    except ImportError:
        return {}


def check_binding_space_resources(
    space: BindingSpace,
    example_args: tuple,
    *,
    envelope: Any = None,
    hints: Mapping[tuple[str, str], ResourceHint] | None = None,
    program: str = "",
    safety: float = 1.0,
) -> ResourceReport:
    """Fit every candidate binding of ``space`` against an envelope.

    Traces the *baseline* (all-default) binding once — candidate bindings
    share its shapes, so per-candidate needs are the baseline peak
    adjusted by each target's :class:`ResourceHint` (shelf defaults,
    overridable via ``hints``).  The baseline itself is never marked: the
    planner guarantees a measurable fallback, mirroring legality.
    """
    env = resolve_envelope(envelope)
    merged = shelf_resources()
    if hints:
        merged.update(hints)
    base = estimate_memory(space.build(space.baseline()), *example_args)
    report = ResourceReport(
        program=program or space.tag, envelope=env, base=base
    )
    budget = int(env.memory_bytes * safety)
    for axis in space.axes:
        for target in axis.choices:
            if target == DEFAULT_TARGET:
                continue
            hint = merged.get((axis.name, target), ResourceHint())
            need = hint.need_bytes(base.peak_live_bytes)
            headroom = env.memory_bytes - need
            if need > budget:
                status = "oom"
                reason = (
                    f"needs ~{need / MiB:.1f} MiB "
                    f"(base {base.peak_live_bytes / MiB:.1f} MiB, "
                    f"x{hint.memory_multiplier:g} "
                    f"+ {hint.workspace_bytes / MiB:.1f} MiB workspace) "
                    f"> {env.name} budget {budget / MiB:.1f} MiB"
                )
            elif (
                env.vmem_bytes
                and hint.vmem_tile_bytes
                and hint.vmem_tile_bytes > env.vmem_bytes
            ):
                status = "vmem-oom"
                reason = (
                    f"resident tiles ~{hint.vmem_tile_bytes / MiB:.1f} MiB "
                    f"> {env.name} VMEM {env.vmem_bytes / MiB:.1f} MiB"
                )
            else:
                status = "fits"
                reason = ""
            report.verdicts[(axis.name, target)] = ResourceVerdict(
                block=axis.name,
                target=target,
                status=status,
                need_bytes=need,
                headroom_bytes=headroom,
                reason=reason,
            )
    return report


# ---------------------------------------------------------------------------
# Serve-engine capacity planning


@dataclasses.dataclass(frozen=True)
class CapacityPlan:
    """Static sizing of one serve configuration against one envelope.

    All byte counts come from ``ParamMeta`` trees — nothing is
    materialised, so planning a 480B config takes the same milliseconds
    as a reduced one.  ``max_slots``/``max_pages`` answer "how far could
    this config scale on this device"; ``max_prefill_tokens`` bounds the
    ``--prefill-chunk`` width by per-token activation cost.
    """

    arch: str
    envelope: DeviceEnvelope
    n_slots: int
    max_len: int
    page_size: int | None
    n_pages: int | None
    params_bytes: int
    cache_bytes: int
    per_slot_bytes: int
    per_page_bytes: int
    total_bytes: int
    budget_bytes: int
    headroom_bytes: int
    fits: bool
    max_slots: int
    max_pages: int | None
    pool_tokens: int
    max_prefill_tokens: int | None = None
    safety: float = 1.0

    @property
    def paged(self) -> bool:
        return self.page_size is not None

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["envelope"] = self.envelope.to_dict()
        return d

    def summary(self) -> str:
        from repro.analysis.devices import GiB

        lines = [
            f"capacity plan: {self.arch} on {self.envelope}",
            f"  params     {self.params_bytes / GiB:9.3f} GiB",
            f"  kv cache   {self.cache_bytes / GiB:9.3f} GiB "
            f"({self.n_slots} slots x {self.max_len} tokens"
            + (
                f", {self.n_pages} pages x {self.page_size})"
                if self.paged
                else ")"
            ),
            f"  total      {self.total_bytes / GiB:9.3f} GiB "
            f"vs budget {self.budget_bytes / GiB:.3f} GiB "
            f"(safety x{self.safety:g})",
            f"  headroom   {self.headroom_bytes / GiB:9.3f} GiB "
            f"-> {'FITS' if self.fits else 'DOES NOT FIT'}",
            f"  max slots  {self.max_slots} (at {self.max_len} tokens each)",
        ]
        if self.paged:
            lines.append(f"  max pages  {self.max_pages}")
        lines.append(f"  pool       {self.pool_tokens} tokens")
        if self.max_prefill_tokens is not None:
            lines.append(
                f"  prefill    <= {self.max_prefill_tokens} tokens/chunk "
                f"by activation headroom"
            )
        return "\n".join(lines)

    def diagnostics(self, program: str = "") -> list[Diagnostic]:
        """A single ratchetable diagnostic: warning when the configured
        deployment cannot fit, info otherwise."""
        if self.fits:
            sev, code = "info", "capacity-fit"
            msg = (
                f"fits {self.envelope.name} with "
                f"{self.headroom_bytes / MiB:.0f} MiB headroom "
                f"(max {self.max_slots} slots)"
            )
        else:
            sev, code = "warning", "capacity-oom"
            msg = (
                f"params+cache ~{self.total_bytes / MiB:.0f} MiB exceed "
                f"{self.envelope.name} budget {self.budget_bytes / MiB:.0f} "
                f"MiB by {-self.headroom_bytes / MiB:.0f} MiB"
            )
        return [
            Diagnostic(
                pass_name="resources",
                code=code,
                severity=sev,
                program=program or f"{self.arch}:capacity",
                subject=f"slots={self.n_slots},max_len={self.max_len}"
                + (f",page_size={self.page_size}" if self.paged else ""),
                message=msg,
                platform=self.envelope.name,
            )
        ]


def _cache_bytes_fn(cfg, max_len: int, page_size, n_pages):
    from repro.models import lm
    from repro.models import params as pm

    def f(batch: int, pages: int | None) -> int:
        kw = {}
        if page_size is not None:
            kw = {"page_size": page_size, "n_pages": pages}
        return pm.param_bytes(lm.cache_metas_tree(cfg, batch, max_len, **kw))

    return f


def _prefill_token_bytes(cfg) -> int | None:
    """Peak intermediate bytes per prefill token (batch=1), traced with
    abstract params — best effort, None when the trace fails."""
    import jax.numpy as jnp

    from repro.models import lm
    from repro.models import params as pm

    seq = 8
    try:
        aparams = pm.abstract_params(lm.build_metas(cfg))
        batch = {"tokens": jax.ShapeDtypeStruct((1, seq), jnp.int32)}
        acache = pm.abstract_params(lm.cache_metas_tree(cfg, 1, seq))
        closed = jax.make_jaxpr(
            lambda p, b, c: lm.prefill(p, b, cfg, c)
        )(aparams, batch, acache)
        return max(1, jaxpr_peak_bytes(closed.jaxpr) // seq)
    except Exception:  # noqa: BLE001 — sizing hint only, never fatal
        return None


def plan_serve_capacity(
    cfg: Any,
    *,
    n_slots: int,
    max_len: int,
    page_size: int | None = None,
    n_pages: int | None = None,
    envelope: Any = None,
    safety: float = 0.9,
    prefill_bound: bool = True,
) -> CapacityPlan:
    """Size a serve deployment statically against a device envelope.

    Cache bytes are linear in slots and (when paged) pages; two-sample
    deltas over the meta tree recover the per-slot / per-page
    coefficients, from which the max slots / pages that fit the budget
    follow directly.  ``pool_tokens`` restates the configured pool in
    tokens so :meth:`ServeEngine.plan_capacity` can cross-check it
    against the live ``PagePool``.
    """
    from repro.models import lm
    from repro.models import params as pm
    from repro.serve.kv.pool import pages_for

    env = resolve_envelope(envelope)
    budget = int(env.memory_bytes * safety)
    params_bytes = pm.param_bytes(lm.build_metas(cfg))

    paged = page_size is not None
    pages_per_slot = pages_for(max_len, page_size) if paged else 0
    if paged and n_pages is None:
        n_pages = n_slots * pages_per_slot  # the engine's default pool

    f = _cache_bytes_fn(cfg, max_len, page_size, n_pages)
    if paged:
        cache_bytes = f(n_slots, n_pages)
        per_slot = f(2, n_pages) - f(1, n_pages)  # SSM state + index rows
        per_page = f(1, n_pages + 1) - f(1, n_pages)
        fixed = f(1, n_pages) - per_slot - n_pages * per_page
        slot_cost = per_slot + pages_per_slot * per_page
    else:
        cache_bytes = f(n_slots, None)
        per_slot = f(2, None) - f(1, None)
        per_page = 0
        fixed = f(1, None) - per_slot
        slot_cost = per_slot

    total = params_bytes + cache_bytes
    headroom = budget - total
    spare = budget - params_bytes - fixed
    max_slots = max(0, spare // slot_cost) if slot_cost > 0 else n_slots
    max_pages = None
    if paged:
        page_spare = spare - n_slots * per_slot
        max_pages = max(0, page_spare // per_page) if per_page > 0 else n_pages
    pool_tokens = n_pages * page_size if paged else n_slots * max_len

    max_prefill = None
    if prefill_bound and headroom > 0:
        per_tok = _prefill_token_bytes(cfg)
        if per_tok:
            max_prefill = max(1, headroom // per_tok)

    return CapacityPlan(
        arch=getattr(cfg, "name", str(cfg)),
        envelope=env,
        n_slots=n_slots,
        max_len=max_len,
        page_size=page_size,
        n_pages=n_pages if paged else None,
        params_bytes=params_bytes,
        cache_bytes=cache_bytes,
        per_slot_bytes=per_slot,
        per_page_bytes=per_page,
        total_bytes=total,
        budget_bytes=budget,
        headroom_bytes=headroom,
        fits=headroom >= 0,
        max_slots=int(max_slots),
        max_pages=int(max_pages) if max_pages is not None else None,
        pool_tokens=int(pool_tokens),
        max_prefill_tokens=max_prefill,
        safety=safety,
    )


# ---------------------------------------------------------------------------
# Shelf coverage


def lint_shelf_coverage(
    *,
    impls: tuple[tuple[str, str], ...] | None = None,
    legality: Mapping[tuple[str, str], Any] | None = None,
    hints: Mapping[tuple[str, str], ResourceHint] | None = None,
) -> list[Diagnostic]:
    """Every shelf implementation must declare a ``BLOCK_LEGALITY``
    envelope AND a ``BLOCK_RESOURCES`` hint — missing entries are
    ratcheted warnings, so a new kernel cannot land unchecked."""
    from repro import kernels

    impls = impls if impls is not None else kernels.SHELF_IMPL_PAIRS
    legality = legality if legality is not None else kernels.BLOCK_LEGALITY
    hints = hints if hints is not None else kernels.BLOCK_RESOURCES
    out = []
    for block, target in impls:
        missing = []
        if (block, target) not in legality:
            missing.append("BLOCK_LEGALITY")
        if (block, target) not in hints:
            missing.append("BLOCK_RESOURCES")
        if missing:
            out.append(
                Diagnostic(
                    pass_name="resources",
                    code="shelf-coverage",
                    severity="warning",
                    program="kernels.shelf",
                    subject=f"{block}->{target}",
                    message=(
                        f"shelf implementation declares no "
                        f"{' or '.join(missing)} entry; every kernel must "
                        f"ship its static envelope"
                    ),
                )
            )
    return out
