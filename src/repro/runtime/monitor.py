"""Step monitoring: throughput EMA + straggler detection.

At 1000+ nodes the dominant soft failure is the slow host (flaky NIC,
thermal throttle, noisy neighbour).  The monitor keeps a rolling step-time
window; a step exceeding ``threshold`` x the rolling median is flagged, and
a host flagged ``patience`` times in a row is reported for eviction — the
launcher responds by checkpoint-restart without the straggler (elastic
downsize), which is cheaper than letting one host set the fleet's pace.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from collections import deque
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    host: int
    seconds: float
    median: float


class StepMonitor:
    """``histogram`` (optional) is a write-through bridge into a
    ``repro.obs.MetricsRegistry`` instrument: every observed step duration
    is also recorded there (``.observe(seconds)``), so the monitor's
    rolling window and the exported latency histogram are fed by the same
    observation — the numbers are never computed twice."""

    def __init__(
        self,
        window: int = 32,
        threshold: float = 2.0,
        patience: int = 3,
        on_straggler: Callable[[StragglerEvent], None] | None = None,
        histogram=None,
    ) -> None:
        self.window: deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.patience = patience
        self.on_straggler = on_straggler
        self.histogram = histogram
        self.events: list[StragglerEvent] = []
        self._consecutive: dict[int, int] = {}
        self.flagged_hosts: set[int] = set()
        self._t0: float | None = None
        self.steps = 0
        self.total_time = 0.0

    # -- timing ------------------------------------------------------------
    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int, host: int = 0) -> float:
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        self.observe(step, dt, host)
        return dt

    def observe(self, step: int, seconds: float, host: int = 0) -> None:
        self.steps += 1
        self.total_time += seconds
        if self.histogram is not None:
            self.histogram.observe(seconds)
        med = statistics.median(self.window) if self.window else seconds
        self.window.append(seconds)
        if len(self.window) >= 8 and seconds > self.threshold * med:
            ev = StragglerEvent(step, host, seconds, med)
            self.events.append(ev)
            self._consecutive[host] = self._consecutive.get(host, 0) + 1
            if self._consecutive[host] >= self.patience:
                self.flagged_hosts.add(host)
            if self.on_straggler:
                self.on_straggler(ev)
        else:
            self._consecutive[host] = 0

    # -- reporting ------------------------------------------------------------
    def throughput(self, tokens_per_step: int) -> float:
        if self.total_time == 0:
            return 0.0
        return self.steps * tokens_per_step / self.total_time

    def median_step(self) -> float:
        return statistics.median(self.window) if self.window else 0.0
