"""Unified decoder LM covering all 10 assigned architectures.

A model is a *block pattern* — one char per layer:
    'a'  attention + (MoE if configured, else SwiGLU MLP)
    'd'  attention + dense MLP (the leading dense layers of an MoE stack)
    'm'  Mamba-2 SSD block
    's'  shared-parameter attention+MLP block (Zamba2) — one param set,
         applied at every 's' site (each site keeps its own KV cache)

Consecutive identical chars form a *group*; each group's parameters are
stacked with a leading layer axis and executed with ``lax.scan`` so compile
time and HLO size are O(#groups), not O(#layers).  Shared blocks are applied
point-wise between groups with the single shared param set.

Modes: train (loss), prefill (build cache + logits), decode (one token
against the cache).  Caches/states are stacked per group, mirroring the
param stacking.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

import repro.kernels  # noqa: F401  (registers function blocks)

# Remat policy for the per-layer checkpoint: "none" = recompute everything
# (the paper-faithful baseline), "save_moe" = keep each MoE block's output
# (a small (B,S,D) bf16 per layer) so the backward never re-runs the expert
# forward — each re-run costs a full FSDP gather of the expert weights, the
# dominant collective for 100B+ MoE models (a §Perf knob).
REMAT_POLICY = "none"
from repro.configs.base import ArchConfig
from repro.models import params as pm
from repro.models.attention import (
    attention_forward,
    attn_metas,
    cache_metas,
    cache_metas_paged,
)
from repro.models.layers import (
    cross_entropy,
    embed_lookup,
    embed_metas,
    lm_logits,
    mlp_forward,
    mlp_metas,
    rmsnorm,
)
from repro.models.moe import moe_forward, moe_metas
from repro.models.params import ParamMeta
from repro.models.ssm import ssm_forward, ssm_metas, ssm_state_metas
from repro.sharding.utils import constrain


# -- pattern grouping -----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Group:
    index: int
    kind: str  # 'a' | 'd' | 'm' | 's'
    count: int

    @property
    def key(self) -> str:
        return f"g{self.index}_{self.kind}"


def groups_of(cfg: ArchConfig) -> list[Group]:
    pat = cfg.pattern()
    out: list[Group] = []
    i = 0
    gi = 0
    while i < len(pat):
        j = i
        while j < len(pat) and pat[j] == pat[i]:
            j += 1
        out.append(Group(gi, pat[i], j - i))
        gi += 1
        i = j
    return out


# -- parameter metas --------------------------------------------------------------


def _stack(metas: Any, n: int) -> Any:
    return pm.tree_map_metas(
        lambda m: ParamMeta(
            (n,) + m.shape, ("layers",) + m.axes, m.dtype, m.init, m.scale
        ),
        metas,
    )


def _block_metas(cfg: ArchConfig, kind: str) -> dict:
    d = cfg.d_model
    dt = cfg.param_dtype
    if kind == "m":
        return {
            "ln": ParamMeta((d,), (None,), dt, init="ones"),
            "mixer": ssm_metas(cfg),
        }
    metas = {
        "ln1": ParamMeta((d,), (None,), dt, init="ones"),
        "attn": attn_metas(cfg),
        "ln2": ParamMeta((d,), (None,), dt, init="ones"),
    }
    if kind == "a" and cfg.moe is not None:
        metas["moe"] = moe_metas(cfg)
    else:
        metas["mlp"] = mlp_metas(d, cfg.d_ff, dt)
    return metas


def build_metas(cfg: ArchConfig) -> dict:
    metas: dict = {"embed": embed_metas(cfg)}
    blocks: dict = {}
    has_shared = False
    for g in groups_of(cfg):
        if g.kind == "s":
            has_shared = True
            continue
        blocks[g.key] = _stack(_block_metas(cfg, g.kind), g.count)
    if has_shared:
        metas["shared_block"] = _block_metas(cfg, "s")
    metas["blocks"] = blocks
    metas["final_norm"] = ParamMeta(
        (cfg.d_model,), (None,), cfg.param_dtype, init="ones"
    )
    return metas


def cache_metas_tree(
    cfg: ArchConfig,
    batch: int,
    max_len: int,
    *,
    page_size: int | None = None,
    n_pages: int | None = None,
) -> dict:
    """Cache layout: contiguous (default) or block-paged.

    Contiguous: every attention group leaf reserves ``batch x max_len``
    rows.  Paged (``page_size`` + ``n_pages`` given): attention leaves
    become a shared pool of ``n_pages`` fixed-size pages (+ one null page
    at index ``n_pages``), addressed through the ``(batch, max_pages)``
    page table the serving engine passes alongside the cache; SSM state
    leaves stay per-slot (a recurrent state has no sequence axis to page).
    """
    paged = page_size is not None
    if paged and n_pages is None:
        raise ValueError("paged cache needs both page_size and n_pages")
    caches: dict = {}
    for g in groups_of(cfg):
        if g.kind == "m":
            caches[g.key] = _stack(ssm_state_metas(cfg, batch), g.count)
        elif paged:
            caches[g.key] = _stack(
                cache_metas_paged(cfg, n_pages + 1, page_size), g.count
            )
        else:
            caches[g.key] = _stack(cache_metas(cfg, batch, max_len), g.count)
    # per-slot lengths: continuous-batching serving staggers requests
    # across batch rows, so each row carries its own write position
    caches["index"] = ParamMeta((batch,), ("act_batch",), "int32", init="zeros")
    return caches


def init_params(cfg: ArchConfig, seed: int = 0) -> Any:
    return pm.init_params(build_metas(cfg), seed)


def init_cache(
    cfg: ArchConfig,
    batch: int,
    max_len: int,
    *,
    page_size: int | None = None,
    n_pages: int | None = None,
) -> Any:
    return pm.init_params(
        cache_metas_tree(
            cfg, batch, max_len, page_size=page_size, n_pages=n_pages
        ),
        0,
    )


# -- block application -------------------------------------------------------------


@jax.custom_jvp
def _opt_barrier(x):
    """``lax.optimization_barrier`` with an identity differentiation rule.

    The barrier is a scheduling hint, not a math op, so its tangent is the
    identity — but jax (< 0.4.38) ships no differentiation rule for the
    primitive at all, which kills the train-step backward pass under
    ``value_and_grad``.  The custom JVP keeps the barrier in the primal
    computation and lets tangents flow through untouched.
    """
    return jax.lax.optimization_barrier(x)


@_opt_barrier.defjvp
def _opt_barrier_jvp(primals, tangents):
    (x,), (dx,) = primals, tangents
    return _opt_barrier(x), dx


def _apply_attn_block(
    lp: dict, x: jax.Array, cfg: ArchConfig, positions, cache, index, mode,
    kind: str, pages=None,
):
    cd = jnp.dtype(cfg.compute_dtype)
    # Sequence-parallel <-> tensor-parallel transitions are made explicit
    # and pinned to the bf16 side of the norm: the all-gather to full
    # sequence happens on the bf16 post-norm activation (not the f32 norm
    # internals XLA would otherwise hoist it above), and mixer/FFN outputs
    # are constrained straight back to sequence shards so GSPMD emits
    # reduce-scatters instead of all-reduce + re-slice.
    # barrier after the bf16 cast: the SP->TP all-gather must happen on
    # the bf16 post-norm tensor, not be hoisted above the cast into the
    # norm's f32 internals (which doubles transition bytes)
    h_in = _opt_barrier(
        rmsnorm(lp["ln1"], x, cfg.norm_eps).astype(cd)
    )
    attn_out, new_cache = attention_forward(
        lp["attn"], h_in, cfg, positions, cache, index, mode, pages
    )
    x = x + attn_out.astype(x.dtype)
    ff_in = _opt_barrier(
        rmsnorm(lp["ln2"], x, cfg.norm_eps).astype(cd)
    )
    if kind == "a" and cfg.moe is not None:
        ff, aux = moe_forward(lp["moe"], ff_in, cfg, cd)
    else:
        ff = mlp_forward(lp["mlp"], ff_in, cd)
        aux = jnp.asarray(0.0, jnp.float32)
    x = x + ff.astype(x.dtype)
    x = constrain(x, "act_batch", "act_seq", None)
    return x, aux, new_cache


def _apply_mamba_block(lp, x, cfg, cache, mode):
    if mode == "extend":
        raise ValueError(
            "chunked prefill (extend mode) is unsupported for SSM blocks: "
            "resuming the scan needs the conv window stitched across chunk "
            "boundaries"
        )
    cd = jnp.dtype(cfg.compute_dtype)
    h_in = _opt_barrier(
        rmsnorm(lp["ln"], x, cfg.norm_eps).astype(cd)
    )
    out, new_state = ssm_forward(lp["mixer"], h_in, cfg, cache, mode)
    x = x + out.astype(x.dtype)
    x = constrain(x, "act_batch", "act_seq", None)
    return x, jnp.asarray(0.0, jnp.float32), new_state


def _apply_group(
    gparams, g: Group, x, cfg, positions, gcache, index, mode, shared_params,
    pages=None,
):
    """Scan a homogeneous group of layers; returns (x, aux_sum, new_gcache)."""
    use_cache = gcache is not None
    shared = g.kind == "s"

    def apply_one(x, aux, lp, lcache):
        p = shared_params if shared else lp
        if g.kind == "m":
            x, a, nc = _apply_mamba_block(p, x, cfg, lcache, mode)
        else:
            x, a, nc = _apply_attn_block(
                p, x, cfg, positions, lcache, index, mode, g.kind, pages
            )
        return x, aux + a, nc

    def layer(x_aux, xs):
        x, aux = x_aux
        # barrier: prevents XLA from hoisting dtype converts of the stacked
        # layer-input residuals out of the scan (an f32 copy of every
        # saved carry doubles remat memory otherwise)
        x = _opt_barrier(x)
        if shared:
            lp, lcache = None, xs
        elif use_cache:
            lp, lcache = xs
        else:
            lp, lcache = xs, None
        x, aux, nc = apply_one(x, aux, lp, lcache)
        return (x, aux), nc

    body = layer
    if cfg.remat == "full" and mode == "train":
        policy = None
        if REMAT_POLICY == "save_moe" and cfg.moe is not None:
            policy = jax.checkpoint_policies.save_only_these_names("moe_out")
        body = jax.checkpoint(layer, prevent_cse=False, policy=policy)

    zero = jnp.asarray(0.0, jnp.float32)
    if shared and not use_cache:
        # cache-less shared blocks: unrolled application (count is small
        # and there are no per-site parameters to stack)
        aux_t = zero
        for _ in range(g.count):
            x, aux_t, _ = apply_one(x, aux_t, None, None)
        return x, aux_t, None

    if shared:
        xs = gcache  # scan each site's cache under the shared params
    elif use_cache:
        xs = (gparams, gcache)
    else:
        xs = gparams
    (x, aux), new_cache = jax.lax.scan(body, (x, zero), xs)
    return x, aux, (new_cache if use_cache else None)


# -- forward / loss / serve ---------------------------------------------------------


def _input_embeds(params, batch, cfg: ArchConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    if "embeds" in batch:
        return batch["embeds"].astype(cd)
    return embed_lookup(params["embed"], batch["tokens"], cd)


def backbone(
    params: Any,
    batch: dict,
    cfg: ArchConfig,
    mode: str = "train",
    cache: Any = None,
):
    """All blocks, no head.  Returns (hidden (B,S,D), aux_loss, new_cache)."""
    x = _input_embeds(params, batch, cfg)
    b, s = x.shape[0], x.shape[1]
    x = constrain(x, "act_batch", "act_seq", None)

    pages = None
    if mode in ("decode", "extend"):
        index = cache["index"]
        if index.ndim != 1:
            raise ValueError(
                "cache['index'] must be per-slot (B,) write positions; the "
                "scalar-index broadcast fallback was removed — rebuild the "
                "cache with init_cache()"
            )
        index = index.astype(jnp.int32)
        pages = cache.get("pages")  # (B, max_pages) page table, paged only
        positions = index[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    else:
        index = None
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, :], (b, s)
        )

    aux_total = jnp.asarray(0.0, jnp.float32)
    new_cache: dict = {} if cache is not None else None
    shared = params.get("shared_block")
    for g in groups_of(cfg):
        gparams = None if g.kind == "s" else params["blocks"][g.key]
        gcache = cache[g.key] if cache is not None else None
        x, aux, nc = _apply_group(
            gparams, g, x, cfg, positions, gcache, index, mode, shared, pages
        )
        aux_total = aux_total + aux
        if cache is not None:
            new_cache[g.key] = nc
    return x, aux_total, new_cache


def head(params: Any, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params["embed"], x, cfg, jnp.dtype(cfg.compute_dtype))


def forward(
    params: Any,
    batch: dict,
    cfg: ArchConfig,
    mode: str = "train",
    cache: Any = None,
):
    """Returns (logits, aux_loss, new_cache)."""
    x, aux_total, new_cache = backbone(params, batch, cfg, mode, cache)
    s = x.shape[1]
    logits = head(params, x, cfg)
    if cache is not None:
        if mode in ("decode", "extend"):
            new_cache["index"] = cache["index"] + s
        else:  # prefill: every row's cache now holds s tokens
            new_cache["index"] = jnp.full(
                (batch["tokens" if "tokens" in batch else "embeds"].shape[0],),
                s, jnp.int32,
            )
    return logits, aux_total, new_cache


def loss_fn(params: Any, batch: dict, cfg: ArchConfig):
    x, aux, _ = backbone(params, batch, cfg, mode="train")

    def head_loss(p, xx, labels):
        logits = head(p, xx, cfg)
        return cross_entropy(logits, labels)

    # remat the head: the (B,S,V) logits/softmax residuals are the largest
    # single activations in the step; recomputing one matmul in the backward
    # is far cheaper than holding them
    if cfg.remat == "full":
        head_loss = jax.checkpoint(head_loss)
    ce = head_loss(params, x, batch["labels"])
    coef = cfg.moe.aux_loss_coef if cfg.moe else 0.0
    total = ce + coef * aux
    return total, {"loss": total, "ce": ce, "aux": aux}


def prefill(params: Any, batch: dict, cfg: ArchConfig, cache: Any):
    logits, _, new_cache = forward(params, batch, cfg, mode="prefill", cache=cache)
    return logits, new_cache


def decode_step(params: Any, tokens: jax.Array, cfg: ArchConfig, cache: Any):
    """tokens (B, 1) -> (logits (B,1,V), new_cache).  cache["index"] (B,)
    is each row's write position for this token — rows may sit at
    different positions (continuous batching)."""
    logits, _, new_cache = forward(
        params, {"tokens": tokens}, cfg, mode="decode", cache=cache
    )
    return logits, new_cache
