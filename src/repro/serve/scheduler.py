"""Continuous-batching admission control: slots, queueing, token budget.

The engine's KV cache is a fixed array of ``n_slots`` batch rows.  The
scheduler owns which request occupies which slot: submitted requests wait
in FIFO order, each engine step admits waiting requests into free slots
(a prefill each), and finished requests release their slot immediately —
the next waiting request reuses it on the following step, while the other
slots keep decoding.  This is continuous batching: the batch recomposes
every step instead of draining entirely before refilling.

The *token budget* (``max_tokens_per_step``) bounds how much work one
engine step may inject, in tokens: a decode step costs one token per
active slot, an admission costs the prompt length its prefill program
actually runs (bucket-padded when the engine pads) plus the admitted
request's own decode token this step.  A small
budget keeps per-step latency flat under bursty arrivals (prefills are
spread over steps instead of stalling every in-flight decode at once); a
large budget maximises admission throughput.  When nothing is active and
nothing was admitted yet, one admission is always allowed regardless of
budget, so a prompt longer than the budget cannot deadlock the queue.
"""

from __future__ import annotations

from collections import deque

from repro.serve.request import RequestState


class Scheduler:
    def __init__(
        self,
        n_slots: int,
        max_tokens_per_step: int | None = None,
        prompt_cost=None,
    ) -> None:
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.max_tokens_per_step = max_tokens_per_step
        #: maps a prompt length to the tokens its prefill actually runs —
        #: the engine passes its bucket-padded length so the budget bounds
        #: the real program size, not the nominal prompt
        self.prompt_cost = prompt_cost or (lambda n: n)
        # pop() takes from the end: keep slot 0 first for readable traces
        self._free: list[int] = list(range(n_slots - 1, -1, -1))
        self.waiting: deque[RequestState] = deque()
        self.active: dict[int, RequestState] = {}
        #: admissions per slot over the scheduler's lifetime — any count > 1
        #: is an observed slot reuse (the continuous-batching signature)
        self.admitted_per_slot: dict[int, int] = {}

    # -- queue side -----------------------------------------------------------
    def enqueue(self, state: RequestState) -> None:
        self.waiting.append(state)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    # -- per-step admission ----------------------------------------------------
    def admissions(self) -> list[RequestState]:
        """Admit waiting requests into free slots for this engine step.

        FIFO, budget-capped (decode tokens for the currently active slots
        are charged first), and guaranteed to make progress when the
        engine is otherwise idle.
        """
        admitted: list[RequestState] = []
        budget = self.max_tokens_per_step
        spent = len(self.active)  # this step's decode tokens
        while self.waiting and self._free:
            nxt = self.waiting[0]
            # +1: the admitted request decodes in this same step too
            cost = self.prompt_cost(len(nxt.request.prompt)) + 1
            if budget is not None and spent + cost > budget:
                if self.active or admitted:
                    break  # decode (or earlier admissions) proceed first
                # idle engine: admit anyway — a prompt longer than the
                # budget must not wedge the queue
            self.waiting.popleft()
            slot = self._free.pop()
            nxt.slot = slot
            self.active[slot] = nxt
            self.admitted_per_slot[slot] = (
                self.admitted_per_slot.get(slot, 0) + 1
            )
            admitted.append(nxt)
            spent += cost
        return admitted

    def release(self, slot: int) -> RequestState:
        """Evict a finished request and free its slot for reuse."""
        state = self.active.pop(slot)
        self._free.append(slot)
        return state

    # -- reporting -------------------------------------------------------------
    @property
    def slot_reuses(self) -> int:
        """Admissions beyond each slot's first — > 0 proves continuous
        batching actually recomposed the batch."""
        return sum(max(0, n - 1) for n in self.admitted_per_slot.values())
