"""FunctionBlock registry: bindings scope the offload pattern."""

import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels  # noqa: F401 registers blocks
from repro.core import blocks
from repro.core.engine import OffloadEngine


def test_registry_has_shelf_blocks():
    names = blocks.registry.blocks()
    for expected in ("matmul", "attention", "rmsnorm", "ssd_scan", "fft2d", "lu"):
        assert expected in names


def test_default_binding_prefers_xla():
    fn = blocks.registry.resolve("rmsnorm")
    x = jnp.ones((2, 8))
    w = jnp.ones(8)
    out = fn(x, w)
    assert out.shape == (2, 8)


def test_bind_scopes_pattern():
    calls = []

    def probe(*a, **k):
        calls.append("probe")
        return a[0]

    blocks.registry.register("rmsnorm", "probe_target", probe)
    with blocks.bind({"rmsnorm": "probe_target"}):
        blocks.call("rmsnorm", jnp.ones(4), jnp.ones(4))
    assert calls == ["probe"]
    # binding is restored outside the context
    out = blocks.call("rmsnorm", jnp.ones((1, 4)), jnp.ones(4))
    assert out.shape == (1, 4)


def test_engine_environment_pattern_selection():
    eng = OffloadEngine()
    pat_cpu = eng.select_block_pattern("cpu")
    assert pat_cpu["attention"] == "xla"
    pat_tpu = eng.select_block_pattern("tpu")
    assert pat_tpu["attention"] == "pallas"
    assert pat_tpu["fft2d"] == "pallas"


def test_measured_binding_selection():
    eng = OffloadEngine()
    x = jnp.ones((4, 64), jnp.float32)
    w = jnp.ones(64, jnp.float32)

    def builder():
        def step(x, w):
            return blocks.call("rmsnorm", x, w)

        return step

    best, results = eng.measure_block_pattern(
        builder,
        [{"rmsnorm": "ref"}, {"rmsnorm": "xla"}],
        (x, w),
        repeats=1,
    )
    assert best["rmsnorm"] in ("ref", "xla")
    assert len(results) == 2
