"""jax-version compatibility shims for the Pallas TPU shelf.

The shelf targets the current Pallas API, where TPU compiler options are
``pltpu.CompilerParams``.  On jax 0.4.x the same dataclass is named
``pltpu.TPUCompilerParams`` — same fields, different name — and kernels
that reference the new name fail at trace time with ``AttributeError``
even in ``interpret=True`` mode on CPU.  Route every kernel's compiler
params through :func:`tpu_compiler_params` so one shelf source supports
both jax generations.
"""

from __future__ import annotations

from typing import Any

from jax.experimental.pallas import tpu as pltpu

#: The TPU compiler-params class under whichever name this jax exports it.
CompilerParams = getattr(
    pltpu, "CompilerParams", None
) or getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs: Any):
    """Construct TPU compiler params on any supported jax version."""
    return CompilerParams(**kwargs)
