"""Offload-legality pass: which (block, target) bindings may be measured.

The paper's Step 1 decides *statically* which function blocks are offload
candidates before any compilation or measurement is spent on them.  Here a
binding is classified from cheap facts first:

1. **registry metadata** — ``repro.kernels.BLOCK_LEGALITY`` declares each
   shelf implementation's platform and dtype envelope (a Pallas TPU kernel
   is illegal on a CPU/GPU host backend);
2. **program features** — dtype universe and dynamic-shape presence of the
   traced step (a float64 program cannot bind a float32-only kernel);
3. **probe trace** — the step is abstractly re-traced under the candidate
   binding (``jax.make_jaxpr``, no compile, no execution); a trace failure
   is a definitive illegal verdict.

Verdicts are ``legal`` / ``illegal`` / ``unknown`` (no metadata and probe
disabled).  Illegal pairs feed ``BindingSpace.mark_illegal`` so search
strategies prune them instead of timing (or crashing on) them.

Platform-dependent verdicts carry severity ``info`` — they flip between a
CPU CI host and a TPU production host, so they never enter the lint
baseline ratchet.  Structural verdicts (dtype, trace failure) are
``warning``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

from repro.analysis.diagnostics import Diagnostic
from repro.analysis.features import ProgramFeatures, trace_features

LEGAL = "legal"
ILLEGAL = "illegal"
UNKNOWN = "unknown"


@dataclasses.dataclass(frozen=True)
class TargetConstraints:
    """Static envelope of one registered block implementation.

    ``requires_platform`` — JAX backend names the implementation lowers on
    (empty = any).  ``dtypes`` — float dtypes the kernel supports (empty =
    any); only *floating* program dtypes are checked against it, since
    integer index/id operands ride along in every program.
    """

    requires_platform: tuple[str, ...] = ()
    dtypes: tuple[str, ...] = ()
    allow_dynamic_shapes: bool = True
    notes: str = ""


@dataclasses.dataclass(frozen=True)
class BlockVerdict:
    block: str
    target: str
    status: str  # legal | illegal | unknown
    reason: str = ""
    severity: str = "info"  # severity of the diagnostic this verdict emits


@dataclasses.dataclass
class LegalityReport:
    program: str
    platform: str
    verdicts: list[BlockVerdict] = dataclasses.field(default_factory=list)
    features: ProgramFeatures | None = None
    #: Resource verdicts when ``check_binding_space`` ran with an envelope
    #: (a ``repro.analysis.resources.ResourceReport``), else None.
    resources: Any = None

    @property
    def illegal(self) -> dict[tuple[str, str], str]:
        """The ``(block, target) -> reason`` map ``mark_illegal`` consumes.
        Legality reasons take precedence; statically-OOM bindings from the
        resource pass (when it ran) merge in with their ``memory:`` tag."""
        out: dict[tuple[str, str], str] = {}
        if self.resources is not None:
            out.update(self.resources.oom)
        out.update({
            (v.block, v.target): v.reason
            for v in self.verdicts
            if v.status == ILLEGAL
        })
        return out

    def counts(self) -> dict[str, int]:
        out = {LEGAL: 0, ILLEGAL: 0, UNKNOWN: 0}
        for v in self.verdicts:
            out[v.status] += 1
        return out

    def diagnostics(self) -> list[Diagnostic]:
        diags = []
        for v in self.verdicts:
            if v.status == LEGAL:
                continue
            code = "illegal-binding" if v.status == ILLEGAL else "no-metadata"
            diags.append(
                Diagnostic(
                    pass_name="legality",
                    code=code,
                    severity=v.severity if v.status == ILLEGAL else "info",
                    program=self.program,
                    subject=f"{v.block}->{v.target}",
                    message=v.reason or f"no legality metadata for {v.target}",
                    platform=self.platform,
                )
            )
        if self.resources is not None:
            diags.extend(self.resources.diagnostics())
        return diags


def _float_dtypes(dtypes: frozenset[str]) -> set[str]:
    return {d for d in dtypes if d.startswith(("float", "bfloat", "complex"))}


def shelf_constraints() -> Mapping[tuple[str, str], TargetConstraints]:
    """The kernel shelf's declared legality metadata (lazy import: kernels
    imports this module for the TargetConstraints type)."""
    from repro.kernels import BLOCK_LEGALITY

    return BLOCK_LEGALITY


def classify_binding(
    block: str,
    target: str,
    spec: TargetConstraints | None,
    features: ProgramFeatures | None,
    platform: str,
) -> BlockVerdict:
    """Metadata-only classification of one (block, target) binding."""
    if spec is None:
        return BlockVerdict(block, target, UNKNOWN,
                            reason="no registry legality metadata")
    if spec.requires_platform and platform not in spec.requires_platform:
        return BlockVerdict(
            block, target, ILLEGAL,
            reason=(
                f"requires platform {'/'.join(spec.requires_platform)}, "
                f"host backend is {platform}"
            ),
            severity="info",  # flips between CI (cpu) and prod (tpu) hosts
        )
    if features is not None:
        if spec.dtypes:
            unsupported = _float_dtypes(features.dtypes) - set(spec.dtypes)
            if unsupported:
                return BlockVerdict(
                    block, target, ILLEGAL,
                    reason=(
                        f"program uses {sorted(unsupported)}, kernel "
                        f"supports {list(spec.dtypes)}"
                    ),
                    severity="warning",
                )
        if features.dynamic_shapes and not spec.allow_dynamic_shapes:
            return BlockVerdict(
                block, target, ILLEGAL,
                reason="program has dynamic shapes; kernel requires static",
                severity="warning",
            )
    return BlockVerdict(block, target, LEGAL)


def check_binding_space(
    space: Any,
    args: Sequence[Any],
    constraints: Mapping[tuple[str, str], TargetConstraints] | None = None,
    platform: str | None = None,
    probe_trace: bool = True,
    program: str = "",
    envelope: Any = None,
    resource_hints: Mapping[tuple[str, str], Any] | None = None,
) -> LegalityReport:
    """Classify every (block, target) choice of a ``BindingSpace``.

    Cheap checks run first (registry metadata against the host platform and
    the program's dtype/shape features); only pairs that survive them are
    probe-traced under their single-block binding — ``jax.make_jaxpr``
    only, so an hours-long candidate compile is never spent on a binding
    the probe can reject (the paper's FPGA pre-filter economics).

    When ``envelope`` is given (a ``DeviceEnvelope``, a static-table name,
    or ``"host"``/``True`` to probe the live runtime), the memory-envelope
    pass also runs — the paper's FPGA resource-fit check — and its
    statically-OOM bindings join ``report.illegal`` tagged ``memory:``.
    """
    import jax

    from repro.core.planner.space import DEFAULT_TARGET

    if constraints is None:
        constraints = shelf_constraints()
    if platform is None:
        platform = jax.default_backend()
    report = LegalityReport(program=program or space.tag, platform=platform)
    if envelope is not None:
        from repro.analysis.resources import check_binding_space_resources

        report.resources = check_binding_space_resources(
            space,
            tuple(args),
            envelope=envelope,
            hints=resource_hints,
            program=program or space.tag,
        )

    features: ProgramFeatures | None = None
    try:
        features = trace_features(space.build(space.baseline()), *args)
    except Exception:  # noqa: BLE001 — feature-less classification still works
        features = None
    report.features = features

    baseline = space.baseline()
    for i, axis in enumerate(space.axes):
        for c, label in enumerate(axis.choices):
            if label == DEFAULT_TARGET:
                continue
            verdict = classify_binding(
                axis.name, label, constraints.get((axis.name, label)),
                features, platform,
            )
            if verdict.status == LEGAL and probe_trace:
                cand = list(baseline)
                cand[i] = c
                try:
                    jax.make_jaxpr(space.build(tuple(cand)))(*args)
                except Exception as e:  # noqa: BLE001 — the probe's verdict
                    verdict = BlockVerdict(
                        axis.name, label, ILLEGAL,
                        reason=f"probe trace failed: {type(e).__name__}: {e}",
                        severity="warning",
                    )
            elif verdict.status == UNKNOWN and probe_trace:
                # no metadata: the probe alone decides legal-vs-illegal
                cand = list(baseline)
                cand[i] = c
                try:
                    jax.make_jaxpr(space.build(tuple(cand)))(*args)
                    verdict = BlockVerdict(axis.name, label, LEGAL)
                except Exception as e:  # noqa: BLE001
                    verdict = BlockVerdict(
                        axis.name, label, ILLEGAL,
                        reason=f"probe trace failed: {type(e).__name__}: {e}",
                        severity="warning",
                    )
            report.verdicts.append(verdict)
    return report
