"""PlanStore — persistent offload plans for production startup.

The paper's flow ends with "the verified pattern is deployed"; this module
makes that a first-class artifact.  A ``Plan`` is the winning pattern of a
search (block -> choice mapping) plus the environment fingerprint it was
verified under.  Plans are JSON files under a configurable directory, so
``launch/serve.py`` / ``launch/train.py`` can load a previously verified
plan at startup and bind it via ``blocks.bind`` with zero re-measurement.
A fingerprint mismatch (different device kind, jax version, ...) makes the
stored plan invisible, forcing a fresh search rather than silently reusing
a pattern verified on different hardware.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import re
import time
from typing import Any, Mapping


def environment_fingerprint(extra: Mapping[str, str] | None = None) -> dict[str, str]:
    """What the measured plan is conditional on."""
    import platform

    fp: dict[str, str] = {
        "python": platform.python_version(),
        "machine": platform.machine(),
    }
    try:
        import jax

        fp["jax"] = jax.__version__
        fp["backend"] = jax.default_backend()
        devs = jax.devices()
        if devs:
            fp["device"] = getattr(devs[0], "device_kind", str(devs[0]))
    except Exception:  # noqa: BLE001 — planner must work without jax
        pass
    try:
        # the kernel shelf is part of the environment: a plan measured
        # against one set of kernel implementations must not silently bind
        # after a kernel rewrite, so the shelf sources are hashed in.
        # Only the stock shelf (repro.kernels) counts, snapshotted at
        # registration time — ad-hoc runtime registrations are not "the
        # environment" and must not churn the hash between processes.
        import repro.kernels as _shelf

        fp["kernel_shelf"] = _shelf.SHELF_FINGERPRINT
    except Exception:  # noqa: BLE001 — shelf needs jax; optional like above
        pass
    if extra:
        fp.update(extra)
    return fp


@dataclasses.dataclass
class Plan:
    key: str  # user-chosen plan name, e.g. "serve:llama3.2-1b:decode"
    space: str  # SearchSpace signature the plan was searched over
    mapping: dict[str, str]  # axis/block -> chosen non-baseline target
    pattern: tuple[str, ...]
    baseline_seconds: float
    best_seconds: float
    speedup: float
    strategy: str
    evaluations: int
    search_seconds: float
    fingerprint: dict[str, str]
    created_unix: float = 0.0
    objective: str = "latency"  # objective that selected this pattern
    best_energy_joules: float | None = None  # when a PowerMeter was wired
    # "measured" (hardware counter) vs "estimated" (modelled draw); None
    # when no meter produced a reading — see repro.metering.meters
    best_energy_provenance: str | None = None

    def to_json(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["pattern"] = list(self.pattern)
        return d

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "Plan":
        d = dict(d)
        d["pattern"] = tuple(d.get("pattern", ()))
        d["mapping"] = dict(d.get("mapping", {}))
        d["fingerprint"] = dict(d.get("fingerprint", {}))
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


def _slug(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]+", "_", key) or "plan"


class PlanStore:
    def __init__(self, root: str | pathlib.Path) -> None:
        self.root = pathlib.Path(root)

    def path_for(self, key: str) -> pathlib.Path:
        return self.root / f"{_slug(key)}.json"

    def keys(self) -> list[str]:
        if not self.root.is_dir():
            return []
        out = []
        for p in sorted(self.root.glob("*.json")):
            try:
                out.append(json.loads(p.read_text())["key"])
            except Exception:  # noqa: BLE001 — skip foreign/corrupt files
                continue
        return out

    def save(self, plan: Plan) -> pathlib.Path:
        self.root.mkdir(parents=True, exist_ok=True)
        path = self.path_for(plan.key)
        tmp = path.with_suffix(".json.tmp")
        tmp.write_text(json.dumps(plan.to_json(), indent=1, sort_keys=True))
        tmp.replace(path)  # atomic publish
        return path

    def load(
        self,
        key: str,
        fingerprint: Mapping[str, str] | None = None,
        match_fingerprint: bool = True,
    ) -> Plan | None:
        """Load a plan, or None when absent / verified under a different
        environment (so the caller falls back to a fresh search)."""
        path = self.path_for(key)
        if not path.exists():
            return None
        try:
            plan = Plan.from_json(json.loads(path.read_text()))
        except Exception:  # noqa: BLE001 — corrupt plan == no plan
            return None
        if plan.key != key:
            # distinct keys can slug to the same filename ('a:b' vs 'a_b');
            # never hand back a plan verified under a different key
            return None
        if match_fingerprint:
            current = dict(fingerprint) if fingerprint is not None else (
                environment_fingerprint()
            )
            # strict equality, both directions: a key only one side can
            # produce is a mismatch, not a wildcard.  Plan-side extras
            # mean hardware we can't even identify; current-side extras
            # mean the plan predates a fingerprint component (e.g. the
            # kernel-shelf hash) and could silently survive the very
            # change that component exists to detect.
            if dict(plan.fingerprint) != current:
                return None
        return plan


def plan_from_report(key: str, space_signature: str, report: Any) -> Plan:
    """Build a Plan from a strategies.PlanReport (kept here so stores can be
    used without importing the strategy layer)."""
    return Plan(
        key=key,
        space=space_signature,
        mapping=dict(report.best.mapping),
        pattern=tuple(report.best.pattern),
        baseline_seconds=report.baseline_seconds,
        best_seconds=report.best.seconds,
        speedup=report.best.speedup,
        strategy=report.strategy,
        evaluations=report.evaluations,
        search_seconds=report.search_seconds,
        fingerprint=environment_fingerprint(),
        created_unix=time.time(),
        objective=getattr(report, "objective", "latency"),
        best_energy_joules=getattr(report.best, "energy_joules", None),
        best_energy_provenance=getattr(report.best, "energy_provenance", None),
    )
