"""Chunked full-sequence attention — the memory-safe XLA formulation.

Flash-attention forward AND backward in jnp, with *static* chunk loops:
  * naive autodiff through attention stacks the full S^2 probability
    matrix per layer — the custom_vjp recomputes probability blocks in the
    backward from the saved (q, k, v, out, lse) instead;
  * chunk iteration is a Python loop over statically-sliced blocks, NOT a
    lax.scan over dynamic slices: GSPMD cannot partition a dynamic slice
    whose sliced axis is sharded and falls back to fully replicating the
    operand (hundreds of GB at 128 heads x 4k seq).  Static slices keep
    every block sharded.
Chunk size adapts so there are at most 8 chunks per axis (<=64 blocks).

This module lives on the kernel shelf (not in ``repro.models``) so the
``("attention", "xla")`` registration in :mod:`repro.kernels` is the one
source of truth — shelf snapshots no longer depend on whether
``repro.models.attention`` happened to be imported first.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_NEG = -1e30


def _chunks(s: int, target: int = 1024, max_chunks: int = 8) -> int:
    c = max(target, -(-s // max_chunks))
    c = min(c, s)
    while s % c:
        c += 1
    return c


# precision of the attention score blocks: "f32" (default) or "bf16"
# (halves the dominant HBM traffic of the XLA attention path; stats and
# accumulation stay f32) — a dry-run hillclimb knob.
CHUNKED_SCORES_DTYPE = "float32"


def _p_block(qc_scaled, lsec, kcf, qpos, kpos, causal):
    if CHUNKED_SCORES_DTYPE == "bfloat16":
        s = jnp.einsum(
            "bkgqd,bksd->bkgqs",
            qc_scaled.astype(jnp.bfloat16),
            kcf.astype(jnp.bfloat16),
            preferred_element_type=jnp.float32,
        )
    else:
        s = jnp.einsum("bkgqd,bksd->bkgqs", qc_scaled, kcf)
    if causal:
        mask = (qpos[:, None] >= kpos[None, :])[None, None, None]
        s = jnp.where(mask, s, _NEG)
    return s, jnp.exp(s - lsec[..., None])


def _chunked_fwd_core(q, k, v, causal: bool, q_chunk: int, kv_chunk: int):
    """Returns (out (B,KH,G,Sq,Dv) f32, lse (B,KH,G,Sq))."""
    b, h, sq, dk = q.shape
    _, kh, skv, dv = v.shape
    g = h // kh
    nq = sq // q_chunk
    nk = skv // kv_chunk
    scale = 1.0 / (dk ** 0.5)
    qg = q.reshape(b, kh, g, sq, dk)
    off = skv - sq  # align sequence ends (cached prefix)

    outs = []
    lses = []
    for qi in range(nq):
        qc = qg[:, :, :, qi * q_chunk : (qi + 1) * q_chunk, :]
        qc = qc.astype(jnp.float32) * scale
        qpos = off + qi * q_chunk + jnp.arange(q_chunk)
        m_acc = jnp.full((b, kh, g, q_chunk), _NEG, jnp.float32)
        l_acc = jnp.zeros((b, kh, g, q_chunk), jnp.float32)
        o_acc = jnp.zeros((b, kh, g, q_chunk, dv), jnp.float32)
        for ki in range(nk):
            if causal and ki * kv_chunk > off + (qi + 1) * q_chunk - 1:
                continue  # block fully above the diagonal
            kc = k[:, :, ki * kv_chunk : (ki + 1) * kv_chunk, :]
            vc = v[:, :, ki * kv_chunk : (ki + 1) * kv_chunk, :]
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            s, _ = _p_block(qc, jnp.zeros_like(m_acc), kc.astype(jnp.float32),
                            qpos, kpos, causal)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_acc, m_cur)
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_acc - m_new)
            l_acc = l_acc * alpha + jnp.sum(p, axis=-1)
            o_acc = o_acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p, vc.astype(jnp.float32)
            )
            m_acc = m_new
        l_safe = jnp.where(l_acc == 0.0, 1.0, l_acc)
        outs.append(o_acc / l_safe[..., None])
        lses.append(m_acc + jnp.log(l_safe))
    out = jnp.concatenate(outs, axis=3) if nq > 1 else outs[0]
    lse = jnp.concatenate(lses, axis=3) if nq > 1 else lses[0]
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _attention_chunked_core(q, k, v, causal, q_chunk, kv_chunk):
    out, _ = _chunked_fwd_core(q, k, v, causal, q_chunk, kv_chunk)
    b, h, sq, _ = q.shape
    return out.reshape(b, h, sq, -1).astype(q.dtype)


def _core_fwd(q, k, v, causal, q_chunk, kv_chunk):
    out, lse = _chunked_fwd_core(q, k, v, causal, q_chunk, kv_chunk)
    b, h, sq, _ = q.shape
    res = (q, k, v, out, lse)
    return out.reshape(b, h, sq, -1).astype(q.dtype), res


def _core_bwd(causal, q_chunk, kv_chunk, res, do):
    q, k, v, out, lse = res  # out/lse grouped (B,KH,G,Sq,*)
    b, h, sq, dk = q.shape
    _, kh, skv, dv = v.shape
    g = h // kh
    nq = sq // q_chunk
    nk = skv // kv_chunk
    scale = 1.0 / (dk ** 0.5)
    qg = q.reshape(b, kh, g, sq, dk).astype(jnp.float32)
    dog = do.reshape(b, kh, g, sq, dv).astype(jnp.float32)
    off = skv - sq
    dsum = jnp.sum(dog * out, axis=-1)  # (B,KH,G,Sq)

    dq_parts = []
    dk_parts = [jnp.zeros((b, kh, kv_chunk, dk), jnp.float32) for _ in range(nk)]
    dv_parts = [jnp.zeros((b, kh, kv_chunk, dv), jnp.float32) for _ in range(nk)]
    for qi in range(nq):
        sl = slice(qi * q_chunk, (qi + 1) * q_chunk)
        qc = qg[:, :, :, sl, :] * scale
        doc = dog[:, :, :, sl, :]
        lsec = lse[:, :, :, sl]
        dsc = dsum[:, :, :, sl]
        qpos = off + qi * q_chunk + jnp.arange(q_chunk)
        dq_acc = jnp.zeros((b, kh, g, q_chunk, dk), jnp.float32)
        for ki in range(nk):
            if causal and ki * kv_chunk > off + (qi + 1) * q_chunk - 1:
                continue
            ksl = slice(ki * kv_chunk, (ki + 1) * kv_chunk)
            kcf = k[:, :, ksl, :].astype(jnp.float32)
            vcf = v[:, :, ksl, :].astype(jnp.float32)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            _, p = _p_block(qc, lsec, kcf, qpos, kpos, causal)
            dp = jnp.einsum("bkgqd,bksd->bkgqs", doc, vcf)
            ds = p * (dp - dsc[..., None])
            dq_acc = dq_acc + jnp.einsum("bkgqs,bksd->bkgqd", ds, kcf) * scale
            dk_parts[ki] = dk_parts[ki] + jnp.einsum(
                "bkgqs,bkgqd->bksd", ds, qc
            )  # qc already carries the 1/sqrt(d) factor
            dv_parts[ki] = dv_parts[ki] + jnp.einsum("bkgqs,bkgqd->bksd", p, doc)
        dq_parts.append(dq_acc)

    dq = (jnp.concatenate(dq_parts, axis=3) if nq > 1 else dq_parts[0])
    dk_full = jnp.concatenate(dk_parts, axis=2) if nk > 1 else dk_parts[0]
    dv_full = jnp.concatenate(dv_parts, axis=2) if nk > 1 else dv_parts[0]
    return (
        dq.reshape(b, h, sq, dk).astype(q.dtype),
        dk_full.astype(k.dtype),
        dv_full.astype(v.dtype),
    )


_attention_chunked_core.defvjp(_core_fwd, _core_bwd)


def attention_chunked(
    q: jax.Array,  # (B, H, Sq, Dk)
    k: jax.Array,  # (B, KH, Skv, Dk)
    v: jax.Array,  # (B, KH, Skv, Dv)
    causal: bool = True,
    q_chunk: int | None = None,
    kv_chunk: int | None = None,
) -> jax.Array:
    sq = q.shape[2]
    skv = k.shape[2]
    q_chunk = q_chunk or _chunks(sq)
    kv_chunk = kv_chunk or _chunks(skv)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    if sq % q_chunk or skv % kv_chunk:
        raise ValueError("sequence lengths must tile by attention chunks")
    return _attention_chunked_core(q, k, v, causal, q_chunk, kv_chunk)
