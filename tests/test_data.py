"""Synthetic data pipeline: determinism, sharding, learnability signal."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import SyntheticLMData, host_local_slice


def test_deterministic_per_step():
    d = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    a = d.batch_at(7)
    b = d.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    d = SyntheticLMData(vocab_size=50, seq_len=8, global_batch=2)
    b = d.batch_at(0)
    # labels[t] is the next token after tokens[t] by construction
    assert b["tokens"].shape == b["labels"].shape == (2, 8)
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_tokens_in_vocab_range():
    d = SyntheticLMData(vocab_size=31, seq_len=64, global_batch=4)
    b = d.batch_at(5)
    for k in ("tokens", "labels"):
        assert b[k].min() >= 0 and b[k].max() < 31


@given(st.integers(1, 8))
@settings(max_examples=8, deadline=None)
def test_host_slices_partition_batch(n_hosts):
    d = SyntheticLMData(vocab_size=100, seq_len=4, global_batch=8 * n_hosts)
    b = d.batch_at(0)
    slices = [host_local_slice(b, h, n_hosts) for h in range(n_hosts)]
    rebuilt = np.concatenate([s["tokens"] for s in slices], axis=0)
    np.testing.assert_array_equal(rebuilt, b["tokens"])


def test_structure_is_learnable_signal():
    # with structure=1.0 the recurrence is exact: next token predictable
    d = SyntheticLMData(vocab_size=97, seq_len=32, global_batch=2, structure=1.0)
    b = d.batch_at(0)
    toks = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    # infer (a, c) from the first two transitions and verify the rest
    for row in toks:
        ok = 0
        for a in range(3, 23):
            c = (row[1] - row[0] * a) % 97
            if all((row[t - 1] * a + c) % 97 == row[t] for t in range(1, len(row))):
                ok = 1
                break
        assert ok


def test_embeds_batch_for_frontend_stub():
    d = SyntheticLMData(vocab_size=100, seq_len=8, global_batch=2)
    b = d.embeds_batch_at(0, d_model=16)
    assert b["embeds"].shape == (2, 8, 16)
    assert b["labels"].shape == (2, 8)
