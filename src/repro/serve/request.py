"""Request-level serving types: what callers submit and what they get back.

A ``Request`` is one generation job (prompt token ids + budget + sampling
overrides).  While it runs, the engine emits streaming ``Token`` events —
one per generated token, in generation order — and when it finishes (token
budget exhausted or stop token hit) a final ``Completion`` with the full
token list and latency breakdown.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.serve.sampler import Sampler


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation job.

    ``sampling=None`` inherits the engine's default sampler; ``seed=None``
    derives a per-request seed from the engine seed and the request id (so
    a replayed trace is reproducible without the caller choosing seeds).
    """

    prompt: tuple[int, ...]
    max_new_tokens: int = 16
    sampling: Sampler | None = None
    seed: int | None = None
    stop_token: int | None = None

    def __init__(
        self,
        prompt: Sequence[int],
        max_new_tokens: int = 16,
        sampling: Sampler | None = None,
        seed: int | None = None,
        stop_token: int | None = None,
    ) -> None:
        object.__setattr__(self, "prompt", tuple(int(t) for t in prompt))
        object.__setattr__(self, "max_new_tokens", int(max_new_tokens))
        object.__setattr__(self, "sampling", sampling)
        object.__setattr__(self, "seed", seed)
        object.__setattr__(self, "stop_token", stop_token)
        if not self.prompt:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclasses.dataclass(frozen=True)
class Token:
    """One streamed token event."""

    request_id: int
    token_id: int
    index: int  # position in the generated sequence (0 = first new token)
    phase: str  # "prefill" (the token sampled off the prompt) | "decode"
    engine_step: int  # engine step() call that produced it


@dataclasses.dataclass(frozen=True)
class Completion:
    """Terminal event for one request."""

    request_id: int
    prompt: tuple[int, ...]
    tokens: tuple[int, ...]
    finish_reason: str  # "length" | "stop"
    submitted_at: float  # engine clock (time.perf_counter) timestamps
    first_token_at: float
    finished_at: float
    #: when the scheduler last placed the request into a slot (None for
    #: completions built before the scheduler stamped it)
    admitted_at: float | None = None

    @property
    def ttft(self) -> float:
        """Time from submit to first token (includes the queue wait)."""
        return self.first_token_at - self.submitted_at

    @property
    def ttft_admitted(self) -> float:
        """Time from *admission* to first token — the model-side prefill
        latency with the scheduler's queue wait subtracted out.  Folding
        queue wait into TTFT hides scheduler effects; this is the number
        that isolates them."""
        return self.first_token_at - (
            self.admitted_at
            if self.admitted_at is not None
            else self.submitted_at
        )

    @property
    def queue_wait(self) -> float:
        """Time from submit to (the last) admission."""
        if self.admitted_at is None:
            return 0.0
        return self.admitted_at - self.submitted_at

    @property
    def latency(self) -> float:
        """Time from submit to the final token."""
        return self.finished_at - self.submitted_at


@dataclasses.dataclass
class RequestState:
    """Engine-internal per-request bookkeeping (one per active slot)."""

    request_id: int
    request: Request
    slot: int
    seed: int
    submitted_at: float
    first_token_at: float | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    #: monotonic admission order (preemption evicts the youngest first)
    admit_seq: int = -1
    #: when the request (re-)entered the waiting queue — submit time, or
    #: the preemption time after a requeue (feeds the "queue" trace span)
    queued_at: float = 0.0
    #: when the scheduler *first* placed the request into a slot (fixed
    #: across preemptions — feeds ``Completion.ttft_admitted``)
    admitted_at: float | None = None
    #: the most recent admission (re-stamped on resume — anchors the
    #: "prefill" trace span, which covers this admission's work only)
    last_admitted_at: float = 0.0

    @property
    def done(self) -> bool:
        if self.tokens and self.request.stop_token is not None and (
            self.tokens[-1] == self.request.stop_token
        ):
            return True
        return len(self.tokens) >= self.request.max_new_tokens

    @property
    def finish_reason(self) -> str:
        if self.request.stop_token is not None and self.tokens and (
            self.tokens[-1] == self.request.stop_token
        ):
            return "stop"
        return "length"
