"""Serving CLI — a thin driver over :class:`repro.serve.ServeEngine`.

Submits a mixed-length batch of random-token requests and drives the
engine until idle, printing throughput, latency and power telemetry:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --requests 8 --prompt-len 24 --len-jitter 8 --gen 16 --slots 4

Production startup binds previously verified offload plans (committed by
``repro.offload.zoo`` in a verification environment) per phase — prefill
and decode each trace under their own ``zoo:<arch>:<phase>`` plan:

  ... --plan-dir results/plans

``--plan-key`` forces one explicit key for both phases, ``--plan-search``
searches and commits missing zoo plans first (``--executor`` parallelises
the measurement), ``--meter`` adds real power telemetry with
measured/estimated provenance, and ``--sampler`` sets the default policy
(``greedy`` | ``temperature:0.8`` | ``top_k:40:0.8``).
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.configs import get_config
from repro.obs import Tracer
from repro.serve import Request, Sampler, ServeEngine


def percentile(xs: "list[float]", q: float) -> float:
    """Empty-safe quantile of a sample (shared with serve_load.py)."""
    if not xs:
        return float("nan")
    return float(np.percentile(xs, q * 100))


def format_kv_metrics(engine: ServeEngine) -> str:
    """One line of KV-memory health from ``engine.metrics()`` (shared with
    serve_load.py).  Stranded/utilization/fragmentation are means of one
    sample per engine step while requests were resident."""
    m = engine.metrics()
    kv = m["kv"]
    if m["mode"] == "paged":
        return (
            f"kv pool: {kv['n_pages']} x {kv['page_size']}-token pages, "
            f"peak {kv['peak_used_pages']} used "
            f"({100.0 * kv['peak_used_pages'] / kv['n_pages']:.0f}% peak, "
            f"{m['mean_utilization_pct']:.1f}% mean utilization), "
            f"stranded {m['mean_stranded_pct']:.1f}%, "
            f"fragmentation {m['mean_fragmentation_pct']:.1f}%, "
            f"{m['preemptions']} preemptions, "
            f"{m['prefill_chunks']} prefill chunks"
        )
    return (
        f"kv cache: contiguous {m['n_slots']} x {m['max_len']} "
        f"({kv['token_capacity']} tokens reserved worst-case), "
        f"{m['mean_utilization_pct']:.1f}% mean slot utilization, "
        f"stranded {m['mean_stranded_pct']:.1f}% of reserved, "
        f"{m['prefill_chunks']} prefill chunks"
    )


def build_engine(args: argparse.Namespace) -> ServeEngine:
    """Engine construction shared with ``benchmarks/serve_load.py``."""
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    plan_keys: dict[str, str | None] | str | None = None
    if args.plan_key:
        plan_keys = args.plan_key
    elif args.plan_dir and args.plan_search:
        from repro.offload.zoo import launch_plan_keys

        plan_keys = launch_plan_keys(
            args.plan_dir,
            args.arch,
            ("prefill", "decode"),
            search=True,
            targets=tuple(args.plan_targets.split(",")),
            executor=args.executor,
            meter=args.meter,
        )
    # --trace-out turns the request-lifecycle tracer on for this engine;
    # without it the engine inherits the (disabled) process tracer and
    # tracing costs one attribute check per hot-path site
    tracer = Tracer() if getattr(args, "trace_out", None) else None
    return ServeEngine(
        cfg,
        n_slots=args.slots,
        max_len=args.max_len,
        sampler=Sampler.parse(args.sampler),
        meter=args.meter,
        plan_dir=args.plan_dir,
        plan_keys=plan_keys,
        max_tokens_per_step=args.step_budget,
        prefill_bucket=args.prefill_bucket,
        prefill_chunk=args.prefill_chunk,
        page_size=args.page_size,
        n_pages=args.n_pages,
        decode_impl=args.decode_impl,
        kv_validate=args.kv_validate,
        tracer=tracer,
        seed=args.seed,
        quiet=False,
    )


def write_obs_outputs(engine: ServeEngine, args: argparse.Namespace) -> None:
    """Write the observability artifacts the CLI asked for: a Chrome/
    Perfetto trace (``--trace-out``, loadable at ui.perfetto.dev) and a
    Prometheus text snapshot of the engine registry (``--metrics-out``)."""
    if getattr(args, "trace_out", None):
        engine.tracer.write_chrome(args.trace_out)
        print(f"trace written: {args.trace_out} "
              f"({len(engine.tracer)} records; inspect with "
              f"python -m repro.obs.timeline {args.trace_out})")
    if getattr(args, "metrics_out", None):
        with open(args.metrics_out, "w") as f:
            f.write(engine.registry.render_prometheus())
        print(f"metrics written: {args.metrics_out}")


def make_requests(
    cfg, args: argparse.Namespace, rng: np.random.Generator
) -> list[Request]:
    """Mixed-length random-token trace: prompt/generation lengths jitter
    uniformly around the base values so slots stagger and free at
    different steps (the continuous-batching case, not the static batch)."""
    requests = []
    for _ in range(args.requests):
        plen = max(1, args.prompt_len + int(rng.integers(
            -args.len_jitter, args.len_jitter + 1
        )))
        gen = max(1, args.gen + int(rng.integers(
            -args.gen_jitter, args.gen_jitter + 1
        )))
        prompt = rng.integers(0, cfg.vocab_size, plen).tolist()
        requests.append(Request(prompt, max_new_tokens=gen))
    return requests


def add_engine_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4,
                    help="KV slots = max concurrent requests")
    ap.add_argument("--max-len", type=int, default=256,
                    help="cache positions per slot (prompt + generation)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sampler", default="greedy",
                    help="default sampling policy: greedy | "
                         "temperature:<t> | top_k:<k>[:<t>]")
    ap.add_argument("--step-budget", type=int, default=None,
                    help="max tokens (prefill + decode) one engine step "
                         "may process — bounds prefill-induced decode "
                         "stalls under bursty arrivals")
    ap.add_argument("--prefill-bucket", type=int, default=None,
                    help="pad prompts to a multiple of this bucket so "
                         "prefill traces are shared across lengths "
                         "(attention-family archs only)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="split prompts longer than this into chunk-sized "
                         "prefill pieces interleaved with decode steps "
                         "(flattens the p99 TTFT spike; attention-family "
                         "archs only)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="block-paged KV cache: tokens per page (default: "
                         "contiguous max_len slots)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="KV pool size in pages (default: capacity-"
                         "equivalent, slots * ceil(max_len/page_size); "
                         "smaller over-commits — preemption reclaims)")
    ap.add_argument("--decode-impl", default="auto",
                    choices=("auto", "xla", "pallas"),
                    help="pin the paged_attention binding for the decode "
                         "hot loop (requires --page-size): xla = rolled "
                         "page-walk gather, pallas = fused page-walk "
                         "kernel (interpret-mode off-TPU); auto defers to "
                         "the stored decode plan / default preference")
    ap.add_argument("--kv-validate", action="store_true",
                    help="run the repro.analysis page-aliasing sanitizer "
                         "after every page-table mutation (debug mode; "
                         "raises on aliasing or accounting drift)")
    ap.add_argument("--plan-dir", default=None,
                    help="PlanStore directory with verified offload plans")
    ap.add_argument("--plan-key", default=None,
                    help="explicit plan key bound to BOTH phases; default "
                         "is the stored zoo:<arch>:prefill / :decode plans")
    ap.add_argument("--plan-search", action="store_true",
                    help="search+commit missing zoo plans for this arch "
                         "before binding (verification-environment step)")
    ap.add_argument("--plan-targets", default="ref,xla",
                    help="targets --plan-search searches over "
                         "(add 'pallas' on TPU hosts)")
    ap.add_argument("--executor", default="serial",
                    help="measurement executor for --plan-search: serial | "
                         "device-parallel | batched")
    ap.add_argument("--meter", default="none",
                    help="power telemetry: none | auto | time | nvml | "
                         "rapl | psutil | tpu")
    ap.add_argument("--trace-out", default=None,
                    help="enable request-lifecycle tracing and write a "
                         "Chrome/Perfetto trace_event JSON here (inspect "
                         "with ui.perfetto.dev or repro.obs.timeline)")
    ap.add_argument("--metrics-out", default=None,
                    help="write a Prometheus text snapshot of the engine "
                         "metrics registry here after the run")
    ap.add_argument("--envelope", default=None,
                    help="device envelope for capacity checks: a static "
                         "name (a100-40g, cpu-host-16g, tiny-32m, ...) or "
                         "'host' to probe the live device (default)")


def preflight(args: argparse.Namespace) -> int:
    """Static capacity check of the requested deployment — the paper's
    FPGA resource-fit gate applied before engine boot.  Sizes params +
    KV cache from metadata (nothing is materialised, so full-size
    configs check in milliseconds) against ``--envelope`` and refuses to
    proceed when they cannot fit.  Returns a process exit code: 0 fits,
    2 does not."""
    from repro.analysis.resources import plan_serve_capacity

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    plan = plan_serve_capacity(
        cfg,
        n_slots=args.slots,
        max_len=args.max_len,
        page_size=args.page_size,
        n_pages=args.n_pages,
        envelope=args.envelope,
    )
    print(plan.summary())
    if (
        args.prefill_chunk
        and plan.max_prefill_tokens is not None
        and args.prefill_chunk > plan.max_prefill_tokens
    ):
        print(
            f"preflight: note --prefill-chunk {args.prefill_chunk} exceeds "
            f"the activation-headroom bound ({plan.max_prefill_tokens})",
            file=sys.stderr,
        )
    if not plan.fits:
        print(
            f"preflight: FAIL — {plan.arch} with {plan.n_slots} slots x "
            f"{plan.max_len} tokens does not fit {plan.envelope.name}",
            file=sys.stderr,
        )
        return 2
    print("preflight: OK")
    return 0


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser()
    add_engine_args(ap)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--len-jitter", type=int, default=8,
                    help="uniform prompt-length jitter (staggers slots)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--gen-jitter", type=int, default=4)
    ap.add_argument("--max-steps", type=int, default=10_000)
    ap.add_argument("--preflight", action="store_true",
                    help="static capacity check only: size params + KV "
                         "against --envelope and exit (0 fits, 2 not) "
                         "without booting the engine")
    args = ap.parse_args(argv)

    if args.preflight:
        return preflight(args)

    engine = build_engine(args)
    rng = np.random.default_rng(args.seed)
    requests = make_requests(engine.cfg, args, rng)
    for request in requests:
        engine.submit(request)
    completions = engine.run_until_idle(max_steps=args.max_steps)

    stats = engine.stats
    assert stats.requests_completed == len(requests), (
        f"{stats.requests_completed}/{len(requests)} requests completed"
    )
    print(f"arch={engine.cfg.name} slots={args.slots} "
          f"requests={len(requests)}")
    for phase in ("prefill", "decode"):
        print(engine.telemetry[phase].summary())
    latencies = [c.latency for c in completions]
    ttfts = [c.ttft for c in completions]
    ttfts_admitted = [c.ttft_admitted for c in completions]
    queue_waits = [c.queue_wait for c in completions]
    print(
        f"latency: p50 {percentile(latencies, 0.5)*1e3:.1f} ms "
        f"p99 {percentile(latencies, 0.99)*1e3:.1f} ms | "
        f"ttft: p50 {percentile(ttfts, 0.5)*1e3:.1f} ms "
        f"p99 {percentile(ttfts, 0.99)*1e3:.1f} ms"
    )
    # ttft folds the scheduler's queue wait in; the admitted variant is
    # the model-side prefill latency with that wait subtracted out
    print(
        f"ttft from admit: p50 {percentile(ttfts_admitted, 0.5)*1e3:.1f} ms "
        f"p99 {percentile(ttfts_admitted, 0.99)*1e3:.1f} ms | "
        f"queue wait: p50 {percentile(queue_waits, 0.5)*1e3:.1f} ms "
        f"p99 {percentile(queue_waits, 0.99)*1e3:.1f} ms"
    )
    print(
        f"continuous batching: {stats.slot_reuses} slot reuses, "
        f"max {stats.max_active} concurrent, {stats.steps} engine steps, "
        f"decode median {engine.monitor.median_step()*1e3:.2f} ms/step"
    )
    print(format_kv_metrics(engine))
    sample = completions[0]
    print(f"sample (request {sample.request_id}):",
          np.asarray(sample.tokens[:16]))
    write_obs_outputs(engine, args)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
