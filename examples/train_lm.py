"""End-to-end training driver example: train a ~100M-param llama-family
model for a few hundred steps on the synthetic pipeline, with checkpointing
and fault tolerance active.  (Reduced width/depth so it runs on this CPU
container; the identical driver takes --arch <any of the 10> and the
production mesh on hardware.)

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None,
                    help="default: /tmp/repro_train_lm_<config> (scoped so "
                         "runs with different shapes never cross-restore)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLMData
    from repro.checkpoint.manager import CheckpointManager
    from repro.launch.steps import TrainHyper, make_train_step
    from repro.models import lm
    from repro.models import params as pm
    from repro.optim.adamw import AdamW
    from repro.runtime.fault import FaultTolerantLoop
    from repro.runtime.monitor import StepMonitor

    cfg = dataclasses.replace(
        get_config("llama3.2-1b"),
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=8,
        n_kv_heads=4,
        d_head=args.d_model // 8,
        d_ff=args.d_model * 4,
        vocab_size=2048,
    )
    if args.ckpt_dir is None:
        args.ckpt_dir = (
            f"/tmp/repro_train_lm_d{args.d_model}_l{args.layers}_s{args.seq}"
        )
    n_params = pm.count_params(lm.build_metas(cfg))
    print(f"model: {cfg.name} reduced, {n_params/1e6:.1f}M params")

    data = SyntheticLMData(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, structure=1.0,
    )
    opt = AdamW(weight_decay=0.01)
    step_jit = jax.jit(
        make_train_step(
            cfg, opt,
            TrainHyper(base_lr=2e-3, warmup_steps=15, total_steps=args.steps),
        ),
        donate_argnums=(0, 1),
    )
    params = lm.init_params(cfg, seed=0)
    state = {"params": params, "opt": opt.init(params)}
    monitor = StepMonitor()
    losses = []

    def step_fn(state, batch, step):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, m = step_jit(state["params"], state["opt"], b)
        losses.append(float(m["loss"]))
        if step % 20 == 0:
            print(f"  step {step:4d}  loss {losses[-1]:.4f}", flush=True)
        return {"params": p, "opt": o}

    loop = FaultTolerantLoop(
        step_fn=step_fn, batch_fn=data.batch_at,
        ckpt=CheckpointManager(args.ckpt_dir, keep=2),
        ckpt_every=100, monitor=monitor,
    )
    t0 = time.time()
    res = loop.run(state, args.steps)
    dt = time.time() - t0
    print(
        f"trained {res.completed_steps} steps in {dt:.0f}s "
        f"({args.steps*args.batch*args.seq/dt:.0f} tok/s); "
        f"loss {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f}"
    )
    assert np.mean(losses[-10:]) < np.mean(losses[:10])
    print("loss decreased: OK")


if __name__ == "__main__":
    sys.exit(main())
