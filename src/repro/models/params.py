"""Parameter metadata: single source of truth for shapes, dtypes, logical
sharding axes and initialisation of every model parameter.

``build_*_metas`` functions return nested dicts of ParamMeta; from one meta
tree we derive (i) materialised parameters, (ii) PartitionSpec trees for any
mesh/rules, (iii) ShapeDtypeStructs for the dry-run — guaranteed consistent.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.sharding.utils import resolve_spec


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis names, len == len(shape)
    dtype: str = "float32"
    init: str = "normal"  # "normal" | "zeros" | "ones" | "ssm_a" | "dt_bias"
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_meta(x: Any) -> bool:
    return isinstance(x, ParamMeta)


def tree_map_metas(fn: Callable[[ParamMeta], Any], tree: Any) -> Any:
    return jax.tree.map(fn, tree, is_leaf=is_meta)


def abstract_params(metas: Any) -> Any:
    return tree_map_metas(
        lambda m: jax.ShapeDtypeStruct(m.shape, jnp.dtype(m.dtype)), metas
    )


def spec_tree(metas: Any, rules: dict[str, Any]) -> Any:
    return tree_map_metas(lambda m: resolve_spec(m.axes, rules), metas)


def _init_leaf(m: ParamMeta, key: jax.Array) -> jax.Array:
    dt = jnp.dtype(m.dtype)
    if m.init == "zeros":
        return jnp.zeros(m.shape, dt)
    if m.init == "ones":
        return jnp.ones(m.shape, dt)
    if m.init == "ssm_a":  # A_log: log of uniform [1, 16)
        u = jax.random.uniform(key, m.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dt)
    if m.init == "dt_bias":  # softplus^-1 of dt in [1e-3, 1e-1]
        u = jax.random.uniform(key, m.shape, jnp.float32)
        dtv = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
        inv = dtv + jnp.log(-jnp.expm1(-dtv))
        return inv.astype(dt)
    return (jax.random.normal(key, m.shape, jnp.float32) * m.scale).astype(dt)


def init_params(metas: Any, seed: int = 0) -> Any:
    leaves, treedef = jax.tree.flatten(metas, is_leaf=is_meta)
    keys = jax.random.split(jax.random.PRNGKey(seed), max(len(leaves), 1))
    vals = [_init_leaf(m, k) for m, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def count_params(metas: Any) -> int:
    leaves = jax.tree.leaves(metas, is_leaf=is_meta)
    return sum(math.prod(m.shape) for m in leaves)


def param_bytes(metas: Any) -> int:
    leaves = jax.tree.leaves(metas, is_leaf=is_meta)
    return sum(math.prod(m.shape) * jnp.dtype(m.dtype).itemsize for m in leaves)
