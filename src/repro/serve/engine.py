"""ServeEngine — request-level serving with continuous batching.

The engine turns the model zoo's prefill/decode steps into a *service*:
callers ``submit()`` :class:`Request` objects at any time, drive the engine
with ``step()`` (one scheduling round: admit waiting requests into free KV
slots, then one fused decode step for every active slot) or
``run_until_idle()``, and consume streaming :class:`Token` events plus a
final :class:`Completion` per request.

Design points, each load-bearing for the paper's "committed pattern in
operation" end state:

* **Continuous batching** — the KV cache is ``n_slots`` batch rows with
  *per-slot* write positions (``cache["index"]`` is (B,)); finished
  requests free their slot mid-flight and the next waiting request is
  prefilled straight into it while the other slots keep decoding.  A
  token budget (:class:`repro.serve.scheduler.Scheduler`) bounds how much
  prefill work any single step may inject ahead of the in-flight decodes.
* **Plan-aware phase dispatch** — prefill and decode are *different
  programs* with different winning offload patterns, so each phase is
  traced under its own committed plan (``zoo:<arch>:prefill`` /
  ``zoo:<arch>:decode`` from a :class:`PlanStore`), bound with zero
  re-measurement exactly like ``OffloadSession.attach``.
* **Fused sampling** — logits never leave the device: the jitted phase
  programs end in :func:`repro.serve.sampler.sample_tokens`, so the
  per-step host transfer is (B,) token ids, not (B, V) logits.
* **Telemetry** — every phase call runs under ``metering.meter_window``
  and aggregates into per-phase :class:`PhaseTelemetry` (seconds, joules,
  measured/estimated provenance); the decode loop feeds a
  ``runtime.StepMonitor`` for throughput and straggler stats.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.core import blocks as blocks_mod
from repro.metering import meter_window, resolve_meter
from repro.metering.meters import WindowTelemetry
from repro.models import lm
from repro.offload import stored_binding
from repro.runtime.monitor import StepMonitor
from repro.serve.request import Completion, Request, RequestState, Token
from repro.serve.sampler import Sampler, sample_tokens
from repro.serve.scheduler import Scheduler

PHASES = ("prefill", "decode")


@dataclasses.dataclass
class PhaseTelemetry:
    """Aggregate of every ``meter_window`` a phase ran under."""

    phase: str
    calls: int = 0
    seconds: float = 0.0
    tokens: int = 0
    joules: float | None = None
    provenance: str | None = None

    def add(self, tele: WindowTelemetry, tokens: int) -> None:
        self.calls += 1
        self.seconds += tele.seconds
        self.tokens += tokens
        if tele.joules is not None:
            self.joules = (self.joules or 0.0) + tele.joules
            self.provenance = tele.provenance

    @property
    def tokens_per_second(self) -> float:
        return self.tokens / self.seconds if self.seconds else 0.0

    @property
    def joules_per_token(self) -> float | None:
        if self.joules is None or not self.tokens:
            return None
        return self.joules / self.tokens

    def summary(self) -> str:
        out = (
            f"{self.phase}: {self.tokens} tok in {self.seconds:.2f}s "
            f"({self.tokens_per_second:.1f} tok/s, {self.calls} calls)"
        )
        if self.joules is not None:
            out += (
                f", {self.joules:.1f} J"
                f" [{self.joules_per_token:.3g} J/tok, {self.provenance}]"
            )
        return out


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """One engine lifetime in numbers."""

    steps: int
    requests_submitted: int
    requests_completed: int
    prefill_calls: int
    decode_steps: int
    tokens_generated: int
    slot_reuses: int
    max_active: int


class ServeEngine:
    """Request-level serving engine over the block-pattern LM.

    ``cfg`` is an :class:`ArchConfig` (or an arch name, resolved through
    ``get_config``).  ``plan_dir``/``plan_keys`` bind each phase to a
    committed offload plan: with ``plan_dir`` alone the stored
    ``zoo:<arch>:prefill`` / ``zoo:<arch>:decode`` plans apply when
    present (and compatible with this environment); ``plan_keys`` may name
    explicit keys per phase or one key for both.  ``sampler`` is the
    default :class:`Sampler` for requests that don't carry their own.
    ``meter`` (name or ``PowerMeter``) adds per-phase energy telemetry.

    ``prefill_bucket`` pads prompts up to a multiple of the bucket so
    prefill traces are shared across prompt lengths — attention-family
    archs only (padded tokens would corrupt a recurrent SSM state; the
    padded KV rows here are provably never attended: each decode step
    overwrites position ``index`` before the mask ever admits it).
    """

    def __init__(
        self,
        cfg: ArchConfig | str,
        *,
        params: Any = None,
        n_slots: int = 4,
        max_len: int = 256,
        sampler: Sampler | None = None,
        meter: Any = None,
        plan_dir: str | None = None,
        plan_keys: "dict[str, str | None] | str | None" = None,
        max_tokens_per_step: int | None = None,
        prefill_bucket: int | None = None,
        monitor: StepMonitor | None = None,
        seed: int = 0,
        quiet: bool = True,
    ) -> None:
        if isinstance(cfg, str):
            cfg = get_config(cfg)
        if cfg.frontend == "patch_embed":
            raise ValueError(
                f"{cfg.name}: patch-embed frontends have no token prompt "
                "path; the serving engine takes token-id requests"
            )
        if prefill_bucket is not None and "m" in cfg.pattern():
            raise ValueError(
                "prefill_bucket pads prompts, which corrupts recurrent SSM "
                f"state — unsupported for '{cfg.name}' "
                f"(pattern {cfg.pattern()!r})"
            )
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.sampler = sampler or Sampler.greedy()
        self.meter = resolve_meter(meter)
        self.seed = seed
        self.quiet = quiet
        self.prefill_bucket = prefill_bucket
        self.monitor = monitor or StepMonitor()
        self.scheduler = Scheduler(
            n_slots,
            max_tokens_per_step,
            prompt_cost=lambda n: self._padded_len(n),
        )

        self.params = (
            params if params is not None else lm.init_params(cfg, seed=seed)
        )
        self.cache = lm.init_cache(cfg, n_slots, max_len)

        # -- plan-aware phase dispatch ------------------------------------
        # keys the caller named explicitly must fail loudly when they
        # cannot bind (mirrors resolve_meter: an explicit request is a
        # contract, not a hint); store-derived defaults degrade silently
        explicit = plan_keys is not None
        if explicit and not plan_dir:
            raise ValueError(
                "plan_keys given without plan_dir — both are required to "
                "bind a committed plan"
            )
        self.plan_keys = self._resolve_plan_keys(plan_dir, plan_keys)
        self._bindings: dict[str, dict[str, str] | None] = {}
        for phase in PHASES:
            key = self.plan_keys[phase]
            mapping = (
                stored_binding(plan_dir, key)
                if plan_dir and key
                else None
            )
            if key and mapping is None:
                if explicit:
                    raise ValueError(
                        f"plan '{key}' for phase '{phase}' not "
                        f"found/compatible in {plan_dir}"
                    )
                if not quiet:
                    print(
                        f"serve: plan '{key}' not found/compatible in "
                        f"{plan_dir}; {phase} runs on default bindings"
                    )
            elif mapping and not quiet:
                print(f"serve: {phase} bound to plan '{key}': {mapping}")
            self._bindings[phase] = mapping

        # the cache arguments are donated: the old cache is dead the moment
        # a step returns its successor, and without donation every decode
        # step / admission would copy the full multi-layer KV cache
        self._prefill_fn = jax.jit(self._build_prefill())
        self._decode_fn = jax.jit(self._build_decode(), donate_argnums=(2,))
        self._insert_fn = jax.jit(self._insert_slot, donate_argnums=(0,))

        # host-side per-slot state mirrors (pushed each decode step)
        self._last_tok = np.zeros((n_slots, 1), np.int32)
        self._seeds = np.zeros((n_slots,), np.int32)
        self._gen_counts = np.zeros((n_slots,), np.int32)
        self._temps = np.zeros((n_slots,), np.float32)
        self._topks = np.zeros((n_slots,), np.int32)

        self.telemetry = {p: PhaseTelemetry(p) for p in PHASES}
        self.completions: dict[int, Completion] = {}
        self._finished: list[Completion] = []
        self._next_id = 0
        self._submitted = 0
        self._steps = 0
        self._max_active = 0

    # -- plan resolution ------------------------------------------------------
    def _resolve_plan_keys(
        self,
        plan_dir: str | None,
        plan_keys: "dict[str, str | None] | str | None",
    ) -> dict[str, str | None]:
        if isinstance(plan_keys, str):
            return {p: plan_keys for p in PHASES}
        if plan_keys is not None:
            unknown = set(plan_keys) - set(PHASES)
            if unknown:
                raise KeyError(
                    f"unknown serve phases {sorted(unknown)}; known: {PHASES}"
                )
            return {p: plan_keys.get(p) for p in PHASES}
        if plan_dir:
            from repro.offload.zoo import default_plan_key

            # zoo plans are keyed by the *base* arch — a reduced config
            # (verification-environment shape) binds the same plans
            arch = self.cfg.name.removesuffix("-reduced")
            return {
                p: default_plan_key(plan_dir, arch, p) for p in PHASES
            }
        return {p: None for p in PHASES}

    def _phase(self, phase: str):
        mapping = self._bindings.get(phase)
        if not mapping:
            return contextlib.nullcontext()
        return blocks_mod.registry.bind(mapping)

    # -- jitted programs -------------------------------------------------------
    def _build_prefill(self):
        cfg = self.cfg
        cache_metas = lm.cache_metas_tree(cfg, 1, self.max_len)

        def prefill_fn(params, tokens, last_idx, seed, temp, topk):
            """tokens (1, Lp) -> (first sampled token (1,), filled b1 cache).

            The zero cache is built *inside* the program (XLA fuses it to
            nothing), only the *last real position*'s hidden state reaches
            the head — the (1, Lp, V) logits tensor is never materialised
            — and padded bucket positions past ``last_idx`` are ignored.
            """
            from repro.models import params as pm

            cache = pm.init_params(cache_metas, 0)
            x, _, new_cache = lm.backbone(
                params, {"tokens": tokens}, cfg, "prefill", cache
            )
            x_last = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
            logits = lm.head(params, x_last, cfg)[:, 0, : cfg.vocab_size]
            tok = sample_tokens(
                logits,
                seed[None],
                jnp.zeros((1,), jnp.int32),
                temp[None],
                topk[None],
            )
            new_cache["index"] = (last_idx + 1)[None].astype(jnp.int32)
            return tok, new_cache

        return prefill_fn

    def _build_decode(self):
        cfg = self.cfg

        def decode_fn(params, tokens, cache, seeds, steps, temps, topks):
            """One fused (logits -> token) step for the whole slot batch."""
            logits, new_cache = lm.decode_step(params, tokens, cfg, cache)
            tok = sample_tokens(
                logits[:, 0, : cfg.vocab_size], seeds, steps, temps, topks
            )
            return tok, new_cache

        return decode_fn

    @staticmethod
    def _insert_slot(cache, b1_cache, slot):
        """Write a batch-1 prefilled cache into slot ``slot`` of the engine
        cache.  Group leaves are (layers, B, ...); ``index`` is (B,)."""
        out = {}
        for key, value in cache.items():
            if key == "index":
                out[key] = value.at[slot].set(b1_cache[key][0])
            else:
                out[key] = jax.tree.map(
                    lambda dst, src: dst.at[:, slot].set(src[:, 0]),
                    value,
                    b1_cache[key],
                )
        return out

    # -- public API ------------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request; returns its request id.  Admission happens on a
        subsequent ``step()`` when a slot and token budget are available."""
        total = len(request.prompt) + request.max_new_tokens
        if total > self.max_len:
            raise ValueError(
                f"request needs {total} cache positions "
                f"(prompt {len(request.prompt)} + {request.max_new_tokens} "
                f"new) but slots hold max_len={self.max_len}"
            )
        request_id = self._next_id
        self._next_id += 1
        self._submitted += 1
        seed = (
            request.seed
            if request.seed is not None
            else (self.seed * 1_000_003 + request_id) & 0x7FFFFFFF
        )
        self.scheduler.enqueue(
            RequestState(
                request_id=request_id,
                request=request,
                slot=-1,
                seed=seed,
                submitted_at=time.perf_counter(),
            )
        )
        return request_id

    def step(self) -> list[Token | Completion]:
        """One scheduling round: admissions (a prefill each), then one fused
        decode step over every active slot.  Returns the streamed events —
        ``Token`` per generated token, ``Completion`` per finished request
        — in generation order."""
        if not self.scheduler.has_work:
            return []
        self._steps += 1
        events: list[Token | Completion] = []
        admitted = self.scheduler.admissions()
        # concurrency peaks right after admission, before same-step
        # finishes release their slots — sample it here, not at step end
        self._max_active = max(self._max_active, len(self.scheduler.active))
        for state in admitted:
            events.extend(self._admit(state))
        if self.scheduler.active:
            events.extend(self._decode_active())
        return events

    def run_until_idle(self, max_steps: int | None = None) -> list[Completion]:
        """Drive ``step()`` until every submitted request has completed;
        returns the completions in finish order."""
        start = len(self._finished)
        steps = 0
        while self.scheduler.has_work:
            self.step()
            steps += 1
            if max_steps is not None and steps > max_steps:
                raise RuntimeError(
                    f"engine still busy after {max_steps} steps "
                    f"({len(self.scheduler.active)} active, "
                    f"{len(self.scheduler.waiting)} waiting)"
                )
        return self._finished[start:]

    def stream(
        self, requests: Iterable[Request]
    ) -> "Iterable[Token | Completion]":
        """Submit ``requests`` and yield events until idle (convenience)."""
        for request in requests:
            self.submit(request)
        while self.scheduler.has_work:
            yield from self.step()

    def reset_stats(self) -> None:
        """Zero every lifetime counter — telemetry, monitor, scheduler
        reuse accounting, completions — without touching the compiled
        programs or the cache.  For load generators that warm the traces
        up front and must not report the warmup as served traffic.  Only
        valid on an idle engine (no active or waiting requests)."""
        if self.scheduler.has_work:
            raise RuntimeError("reset_stats on a busy engine")
        self.telemetry = {p: PhaseTelemetry(p) for p in PHASES}
        self.monitor = StepMonitor(
            window=self.monitor.window.maxlen or 32,
            threshold=self.monitor.threshold,
            patience=self.monitor.patience,
            on_straggler=self.monitor.on_straggler,
        )
        self.scheduler.admitted_per_slot.clear()
        self.completions.clear()
        self._finished.clear()
        self._submitted = 0
        self._steps = 0
        self._max_active = 0

    @property
    def stats(self) -> EngineStats:
        return EngineStats(
            steps=self._steps,
            requests_submitted=self._submitted,
            requests_completed=len(self._finished),
            prefill_calls=self.telemetry["prefill"].calls,
            decode_steps=self.telemetry["decode"].calls,
            tokens_generated=sum(
                len(c.tokens) for c in self._finished
            ) + sum(
                len(s.tokens) for s in self.scheduler.active.values()
            ),
            slot_reuses=self.scheduler.slot_reuses,
            max_active=self._max_active,
        )

    # -- phase execution -------------------------------------------------------
    def _padded_len(self, length: int) -> int:
        if self.prefill_bucket:
            bucket = self.prefill_bucket
            length = min(-(-length // bucket) * bucket, self.max_len)
        return length

    def _padded_prompt(self, prompt: Sequence[int]) -> np.ndarray:
        out = np.zeros((1, self._padded_len(len(prompt))), np.int32)
        out[0, : len(prompt)] = prompt
        return out

    def _request_knobs(self, state: RequestState) -> tuple[float, int]:
        return (state.request.sampling or self.sampler).knobs

    def _admit(self, state: RequestState) -> list[Token | Completion]:
        request = state.request
        temp, topk = self._request_knobs(state)
        tokens = self._padded_prompt(request.prompt)
        with self._phase("prefill"), meter_window(self.meter) as tele:
            tok, b1_cache = self._prefill_fn(
                self.params,
                jnp.asarray(tokens),
                jnp.asarray(len(request.prompt) - 1, jnp.int32),
                jnp.asarray(state.seed, jnp.int32),
                jnp.asarray(temp, jnp.float32),
                jnp.asarray(topk, jnp.int32),
            )
            self.cache = self._insert_fn(
                self.cache, b1_cache, jnp.asarray(state.slot, jnp.int32)
            )
            first = int(np.asarray(tok)[0])  # blocks inside the meter window
        self.telemetry["prefill"].add(tele, len(request.prompt))

        slot = state.slot
        self._last_tok[slot, 0] = first
        self._seeds[slot] = state.seed
        self._gen_counts[slot] = 1
        self._temps[slot] = temp
        self._topks[slot] = topk
        state.first_token_at = time.perf_counter()
        state.tokens.append(first)
        events: list[Token | Completion] = [
            Token(state.request_id, first, 0, "prefill", self._steps)
        ]
        if state.done:
            events.append(self._finish(slot))
        return events

    def _decode_active(self) -> list[Token | Completion]:
        active = dict(self.scheduler.active)  # slot -> state
        self.monitor.start()
        with self._phase("decode"), meter_window(self.meter) as tele:
            tok, self.cache = self._decode_fn(
                self.params,
                jnp.asarray(self._last_tok),
                self.cache,
                jnp.asarray(self._seeds),
                jnp.asarray(self._gen_counts),
                jnp.asarray(self._temps),
                jnp.asarray(self._topks),
            )
            toks = np.asarray(tok)  # the only device->host transfer: (B,)
        self.monitor.stop(self._steps)
        self.telemetry["decode"].add(tele, len(active))

        events: list[Token | Completion] = []
        for slot, state in active.items():
            token = int(toks[slot])
            self._last_tok[slot, 0] = token
            self._gen_counts[slot] += 1
            index = len(state.tokens)
            state.tokens.append(token)
            events.append(
                Token(state.request_id, token, index, "decode", self._steps)
            )
            if state.done:
                events.append(self._finish(slot))
        return events

    def _finish(self, slot: int) -> Completion:
        state = self.scheduler.release(slot)
        self._gen_counts[slot] = 0
        completion = Completion(
            request_id=state.request_id,
            prompt=state.request.prompt,
            tokens=tuple(state.tokens),
            finish_reason=state.finish_reason,
            submitted_at=state.submitted_at,
            first_token_at=state.first_token_at or time.perf_counter(),
            finished_at=time.perf_counter(),
        )
        self.completions[state.request_id] = completion
        self._finished.append(completion)
        return completion
