"""Paged-attention microbenchmark: fused page walk vs gathered view.

Sweeps page sizes at a fixed decode shape and reports, per
``(page_size, impl)`` cell, the measured step latency and the static
memory envelope (``repro.analysis.resources.estimate_memory``) of a
jitted single-block decode call.  The gather (XLA) path is always timed
on the local backend; the fused Pallas kernel is timed only where it can
actually run — on a TPU, or in interpret mode when ``--interpret`` is
passed (orders of magnitude slower; parity checking only, not a
performance number).  The static estimates are platform-independent, so
the peak-live-bytes comparison the planner's resource pass relies on is
recorded even on CPU-only hosts.

  PYTHONPATH=src python benchmarks/paged_attention_bench.py \
      --json-out BENCH_paged_attn.json

``make bench-paged-attn`` runs the CI-sized sweep.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

sys.path.insert(
    0, os.path.join(os.path.dirname(__file__), "..", "src")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from common import emit, emit_header, time_call  # noqa: E402
from repro.analysis.resources import estimate_memory  # noqa: E402
from repro.kernels import ops  # noqa: E402


def git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
    except Exception:  # noqa: BLE001 — the snapshot is still useful
        return "unknown"


def make_operands(rng, *, batch, heads, kv_heads, head_dim, seq, page_size):
    """Ragged decode operands: per-slot lengths spread across [1, seq]."""
    max_pages = -(-seq // page_size)
    n_pages = batch * max_pages
    k_pool = jnp.asarray(
        rng.standard_normal((n_pages + 1, kv_heads, page_size, head_dim)),
        jnp.float32,
    )
    v_pool = jnp.asarray(
        rng.standard_normal((n_pages + 1, kv_heads, page_size, head_dim)),
        jnp.float32,
    )
    q = jnp.asarray(
        rng.standard_normal((batch, heads, 1, head_dim)), jnp.float32
    )
    lengths = np.linspace(1, seq - 1, batch).astype(np.int32)
    pages = np.arange(n_pages, dtype=np.int32).reshape(batch, max_pages)
    for i, ln in enumerate(lengths):
        pages[i, -(-(int(ln) + 1) // page_size):] = n_pages  # null page
    return q, k_pool, v_pool, jnp.asarray(pages), jnp.asarray(lengths)


def bench_cell(args, page_size, backend, interpret):
    rng = np.random.default_rng(args.seed)
    operands = make_operands(
        rng, batch=args.batch, heads=args.heads, kv_heads=args.kv_heads,
        head_dim=args.head_dim, seq=args.seq, page_size=page_size,
    )

    def step(q, k_pool, v_pool, pages, index):
        return ops.paged_attention(
            q, k_pool, v_pool, pages, index,
            backend=backend, interpret=interpret or None,
        )

    est = estimate_memory(step, *operands)
    on_tpu = jax.default_backend() == "tpu"
    timed = backend == "xla" or on_tpu or interpret
    seconds = (
        time_call(jax.jit(step), operands, repeats=args.repeats)
        if timed else None
    )
    return {
        "page_size": page_size,
        "impl": backend,
        "interpret": bool(interpret) and not on_tpu,
        "seconds": seconds,
        "tokens_per_second": (
            args.batch / seconds if seconds else None
        ),
        "peak_live_bytes": est.peak_live_bytes,
        "operand_bytes": est.operand_bytes,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--kv-heads", type=int, default=2)
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--seq", type=int, default=512,
                    help="pool capacity per slot (max context)")
    ap.add_argument("--page-sizes", type=int, nargs="+",
                    default=[8, 16, 32, 64])
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--interpret", action="store_true",
                    help="time the Pallas kernel in interpret mode off-TPU "
                         "(slow; parity path, not a performance number)")
    ap.add_argument("--json-out", default=None,
                    help="write a machine-readable snapshot "
                         "(e.g. BENCH_paged_attn.json)")
    args = ap.parse_args()

    emit_header()
    cells = []
    for ps in args.page_sizes:
        for backend in ("xla", "pallas"):
            cell = bench_cell(args, ps, backend, args.interpret)
            cells.append(cell)
            peak = f"peak={cell['peak_live_bytes']}B"
            if cell["seconds"] is not None:
                emit(f"paged_attn/{backend}/ps{ps}", cell["seconds"], peak)
            else:
                print(f"paged_attn/{backend}/ps{ps},untimed "
                      f"(TPU-only kernel),{peak}", flush=True)

    if args.json_out:
        record = {
            "schema": 1,
            "benchmark": "paged_attention",
            "git_sha": git_sha(),
            "timestamp": time.strftime(
                "%Y-%m-%dT%H:%M:%SZ", time.gmtime()
            ),
            "backend": jax.default_backend(),
            "shape": {
                "batch": args.batch,
                "heads": args.heads,
                "kv_heads": args.kv_heads,
                "head_dim": args.head_dim,
                "seq": args.seq,
            },
            "repeats": args.repeats,
            "cells": cells,
        }
        with open(args.json_out, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        print(f"snapshot written: {args.json_out}")


if __name__ == "__main__":
    main()
