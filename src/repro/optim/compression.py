"""Gradient compression for cross-replica reduction.

``compressed_psum_mean`` runs the data-parallel gradient mean inside
``shard_map`` with int8 block quantization: each replica quantizes its local
gradient shard (per-tensor scale = max|g|/127), all-reduces the int8 payload
as int32 partial sums, and dequantizes — an 4x reduction in all-reduce bytes
versus f32 (2x vs bf16) at ~0.4% RMS error.  ``quantize_tree`` exposes the
same codec for checkpoint/offload use.

This is an *explicit* collective path (shard_map), used when the launcher is
configured with ``--grad-compression int8``; the default path leaves
reduction to GSPMD.  Error feedback (residual carry) is available through
``ef_update`` for loops that keep a residual buffer.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantize_tree(tree: Any) -> Any:
    return jax.tree.map(_quantize, tree)


def compressed_psum_mean(grads: Any, mesh: Mesh, axis: str = "data") -> Any:
    """Mean of per-replica gradient trees over ``axis``, int8 on the wire."""

    def local_reduce(g):
        def f(x):
            q, s = _quantize(x)
            # int8 payload all-reduced as int32 partial sums; scales are a
            # tiny f32 all-reduce alongside
            tot = jax.lax.psum(q.astype(jnp.int32), axis)
            smax = jax.lax.pmax(s, axis)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
            return (tot.astype(jnp.float32) * smax) / n

        return jax.tree.map(f, g)

    spec = P(axis)
    every = jax.tree.map(lambda _: P(*([None])), grads)
    fn = shard_map(
        local_reduce,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), grads),),
        out_specs=jax.tree.map(lambda _: P(), grads),
        check_rep=False,
    )
    return fn(grads)


def ef_update(grad: jax.Array, residual: jax.Array):
    """Error-feedback quantization step: returns (q, scale, new_residual)."""
    comp = grad + residual
    q, s = _quantize(comp)
    deq = _dequantize(q, s)
    return q, s, comp - deq
