"""Core: automatic function-block offloading (the paper's contribution).

Public API:
    OffloadEngine      Steps 1-3 for existing applications
    CodePatternDB      the replacement registry (B-1/B-2)
    default_db         the stock DB with the TPU kernel shelf
    blocks             framework-native FunctionBlock registry
    planner            unified pattern-search subsystem (spaces, strategies,
                       MeasurementCache, persistent PlanStore)
    run_ga             prior-work loop-offload GA baseline (shim over
                       planner.GeneticSearch)
"""

from repro.core import blocks, planner  # noqa: F401
from repro.core.engine import AdaptedApp, Discovery, OffloadEngine  # noqa: F401
from repro.core.ga import GAReport, run_ga  # noqa: F401
from repro.core.interface import (  # noqa: F401
    InterfaceMismatch,
    InterfaceSpec,
    Param,
    Policy,
    match_interfaces,
)
from repro.core.planner import (  # noqa: F401
    BindingSpace,
    CostGuidedSearch,
    ExhaustiveSearch,
    GeneticSearch,
    MeasurementCache,
    Plan,
    Planner,
    PlanStore,
    SingleThenCombine,
    SubsetSpace,
)
from repro.core.pattern_db import (  # noqa: F401
    CodePatternDB,
    ReplacementEntry,
    default_db,
)
from repro.core.verify import (  # noqa: F401
    VerificationReport,
    measure,
    search_offload_pattern,
    verify_numerics,
)
