"""Step-1 analysis for traced JAX programs (beyond-paper extension).

C has no equivalent of a compute-graph trace; JAX does.  Next to the Python
AST analyzer (the Clang analogue), this module walks a ``ClosedJaxpr`` to:

* build a **primitive histogram** (the jaxpr counterpart of a Deckard
  characteristic vector) for whole-program or per-subcall similarity,
* detect **named sub-computations** (``pjit``/``custom_jvp``/``custom_vjp``
  calls carry the wrapped function's name) — the A-1 "library call" analogue
  at trace level,
* detect structural features used by the offload pre-filter: dot_general /
  conv / fft / scan / while presence, total dot FLOPs estimate.
"""

from __future__ import annotations

import dataclasses
import math
from collections import Counter
from typing import Any, Callable

import jax
import jax.extend.core as jex_core
import numpy as np


@dataclasses.dataclass(frozen=True)
class NamedCall:
    name: str
    primitive: str
    n_eqns: int


@dataclasses.dataclass
class JaxprReport:
    histogram: dict[str, int]
    named_calls: list[NamedCall]
    dot_flops: float  # 2*M*N*K summed over dot_generals (static shapes)
    has_scan: bool
    has_while: bool
    conv_flops: float = 0.0  # conv_general_dilated MACs * 2
    fft_flops: float = 0.0  # 5*N*log2(N) per transformed axis

    @property
    def flops(self) -> float:
        """Total counted FLOPs across dot/conv/fft — the roofline numerator.
        Counts inside ``scan`` bodies are scaled by trip count."""
        return self.dot_flops + self.conv_flops + self.fft_flops

    def intensity_hint(self, total_bytes: float) -> float:
        if total_bytes <= 0:
            return 0.0
        return self.flops / total_bytes


def _sub_jaxprs(eqn) -> list[Any]:
    subs = []
    for v in eqn.params.values():
        if isinstance(v, jex_core.ClosedJaxpr):
            subs.append(v.jaxpr)
        elif isinstance(v, jex_core.Jaxpr):
            subs.append(v)
        elif isinstance(v, (tuple, list)):
            for e in v:
                if isinstance(e, jex_core.ClosedJaxpr):
                    subs.append(e.jaxpr)
                elif isinstance(e, jex_core.Jaxpr):
                    subs.append(e)
    return subs


def _count_eqns(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        n += 1
        for sub in _sub_jaxprs(eqn):
            n += _count_eqns(sub)
    return n


def _dot_flops(eqn) -> float:
    try:
        lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
        dnums = eqn.params["dimension_numbers"]
        (lc, rc), (lb, rb) = dnums
        m = math.prod(
            d for i, d in enumerate(lhs.shape) if i not in set(lc) | set(lb)
        )
        n = math.prod(
            d for i, d in enumerate(rhs.shape) if i not in set(rc) | set(rb)
        )
        k = math.prod(lhs.shape[i] for i in lc)
        b = math.prod(lhs.shape[i] for i in lb)
        return 2.0 * b * m * n * k
    except Exception:  # pragma: no cover - defensive
        return 0.0


def _conv_flops(eqn) -> float:
    """conv_general_dilated: 2 MACs per output element per contributing
    kernel tap — 2 * out_elems * (kernel_elems / out_features) accounts for
    feature-group division the same way ``launch.hlo_cost`` does."""
    try:
        rhs = eqn.invars[1].aval
        out = eqn.outvars[0].aval
        dnums = eqn.params["dimension_numbers"]
        out_feature_dim = out.shape[dnums.out_spec[1]]
        kernel_elems = math.prod(rhs.shape)
        out_elems = math.prod(out.shape)
        return 2.0 * out_elems * max(kernel_elems // max(out_feature_dim, 1), 1)
    except Exception:  # pragma: no cover - defensive
        return 0.0


def _fft_flops(eqn) -> float:
    """fft: standard 5*N*log2(N) estimate per transform, times the number
    of batched transforms (leading, non-transformed axes)."""
    try:
        x = eqn.invars[0].aval
        fft_lengths = tuple(eqn.params.get("fft_lengths") or ())
        if not fft_lengths:
            fft_lengths = (x.shape[-1],)
        n = math.prod(fft_lengths)
        batch = math.prod(x.shape) / max(
            math.prod(x.shape[-len(fft_lengths):]), 1
        )
        return 5.0 * batch * n * math.log2(max(n, 2))
    except Exception:  # pragma: no cover - defensive
        return 0.0


# primitive aliases: semantically-equal primitives that different source
# spellings trace to (x**2 -> integer_pow, jnp.square -> square, ...)
_CANON = {"square": "integer_pow", "pow": "integer_pow"}


def analyze_jaxpr(closed: Any) -> JaxprReport:
    hist: Counter[str] = Counter()
    named: list[NamedCall] = []
    dot_flops = 0.0
    conv_flops = 0.0
    fft_flops = 0.0

    def walk(jaxpr, scale: float = 1.0) -> None:
        nonlocal dot_flops, conv_flops, fft_flops
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            hist[_CANON.get(prim, prim)] += 1
            if prim == "dot_general":
                dot_flops += scale * _dot_flops(eqn)
            elif prim == "conv_general_dilated":
                conv_flops += scale * _conv_flops(eqn)
            elif prim == "fft":
                fft_flops += scale * _fft_flops(eqn)
            name = eqn.params.get("name")
            if isinstance(name, str):
                subs = _sub_jaxprs(eqn)
                n_eqns = sum(_count_eqns(s) for s in subs)
                named.append(NamedCall(name=name, primitive=prim, n_eqns=n_eqns))
            inner_scale = scale
            if prim == "scan":
                inner_scale = scale * float(eqn.params.get("length", 1))
            for sub in _sub_jaxprs(eqn):
                walk(sub, inner_scale)

    walk(closed.jaxpr if hasattr(closed, "jaxpr") else closed)
    return JaxprReport(
        histogram=dict(hist),
        named_calls=named,
        dot_flops=dot_flops,
        has_scan=hist.get("scan", 0) > 0,
        has_while=hist.get("while", 0) > 0,
        conv_flops=conv_flops,
        fft_flops=fft_flops,
    )


def trace_report(fn: Callable[..., Any], *example_args: Any) -> JaxprReport:
    closed = jax.make_jaxpr(fn)(*example_args)
    return analyze_jaxpr(closed)


def histogram_similarity(a: dict[str, int], b: dict[str, int]) -> float:
    """Size-normalised L1 similarity between primitive histograms, the jaxpr
    counterpart of Deckard vector distance."""
    keys = set(a) | set(b)
    dist = sum(abs(a.get(k, 0) - b.get(k, 0)) for k in keys)
    denom = sum(a.values()) + sum(b.values())
    if denom == 0:
        return 1.0
    return 1.0 - dist / denom


def avals_of(*arrays: Any) -> tuple[jax.ShapeDtypeStruct, ...]:
    return tuple(
        jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype) for a in arrays
    )
