"""Deprecated shim — production-side plan loading moved to ``repro.offload``.

The launch drivers now resolve their binding through
``repro.offload.OffloadSession.attach`` (the zero-search production path);
``stored_binding`` replaces ``load_plan_bindings``.  These wrappers survive
only for source compatibility with existing callers.
"""

from __future__ import annotations

from repro.offload import OffloadSession, stored_binding


def load_plan_bindings(
    plan_dir: str,
    key: str,
    match_fingerprint: bool = True,
    registry=None,
) -> dict[str, str] | None:
    """Deprecated: use ``repro.offload.stored_binding``."""
    return stored_binding(
        plan_dir, key, match_fingerprint=match_fingerprint, registry=registry
    )


def plan_binding_context(plan_dir: str | None, key: str | None):
    """Deprecated: use ``repro.offload.OffloadSession.attach``."""
    return OffloadSession.attach(plan_dir, key)
