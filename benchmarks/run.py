"""Benchmark entry point: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick versions
  PYTHONPATH=src python -m benchmarks.run --full     # + paper-scale timings

CSV format: name,us_per_call,derived
"""

from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from benchmarks.common import emit_header


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also time paper-scale (2048^2) offloaded blocks")
    ap.add_argument("--dryrun-json", default="results/dryrun.json")
    args = ap.parse_args()

    emit_header()

    from benchmarks import (
        executor_compare,
        fig4_ga_generations,
        fig5_function_blocks,
        roofline,
    )

    # Fig. 4: GA generations vs performance (loop offloading, prior work)
    fig4_ga_generations.run(n=128, generations=6, population=6)

    # Measurement-runtime comparison (repro.metering executors)
    executor_compare.run(trial_seconds=0.01, axes=3)

    # Fig. 5: loop offload vs function-block offload speedups
    fig5_function_blocks.run(
        n_fft=128, n_lu=160, repeats=1, full=args.full
    )

    # Roofline terms per (arch x shape) from the dry-run, single-pod mesh
    p = pathlib.Path(args.dryrun_json)
    if p.exists():
        roofline.run(str(p), mesh="16x16")
    else:
        print(f"# roofline skipped: {p} not found (run repro.launch.dryrun)",
              flush=True)


if __name__ == "__main__":
    main()
