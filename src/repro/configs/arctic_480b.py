"""arctic-480b [moe] — 128 experts top-2 + dense residual FFN.
hf:Snowflake/snowflake-arctic-base."""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,  # dense-residual FFN hidden
    vocab_size=32000,
    moe=MoEConfig(
        n_experts=128,
        top_k=2,
        d_expert=4864,
        dense_residual=True,
    ),
    rope_theta=10000.0,
    param_dtype="bfloat16",  # 480B: bf16 params + bf16 moments to fit HBM
    opt_dtype="bfloat16",
)
