"""Deterministic synthetic data pipeline.

Design constraints it satisfies (the same ones a real pipeline must):
  * deterministic per (seed, step) — a restarted job resumes mid-stream with
    identical batches (required by the fault-tolerance path);
  * host-shardable — ``host_local_slice`` carves the per-host slice of the
    global batch exactly as a multi-host loader would, so the launcher's
    data path is the production shape;
  * learnable — tokens follow a noisy affine-recurrence language so a ~100M
    model's loss visibly decreases within a few hundred steps (quickstart).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.85  # probability a token follows the recurrence

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """The full global batch for one step (deterministic)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, 0xD0C])
        )
        b, s, v = self.global_batch, self.seq_len + 1, self.vocab_size
        # affine recurrence with per-sequence parameters + noise
        a = rng.integers(3, 23, (b, 1))
        c = rng.integers(1, v - 1, (b, 1))
        toks = np.empty((b, s), dtype=np.int64)
        toks[:, 0] = rng.integers(0, v, b)
        noise = rng.random((b, s)) > self.structure
        rand = rng.integers(0, v, (b, s))
        for t in range(1, s):
            nxt = (toks[:, t - 1] * a[:, 0] + c[:, 0]) % v
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def embeds_batch_at(self, step: int, d_model: int) -> dict[str, np.ndarray]:
        """Frontend-stub variant: precomputed patch/frame embeddings."""
        base = self.batch_at(step)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, 0xE58])
        )
        embeds = rng.standard_normal(
            (self.global_batch, self.seq_len, d_model)
        ).astype(np.float32)
        return {"embeds": embeds, "labels": base["labels"]}


def host_local_slice(
    batch: dict[str, np.ndarray], host_id: int, n_hosts: int
) -> dict[str, np.ndarray]:
    """The slice of the global batch this host is responsible for loading."""
    out = {}
    for k, v in batch.items():
        gb = v.shape[0]
        assert gb % n_hosts == 0, (gb, n_hosts)
        per = gb // n_hosts
        out[k] = v[host_id * per : (host_id + 1) * per]
    return out
