"""Architecture / run configuration schema."""

from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int  # expert FFN hidden size
    n_shared: int = 0  # always-on shared experts (deepseek)
    dense_residual: bool = False  # dense FFN in parallel with MoE (arctic)
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim

    def conv_dim(self, d_model: int) -> int:
        return self.d_inner(d_model) + 2 * self.d_state


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid: one char per layer — 'a' attention+mlp, 'm' mamba,
    # 's' shared attention block (parameters shared across all 's' sites)
    block_pattern: str | None = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    frontend: Literal["patch_embed", "audio_tokens"] | None = None
    first_k_dense: int = 0  # leading dense layers in an MoE stack
    # numerics / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_dtype: str = "float32"
    remat: Literal["full", "none"] = "full"
    # which attention the arch can run at 500k context (sub-quadratic only)
    subquadratic: bool = False

    # ---------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a lane-aligned multiple (sharding divisibility)."""
        return ((self.vocab_size + 255) // 256) * 256

    def pattern(self) -> str:
        if self.block_pattern is not None:
            assert len(self.block_pattern) == self.n_layers
            return self.block_pattern
        if self.family == "ssm":
            return "m" * self.n_layers
        if self.moe is not None and self.first_k_dense:
            return "d" * self.first_k_dense + "a" * (
                self.n_layers - self.first_k_dense
            )
        return "a" * self.n_layers

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d = self.d_model
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for ch in self.pattern():
            total += self._block_params(ch)
        total += d  # final norm
        return total

    def _attn_params(self) -> int:
        d = self.d_model
        if self.mla:
            m = self.mla
            qd = self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            return (
                d * qd
                + d * m.kv_lora_rank
                + m.kv_lora_rank * self.n_heads * m.qk_nope_head_dim
                + m.kv_lora_rank * self.n_heads * m.v_head_dim
                + d * m.qk_rope_head_dim
                + self.n_heads * m.v_head_dim * d
            )
        return (
            d * self.n_heads * self.d_head
            + 2 * d * self.n_kv_heads * self.d_head
            + self.n_heads * self.d_head * d
        )

    def _mlp_params(self, hidden: int) -> int:
        return 3 * self.d_model * hidden  # SwiGLU: gate, up, down

    def _block_params(self, ch: str) -> int:
        d = self.d_model
        if ch == "m":
            s = self.ssm
            di = s.d_inner(d)
            h = s.n_heads(d)
            cd = s.conv_dim(d)
            in_proj = d * (2 * di + 2 * s.d_state + h)
            return in_proj + s.d_conv * cd + cd + 3 * h + di + di * d + 2 * d
        # attention blocks
        total = self._attn_params() + 2 * d
        if ch == "s":
            return total + self._mlp_params(self.d_ff)
        if self.moe is not None and ch == "a":
            m = self.moe
            total += d * m.n_experts  # router
            total += m.n_experts * self._mlp_params(m.d_expert) // 1
            total += m.n_shared * self._mlp_params(m.d_expert)
            if m.dense_residual:
                total += self._mlp_params(self.d_ff)
        else:
            total += self._mlp_params(self.d_ff)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for ch in self.pattern():
            if ch == "a":
                t = self._attn_params() + 2 * d + d * m.n_experts
                t += (m.top_k + m.n_shared) * self._mlp_params(m.d_expert)
                if m.dense_residual:
                    t += self._mlp_params(self.d_ff)
                total += t
            else:
                total += self._block_params(ch)
        return total

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        pat = None
        if self.block_pattern is not None:
            pat = self.pattern()[: min(4, self.n_layers)]
            if "s" in self.pattern() and "s" not in pat:
                pat = pat[:-1] + "s"
        moe = None
        if self.moe:
            moe = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(2, self.moe.top_k), d_expert=64,
                n_shared=min(1, self.moe.n_shared),
            )
        mla = None
        if self.mla:
            mla = MLAConfig(
                kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                v_head_dim=16,
            )
        ssm = None
        if self.ssm:
            ssm = dataclasses.replace(
                self.ssm, d_state=16, head_dim=8, chunk=16
            )
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(4, self.n_layers),
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=(
                min(4, max(1, self.n_kv_heads * 4 // self.n_heads))
                if self.n_heads
                else 0
            ),
            d_head=16 if self.n_heads else 0,
            d_ff=128,
            vocab_size=512,
            moe=moe,
            mla=mla,
            ssm=ssm,
            block_pattern=pat,
            param_dtype="float32",
            opt_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
