"""Measurement-runtime comparison: serial vs device-parallel vs batched.

Times one ExhaustiveSearch over a synthetic space through each
``repro.metering`` executor.  On a multi-device host DeviceParallelExecutor
approaches wall = slowest-trial (not sum-of-trials); on this single-device
container the interesting number is BatchedExecutor's amortisation of
per-trial dispatch/timer overhead for sub-millisecond variants.

  PYTHONPATH=src python -m benchmarks.executor_compare
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import emit


def run(trial_seconds: float = 0.02, axes: int = 3, repeats: int = 1) -> dict:
    from repro.core.planner import ExhaustiveSearch, MeasurementCache, SubsetSpace
    from repro.metering import (
        BatchedExecutor,
        DeviceParallelExecutor,
        SerialExecutor,
    )

    # device discovery initialises the jax backend (~0.5 s once per
    # process); do it outside the timed windows
    import jax

    jax.devices()

    names = [f"blk{i}" for i in range(axes)]

    def build(subset):
        def fn(_x):
            time.sleep(trial_seconds)
            return _x

        return fn

    executors = [
        ("serial", SerialExecutor()),
        ("device_parallel", DeviceParallelExecutor(max_workers=8)),
        ("batched", BatchedExecutor(max_fuse=8)),
    ]
    out = {}
    for label, executor in executors:
        space = SubsetSpace(build, names, tag=f"bench-{label}")
        cache = MeasurementCache(executor=executor)
        t0 = time.perf_counter()
        ExhaustiveSearch().search(space, (0,), cache=cache, repeats=repeats)
        wall = time.perf_counter() - t0
        out[label] = wall
        emit(
            f"executor.{label}", wall,
            f"trials={cache.evaluations} trial_s={trial_seconds}",
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trial-seconds", type=float, default=0.02)
    ap.add_argument("--axes", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=1)
    args = ap.parse_args()
    run(args.trial_seconds, args.axes, args.repeats)


if __name__ == "__main__":
    main()
