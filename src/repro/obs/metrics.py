"""MetricsRegistry: counters / gauges / exponential-bucket histograms.

One registry per engine (or process) replaces the ad-hoc telemetry dicts
that grew around the serve loop: every number the re-planner, the
power-aware scheduler or a cluster router wants to watch is registered
once, updated in place, and rendered in Prometheus text exposition format
(``registry.render_prometheus()``), optionally served over HTTP by
:class:`MetricsServer` (stdlib ``http.server``, no new dependencies).

Instruments are *families*: ``registry.counter("serve_phase_tokens_total",
"...", labelnames=("phase",))`` returns a family whose ``labels(phase=
"decode")`` children carry the values.  An unlabeled family acts as its
own single child (``family.inc()`` / ``.set()`` / ``.observe()``).

Histograms use cumulative exponential buckets (latency-shaped: equal
resolution per octave) and render the standard ``_bucket``/``_sum``/
``_count`` triplet with an ``le="+Inf"`` terminal bucket.
"""

from __future__ import annotations

import math
import re
import threading
from typing import Any, Sequence

__all__ = [
    "MetricsRegistry",
    "MetricsServer",
    "exponential_buckets",
]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def exponential_buckets(
    start: float = 1e-4, factor: float = 2.0, count: int = 16
) -> tuple[float, ...]:
    """``count`` upper bounds growing geometrically from ``start``.  The
    default (100µs .. ~3.3s at factor 2) spans serve-step latencies."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError("need start > 0, factor > 1, count >= 1")
    return tuple(start * factor**i for i in range(count))


def _escape_help(text: str) -> str:
    return text.replace("\\", r"\\").replace("\n", r"\n")


def _escape_label_value(value: str) -> str:
    return (
        value.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")
    )


def _fmt(value: float) -> str:
    """Prometheus sample value formatting: integers without the
    trailing .0, +Inf spelled the Prometheus way."""
    if value == math.inf:
        return "+Inf"
    if value == -math.inf:
        return "-Inf"
    if float(value).is_integer() and abs(value) < 1e15:
        return str(int(value))
    return repr(float(value))


class _Child:
    """One labeled (or the sole unlabeled) instrument instance."""

    __slots__ = ("kind", "value", "sum", "counts", "_buckets", "_lock")

    def __init__(
        self, kind: str, buckets: tuple[float, ...] | None, lock: threading.Lock
    ) -> None:
        self.kind = kind
        self.value = 0.0
        self.sum = 0.0
        self._buckets = buckets
        self.counts = [0] * (len(buckets) + 1) if buckets is not None else None
        self._lock = lock

    def inc(self, amount: float = 1.0) -> None:
        if self.kind == "counter" and amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        if self.kind != "gauge":
            raise TypeError(f"dec() on a {self.kind}")
        with self._lock:
            self.value -= amount

    def set(self, value: float) -> None:
        if self.kind != "gauge":
            raise TypeError(f"set() on a {self.kind}")
        with self._lock:
            self.value = float(value)

    def observe(self, value: float) -> None:
        if self.kind != "histogram":
            raise TypeError(f"observe() on a {self.kind}")
        value = float(value)
        with self._lock:
            self.sum += value
            self.value += 1  # observation count
            assert self.counts is not None and self._buckets is not None
            for i, bound in enumerate(self._buckets):
                if value <= bound:
                    self.counts[i] += 1
                    break
            else:
                self.counts[-1] += 1  # +Inf overflow bucket

    def _reset(self) -> None:
        with self._lock:
            self.value = 0.0
            self.sum = 0.0
            if self.counts is not None:
                self.counts = [0] * len(self.counts)


class _Family:
    """A named metric plus its labeled children."""

    def __init__(
        self,
        name: str,
        help_text: str,
        kind: str,
        labelnames: tuple[str, ...],
        buckets: tuple[float, ...] | None = None,
    ) -> None:
        self.name = name
        self.help = help_text
        self.kind = kind
        self.labelnames = labelnames
        self.buckets = buckets
        self._lock = threading.Lock()
        self._children: dict[tuple[str, ...], _Child] = {}
        if not labelnames:
            self._children[()] = _Child(kind, buckets, self._lock)

    def labels(self, **labels: Any) -> _Child:
        if tuple(sorted(labels)) != tuple(sorted(self.labelnames)):
            raise KeyError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(labels)}"
            )
        key = tuple(str(labels[n]) for n in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = _Child(self.kind, self.buckets, self._lock)
                self._children[key] = child
        return child

    def _sole(self) -> _Child:
        if self.labelnames:
            raise KeyError(
                f"{self.name} is labeled by {self.labelnames}; "
                "use .labels(...)"
            )
        return self._children[()]

    # unlabeled convenience: the family acts as its own child
    def inc(self, amount: float = 1.0) -> None:
        self._sole().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._sole().dec(amount)

    def set(self, value: float) -> None:
        self._sole().set(value)

    def observe(self, value: float) -> None:
        self._sole().observe(value)

    @property
    def value(self) -> float:
        return self._sole().value

    def children(self) -> "dict[tuple[str, ...], _Child]":
        with self._lock:
            return dict(self._children)


class MetricsRegistry:
    """Thread-safe registry of metric families.

    ``counter``/``gauge``/``histogram`` register idempotently: asking for
    an existing name returns the existing family (and raises if the kind
    or labels disagree — two subsystems silently sharing one name under
    different schemas is the bug this catches).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    def _register(
        self,
        name: str,
        help_text: str,
        kind: str,
        labelnames: Sequence[str],
        buckets: tuple[float, ...] | None = None,
    ) -> _Family:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        labelnames = tuple(labelnames)
        for label in labelnames:
            if not _LABEL_RE.match(label):
                raise ValueError(f"invalid label name {label!r}")
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != labelnames:
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind}{fam.labelnames}, not "
                        f"{kind}{labelnames}"
                    )
                return fam
            fam = _Family(name, help_text, kind, labelnames, buckets)
            self._families[name] = fam
            return fam

    def counter(
        self, name: str, help_text: str = "", labelnames: Sequence[str] = ()
    ) -> _Family:
        return self._register(name, help_text, "counter", labelnames)

    def gauge(
        self, name: str, help_text: str = "", labelnames: Sequence[str] = ()
    ) -> _Family:
        return self._register(name, help_text, "gauge", labelnames)

    def histogram(
        self,
        name: str,
        help_text: str = "",
        labelnames: Sequence[str] = (),
        buckets: Sequence[float] | None = None,
    ) -> _Family:
        bounds = tuple(
            sorted(buckets) if buckets is not None else exponential_buckets()
        )
        return self._register(name, help_text, "histogram", labelnames, bounds)

    def get(self, name: str) -> _Family | None:
        with self._lock:
            return self._families.get(name)

    def families(self) -> list[_Family]:
        with self._lock:
            return list(self._families.values())

    def reset(self) -> None:
        """Zero every child in place (benchmark warmup discard).  Child
        handles held by instruments stay valid."""
        for fam in self.families():
            for child in fam.children().values():
                child._reset()

    # -- exposition --------------------------------------------------------
    def render_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: list[str] = []
        for fam in sorted(self.families(), key=lambda f: f.name):
            if fam.help:
                lines.append(f"# HELP {fam.name} {_escape_help(fam.help)}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, child in sorted(fam.children().items()):
                base_labels = list(zip(fam.labelnames, key))
                if fam.kind == "histogram":
                    assert fam.buckets is not None and child.counts is not None
                    cumulative = 0
                    for bound, n in zip(fam.buckets, child.counts):
                        cumulative += n
                        lines.append(
                            _sample(
                                f"{fam.name}_bucket",
                                base_labels + [("le", _fmt(bound))],
                                cumulative,
                            )
                        )
                    cumulative += child.counts[-1]
                    lines.append(
                        _sample(
                            f"{fam.name}_bucket",
                            base_labels + [("le", "+Inf")],
                            cumulative,
                        )
                    )
                    lines.append(
                        _sample(f"{fam.name}_sum", base_labels, child.sum)
                    )
                    lines.append(
                        _sample(f"{fam.name}_count", base_labels, child.value)
                    )
                else:
                    lines.append(_sample(fam.name, base_labels, child.value))
        return "\n".join(lines) + ("\n" if lines else "")


def _sample(
    name: str, labels: "list[tuple[str, str]]", value: float
) -> str:
    if labels:
        body = ",".join(
            f'{k}="{_escape_label_value(str(v))}"' for k, v in labels
        )
        return f"{name}{{{body}}} {_fmt(value)}"
    return f"{name} {_fmt(value)}"


class MetricsServer:
    """Minimal ``/metrics`` HTTP endpoint over one registry.

    Stdlib-only (``http.server``), threaded, daemonized — safe to leave
    running for the lifetime of a serve process.  ``port=0`` binds an
    ephemeral port (read it back from :attr:`port`)."""

    def __init__(
        self,
        registry: MetricsRegistry,
        port: int = 0,
        host: str = "127.0.0.1",
    ) -> None:
        import http.server

        render = registry.render_prometheus

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self) -> None:  # noqa: N802 — http.server API
                if self.path.split("?")[0] != "/metrics":
                    self.send_error(404, "try /metrics")
                    return
                body = render().encode()
                self.send_response(200)
                self.send_header(
                    "Content-Type", "text/plain; version=0.0.4; charset=utf-8"
                )
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args: Any) -> None:  # quiet by default
                pass

        self._httpd = http.server.ThreadingHTTPServer((host, port), Handler)
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name="repro-obs-metrics",
            daemon=True,
        )
        self._thread.start()

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)
