"""Fused RMSNorm kernel vs oracle, hypothesis shape sweep."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.rmsnorm import rmsnorm_pallas


@pytest.mark.parametrize("shape", [(8, 512), (2, 16, 256), (4, 8, 8, 128)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_rmsnorm_matches_oracle(shape, dtype, rng):
    x = jnp.asarray(rng.standard_normal(shape), dtype)
    w = jnp.asarray(rng.standard_normal(shape[-1]), dtype)
    out = rmsnorm_pallas(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    tol = 1e-5 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@settings(max_examples=12, deadline=None)
@given(
    rows=st.integers(1, 16),
    d=st.sampled_from([64, 128, 256, 512]),
    eps=st.sampled_from([1e-6, 1e-5]),
)
def test_rmsnorm_property_sweep(rows, d, eps):
    rng = np.random.default_rng(rows * d)
    x = jnp.asarray(rng.standard_normal((rows, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(d), jnp.float32)
    out = rmsnorm_pallas(x, w, eps=eps, interpret=True)
    want = ref.rmsnorm_ref(x, w, eps=eps)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_rmsnorm_output_scale_invariant():
    # rmsnorm(cx) == rmsnorm(x) for c > 0 (up to eps): the defining invariant
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((4, 256)), jnp.float32)
    w = jnp.ones(256, jnp.float32)
    a = rmsnorm_pallas(x, w, interpret=True)
    b = rmsnorm_pallas(x * 1000.0, w, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
