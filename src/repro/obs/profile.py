"""Opt-in ``jax.profiler`` capture windows.

The tracer (``repro.obs.trace``) answers *host-side* "why was this step
slow" questions; when the answer is inside a compiled program, the next
tool down is the XLA profiler.  :func:`profile_window` brackets a code
region with ``jax.profiler.start_trace``/``stop_trace`` so the captured
TensorBoard/Perfetto artifacts land in a log directory, and degrades to a
no-op (with one warning) on hosts whose jax build lacks the profiler —
profiling must never be the reason a serve loop cannot run.

Typical uses::

    with obs.profile_window("/tmp/prof"):          # one planner round
        session.plan()

    engine.profile_steps(8, "/tmp/prof")           # N serve steps
"""

from __future__ import annotations

import contextlib
import warnings
from typing import Iterator

__all__ = ["profile_window", "profiler_available"]


def profiler_available() -> bool:
    """True when this jax build exposes the trace-capture profiler API."""
    try:
        import jax.profiler

        return hasattr(jax.profiler, "start_trace") and hasattr(
            jax.profiler, "stop_trace"
        )
    except Exception:  # noqa: BLE001 — absence is an answer, not an error
        return False


@contextlib.contextmanager
def profile_window(
    logdir: str, *, tracer=None, name: str = "profile"
) -> Iterator[bool]:
    """Capture a ``jax.profiler`` trace of the body into ``logdir``.

    Yields True when a capture is actually running, False on graceful
    degrade (no profiler in this jax build, or a capture already active).
    When ``tracer`` (a :class:`repro.obs.Tracer`) is given, the window is
    also recorded as a host-side span so the two timelines line up.
    """
    from repro.obs.trace import get_tracer

    tracer = tracer if tracer is not None else get_tracer()
    started = False
    try:
        import jax.profiler

        jax.profiler.start_trace(logdir)
        started = True
    except Exception as e:  # noqa: BLE001 — degrade, don't abort serving
        warnings.warn(
            f"obs.profile_window: jax profiler capture unavailable "
            f"({type(e).__name__}: {e}); running unprofiled",
            stacklevel=3,
        )
    span = tracer.span(name, logdir=logdir, captured=started)
    try:
        with span:
            yield started
    finally:
        if started:
            import jax.profiler

            try:
                jax.profiler.stop_trace()
            except Exception as e:  # noqa: BLE001
                warnings.warn(
                    f"obs.profile_window: stop_trace failed "
                    f"({type(e).__name__}: {e})",
                    stacklevel=3,
                )
