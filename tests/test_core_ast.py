"""Step-1 analysis (A-1/A-2): library-call detection, local defs, loops."""

import pytest

from repro.core import ast_analysis, default_db
from repro.apps import fourier, matrix

DB = default_db()


def test_detects_library_call_by_name():
    rep = ast_analysis.analyze_module_of(
        fourier.fourier_app_libcall, DB.known_library_names
    )
    calls = [c for c in rep.library_calls if c.enclosing == "fourier_app_libcall"]
    assert any(c.call_name == "fft2d_nr" for c in calls)


def test_detects_dotted_library_call():
    src = """
import numpy as np
def app(x):
    return np.fft.fft2(x)
"""
    rep = ast_analysis.analyze_source(src, {"np.fft.fft2"})
    assert [c.call_name for c in rep.library_calls] == ["np.fft.fft2"]


def test_detects_local_defs_and_their_calls():
    rep = ast_analysis.analyze_module_of(
        fourier.fourier_app_copied, DB.known_library_names
    )
    defs = {d.name: d for d in rep.func_defs}
    assert "my_fft2d" in defs
    assert "my_fft1d" in defs["my_fft2d"].calls
    assert defs["my_fft2d"].source.startswith("def my_fft2d")


def test_detects_loops_with_nesting():
    rep = ast_analysis.analyze_module_of(
        matrix.ludcmp_nr, DB.known_library_names
    )
    loops = [l for l in rep.loops if l.enclosing == "ludcmp_nr"]
    assert len(loops) >= 6  # NR ludcmp has many nested loops
    assert max(l.depth for l in loops) >= 2


def test_unknown_names_not_reported():
    src = "def f(x):\n    return undefined_helper(x)\n"
    rep = ast_analysis.analyze_source(src, DB.known_library_names)
    assert rep.library_calls == []
