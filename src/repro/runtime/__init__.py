from repro.runtime.monitor import StepMonitor  # noqa: F401
from repro.runtime.fault import FaultTolerantLoop, InjectedFailure  # noqa: F401
