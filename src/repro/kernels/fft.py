"""Matmul-DFT — the TPU-native cuFFT analogue.

A GPU FFT (cuFFT) is butterfly-based; butterflies are strided scalar work
that wastes the MXU.  The TPU-native formulation of the paper's "replace the
FFT block with a tuned library" is to express the DFT as dense matmuls that
run on the systolic array:

    2-D FFT:  Y = F_n @ X @ F_m        (DFT matrices are symmetric)

Complex arithmetic maps to 4 real MXU matmuls per stage (re/im planes).
The kernel below is a complex blocked matmul with two f32 VMEM accumulators;
``ops.fft2d`` stacks two stages (rows then columns via transpose).

Cost: direct DFT-matmul is O(n^2) per vector vs O(n log n) for a butterfly
FFT — but it is MXU-dense.  The four-step factorisation (n = n1*n2, two
matmul stages + twiddle) recovers most of the asymptotics while staying
matmul-shaped; it is implemented in ``ops.fft2d(variant="four-step")`` and
evaluated in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def dft_matrix(n: int, sign: float = -1.0) -> tuple[np.ndarray, np.ndarray]:
    """Real/imag planes of the n-point DFT matrix F[k,j] = exp(sign*2pi i kj/n)."""
    k = np.arange(n)
    angles = sign * 2.0 * np.pi * np.outer(k, k) / n
    return np.cos(angles).astype(np.float32), np.sin(angles).astype(np.float32)


def _cmm_kernel(ar_ref, ai_ref, br_ref, bi_ref, or_ref, oi_ref,
                accr_ref, acci_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        accr_ref[...] = jnp.zeros_like(accr_ref)
        acci_ref[...] = jnp.zeros_like(acci_ref)

    ar = ar_ref[...]
    ai = ai_ref[...]
    br = br_ref[...]
    bi = bi_ref[...]
    accr_ref[...] += (
        jnp.dot(ar, br, preferred_element_type=jnp.float32)
        - jnp.dot(ai, bi, preferred_element_type=jnp.float32)
    )
    acci_ref[...] += (
        jnp.dot(ar, bi, preferred_element_type=jnp.float32)
        + jnp.dot(ai, br, preferred_element_type=jnp.float32)
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        or_ref[...] = accr_ref[...].astype(or_ref.dtype)
        oi_ref[...] = acci_ref[...].astype(oi_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def complex_matmul_pallas(
    ar: jax.Array,
    ai: jax.Array,
    br: jax.Array,
    bi: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(ar+i*ai) @ (br+i*bi) as 4 real MXU matmuls, tiled like matmul."""
    m, k = ar.shape
    _, n = br.shape
    if m % block_m or n % block_n or k % block_k:
        raise ValueError("shapes must tile by block sizes; pad first")
    grid = (m // block_m, n // block_n, k // block_k)
    in_spec_a = pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk))
    in_spec_b = pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j))
    out_spec = pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j))
    return pl.pallas_call(
        functools.partial(_cmm_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[in_spec_a, in_spec_a, in_spec_b, in_spec_b],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),
            pltpu.VMEM((block_m, block_n), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(ar, ai, br, bi)


def fft2d_pallas(x: jax.Array, *, interpret: bool = False,
                 block: int = 128) -> jax.Array:
    """2-D FFT of a complex array via two DFT matmul stages."""
    n, m = x.shape
    xr = jnp.real(x).astype(jnp.float32)
    xi = jnp.imag(x).astype(jnp.float32)
    fr_m, fi_m = dft_matrix(m)
    # rows: X @ F_m  (F symmetric)
    yr, yi = complex_matmul_pallas(
        xr, xi, jnp.asarray(fr_m), jnp.asarray(fi_m),
        block_m=min(block, n), block_n=min(block, m), block_k=min(block, m),
        interpret=interpret,
    )
    fr_n, fi_n = dft_matrix(n)
    # columns: F_n @ Y == (Y^T @ F_n)^T
    zr, zi = complex_matmul_pallas(
        yr.T, yi.T, jnp.asarray(fr_n), jnp.asarray(fi_n),
        block_m=min(block, m), block_n=min(block, n), block_k=min(block, n),
        interpret=interpret,
    )
    return (zr.T + 1j * zi.T).astype(jnp.complex64)
