"""Pallas matmul / schur_update vs jnp oracle (interpret mode shape sweep)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.matmul import matmul_pallas, schur_update_pallas

SHAPES = [
    (128, 128, 128),
    (256, 128, 128),
    (128, 384, 256),
    (256, 256, 512),
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_matmul_matches_oracle(m, k, n, dtype, rng):
    a = jnp.asarray(rng.standard_normal((m, k)), dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    out = matmul_pallas(a, b, interpret=True)
    want = ref.matmul_ref(a, b)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol * 10,
    )


@pytest.mark.parametrize("m,k,n", SHAPES[:2])
def test_schur_update_matches_oracle(m, k, n, rng):
    c = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    out = schur_update_pallas(c, a, b, interpret=True)
    want = ref.schur_update_ref(c, a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4)


def test_matmul_rejects_untiled_shapes(rng):
    a = jnp.asarray(rng.standard_normal((100, 128)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    with pytest.raises(ValueError):
        matmul_pallas(a, b, interpret=True)


def test_block_shape_sweep(rng):
    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    want = np.asarray(ref.matmul_ref(a, b))
    for bm, bn, bk in [(128, 128, 128), (128, 256, 128), (256, 128, 256)]:
        out = matmul_pallas(
            a, b, block_m=bm, block_n=bn, block_k=bk, interpret=True
        )
        np.testing.assert_allclose(np.asarray(out), want, atol=2e-4)
