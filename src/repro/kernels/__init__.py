"""Kernel shelf: Pallas TPU kernels (+ XLA formulations + jnp oracles).

This package is the TPU analogue of the paper's accelerated-library shelf
(cuFFT / cuBLAS / cuSOLVER / FPGA IP cores).  Importing it registers every
kernel as a FunctionBlock implementation so the offload engine can bind
ref/xla/pallas per deployment environment.
"""

import functools

from repro.core import blocks
from repro.kernels import ops, ref  # noqa: F401


def _register_all() -> None:
    r = blocks.registry
    # matmul
    r.register("matmul", "ref", ref.matmul_ref, "jnp.dot oracle")
    r.register("matmul", "xla", ref.matmul_ref, "XLA dot")
    r.register(
        "matmul", "pallas",
        functools.partial(ops.matmul, backend="pallas"),
        "blocked MXU matmul",
    )
    # attention
    r.register("attention", "ref", ref.attention_ref, "softmax einsum oracle")
    r.register("attention", "xla", ref.attention_ref, "XLA attention")
    r.register(
        "attention", "pallas",
        functools.partial(ops.flash_attention, backend="pallas"),
        "flash attention, VMEM-tiled",
    )
    # rmsnorm
    r.register("rmsnorm", "ref", ref.rmsnorm_ref, "jnp oracle")
    r.register("rmsnorm", "xla", ref.rmsnorm_ref, "XLA rmsnorm")
    r.register(
        "rmsnorm", "pallas",
        functools.partial(ops.rmsnorm, backend="pallas"),
        "fused rmsnorm",
    )
    # ssd scan
    r.register("ssd_scan", "ref", functools.partial(ops.ssd_scan, backend="ref"),
               "sequential scan oracle")
    r.register("ssd_scan", "xla", functools.partial(ops.ssd_scan, backend="xla"),
               "chunked SSD, XLA")
    r.register("ssd_scan", "pallas",
               functools.partial(ops.ssd_scan, backend="pallas"),
               "chunked SSD, Pallas intra-chunk")
    # fft2d
    r.register("fft2d", "xla", functools.partial(ops.fft2d, backend="xla"),
               "XLA native fft2")
    r.register("fft2d", "pallas", functools.partial(ops.fft2d, backend="pallas"),
               "matmul-DFT on MXU")
    # lu
    r.register("lu", "xla", functools.partial(ops.lu, backend="xla"),
               "blocked LU, XLA trailing update")
    r.register("lu", "pallas", functools.partial(ops.lu, backend="pallas"),
               "blocked LU, Pallas schur update")


_register_all()
