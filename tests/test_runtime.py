"""Fault tolerance: failure injection + recovery, straggler detection."""

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.fault import FaultTolerantLoop, InjectedFailure
from repro.runtime.monitor import StepMonitor


def _make_loop(tmp_path, fail_at=(), max_restarts=3, ckpt_every=5):
    trace = []

    def step_fn(state, batch, step):
        trace.append(step)
        return {"x": state["x"] + batch["v"]}

    def batch_fn(step):
        return {"v": np.float64(step)}  # deterministic replay

    fails = {s: True for s in fail_at}

    def failure_hook(step):
        if fails.pop(step, False):
            raise InjectedFailure(f"node lost at step {step}")

    loop = FaultTolerantLoop(
        step_fn=step_fn,
        batch_fn=batch_fn,
        ckpt=CheckpointManager(tmp_path),
        ckpt_every=ckpt_every,
        max_restarts=max_restarts,
        failure_hook=failure_hook,
    )
    return loop, trace


def _expected(n):
    return float(sum(range(n)))


def test_clean_run(tmp_path):
    loop, _ = _make_loop(tmp_path)
    res = loop.run({"x": 0.0}, 12)
    assert res.completed_steps == 12
    assert res.restarts == 0
    assert float(res.state["x"]) == _expected(12)


def test_recovery_is_bit_exact(tmp_path):
    loop, trace = _make_loop(tmp_path, fail_at=(7,))
    res = loop.run({"x": 0.0}, 12)
    assert res.restarts == 1
    # steps 5 and 6 replayed after restoring the step-5 checkpoint
    assert trace.count(5) == 2 and trace.count(6) == 2
    assert float(res.state["x"]) == _expected(12)


def test_multiple_failures_within_budget(tmp_path):
    loop, _ = _make_loop(tmp_path, fail_at=(3, 8, 11), max_restarts=5)
    res = loop.run({"x": 0.0}, 15)
    assert res.restarts == 3
    assert float(res.state["x"]) == _expected(15)


def test_restart_budget_exceeded_raises(tmp_path):
    # failing the same un-checkpointed step forever must not loop silently
    def always_fail(step):
        if step == 2:
            raise InjectedFailure("persistent fault")

    loop, _ = _make_loop(tmp_path, max_restarts=2)
    loop.failure_hook = always_fail
    with pytest.raises(RuntimeError, match="restart budget"):
        loop.run({"x": 0.0}, 10)


def test_resume_from_existing_checkpoint(tmp_path):
    loop1, _ = _make_loop(tmp_path)
    loop1.run({"x": 0.0}, 10)
    # a fresh process picks up at the last checkpoint, not step 0
    loop2, trace2 = _make_loop(tmp_path)
    res = loop2.run({"x": 0.0}, 15)
    assert min(trace2) == 10
    assert float(res.state["x"]) == _expected(15)


def test_straggler_detection_flags_repeat_offender():
    mon = StepMonitor(window=16, threshold=2.0, patience=2)
    for step in range(20):
        mon.observe(step, 0.1, host=0)
    mon.observe(20, 0.5, host=3)
    mon.observe(21, 0.6, host=3)
    assert 3 in mon.flagged_hosts
    assert len(mon.events) >= 2
    assert mon.median_step() == pytest.approx(0.1, rel=0.2)


def test_normal_jitter_not_flagged():
    mon = StepMonitor(window=16, threshold=2.0, patience=2)
    rng = np.random.default_rng(0)
    for step in range(50):
        mon.observe(step, 0.1 + 0.02 * rng.random(), host=0)
    assert mon.flagged_hosts == set()
