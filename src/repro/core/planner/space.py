"""SearchSpace — what an offload-pattern search ranges over.

A *candidate* is a tuple of per-axis choice indices.  Index 0 is always the
axis's baseline (the un-offloaded / default formulation), so the all-zeros
candidate is the unmodified application.  Spaces know how to turn a
candidate into a runnable callable (``build``) and into human/store-facing
descriptions (``pattern`` / ``mapping_of``).
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
from typing import Any, Callable, Iterator, Mapping, Sequence

Candidate = tuple[int, ...]

#: Sentinel choice label meaning "leave this block on its default binding".
DEFAULT_TARGET = "default"


@dataclasses.dataclass(frozen=True)
class Axis:
    """One independently searchable position: a block and its choices.

    ``choices[0]`` is the baseline choice for the axis.
    """

    name: str
    choices: tuple[str, ...]

    def __post_init__(self) -> None:
        if not self.choices:
            raise ValueError(f"axis '{self.name}' has no choices")


class SearchSpace:
    """Abstract base: a product of axes plus a candidate -> callable builder."""

    axes: tuple[Axis, ...] = ()
    #: Distinguishes spaces with identical axes but different workloads
    #: (different application/builder) in cache and store keys.
    tag: str = ""

    # -- structure -----------------------------------------------------------
    def baseline(self) -> Candidate:
        return (0,) * len(self.axes)

    def size(self) -> int:
        n = 1
        for a in self.axes:
            n *= len(a.choices)
        return n

    def enumerate(self) -> Iterator[Candidate]:
        for cand in itertools.product(*(range(len(a.choices)) for a in self.axes)):
            yield cand

    def validate(self, cand: Candidate) -> None:
        if len(cand) != len(self.axes):
            raise ValueError(
                f"candidate has {len(cand)} genes, space has {len(self.axes)} axes"
            )
        for axis, c in zip(self.axes, cand):
            if not 0 <= c < len(axis.choices):
                raise ValueError(
                    f"axis '{axis.name}' choice index {c} out of range"
                )

    # -- legality ------------------------------------------------------------
    def pruned(self, cand: Candidate) -> str | None:
        """Reason this candidate must not be measured, or None if legal.

        The static pre-filter hook (paper Step 1): strategies consult this
        before handing a candidate to the MeasurementCache, so statically
        illegal bindings are skipped instead of timed (or crashed on).
        The base space prunes nothing.
        """
        return None

    # -- descriptions --------------------------------------------------------
    def signature(self) -> str:
        """Stable identity of the space (cache/store key component)."""
        parts = [f"{a.name}:{'|'.join(a.choices)}" for a in self.axes]
        label = f"[{self.tag}]" if self.tag else ""
        return f"{type(self).__name__}{label}({','.join(parts)})"

    def canonical(self, cand: Candidate) -> tuple:
        """Order-independent hashable key for a candidate."""
        return tuple(
            sorted((a.name, a.choices[c]) for a, c in zip(self.axes, cand))
        )

    def mapping_of(self, cand: Candidate) -> dict[str, str]:
        """Non-baseline choices as an ``{axis_name: choice_label}`` mapping."""
        return {
            a.name: a.choices[c]
            for a, c in zip(self.axes, cand)
            if c != 0
        }

    def pattern(self, cand: Candidate) -> tuple[str, ...]:
        """Sorted names of the axes moved off their baseline choice."""
        return tuple(sorted(a.name for a, c in zip(self.axes, cand) if c != 0))

    def deploy_mapping(self, cand: Candidate) -> dict[str, str]:
        """The mapping a persisted Plan must carry so deployment reproduces
        exactly this candidate.  Defaults to the non-baseline choices;
        spaces whose baseline choice is itself an explicit binding (see
        BindingSpace) override this to pin every axis."""
        return self.mapping_of(cand)

    def candidate_from_mapping(self, mapping: Mapping[str, str]) -> Candidate:
        by_name = {a.name: a for a in self.axes}
        unknown = set(mapping) - set(by_name)
        if unknown:
            raise KeyError(f"mapping names unknown axes: {sorted(unknown)}")
        genes = []
        for a in self.axes:
            label = mapping.get(a.name, a.choices[0])
            if label not in a.choices:
                raise KeyError(
                    f"axis '{a.name}' has no choice '{label}' "
                    f"(choices: {a.choices})"
                )
            genes.append(a.choices.index(label))
        return tuple(genes)

    # -- execution -----------------------------------------------------------
    def build(self, cand: Candidate) -> Callable[..., Any]:
        raise NotImplementedError


class SubsetSpace(SearchSpace):
    """Binary offload-or-not per discovered block (the paper's space).

    Wraps the historical ``build_variant(subset: frozenset[str])`` builder
    used by the engine's Step 3 and by the loop-GA baseline: gene 1 on axis
    *i* puts ``names[i]`` into the offloaded subset.
    """

    def __init__(
        self,
        build_variant: Callable[[frozenset[str]], Callable[..., Any]],
        names: Sequence[str],
        on_label: str = "offload",
        off_label: str = "cpu",
        tag: str = "",
    ) -> None:
        self._build_variant = build_variant
        self.names = tuple(names)
        self.axes = tuple(Axis(n, (off_label, on_label)) for n in self.names)
        self.tag = tag

    @classmethod
    def from_genome_builder(
        cls,
        build_variant: Callable[[tuple[int, ...]], Callable[..., Any]],
        n_genes: int,
        names: Sequence[str] | None = None,
        tag: str = "",
    ) -> "SubsetSpace":
        """Adapt a bit-genome builder (the historical loop-GA interface:
        ``build_variant((0, 1, ...))``) into a SubsetSpace."""
        gene_names = (
            list(names) if names is not None
            else [f"gene{i}" for i in range(n_genes)]
        )

        def build_subset(subset: frozenset[str]) -> Callable[..., Any]:
            return build_variant(tuple(int(n in subset) for n in gene_names))

        return cls(
            build_subset,
            gene_names,
            tag=tag or getattr(build_variant, "__qualname__", ""),
        )

    def subset_of(self, cand: Candidate) -> frozenset[str]:
        return frozenset(n for n, c in zip(self.names, cand) if c)

    def candidate_from_subset(self, subset: frozenset[str]) -> Candidate:
        return tuple(1 if n in subset else 0 for n in self.names)

    def build(self, cand: Candidate) -> Callable[..., Any]:
        self.validate(cand)
        return self._build_variant(self.subset_of(cand))


class BindingSpace(SearchSpace):
    """Per-block choice among registered execution targets.

    This generalises the paper's GPU-vs-FPGA *destination* choice: each
    function block independently picks one of its registered targets
    (``{ref, xla, pallas}``), so a GA genome over this space is n-ary
    rather than binary.  ``step_builder`` is re-invoked under the candidate
    binding so the chosen pattern is traced into the step (offload pattern
    as a compile-time property), and calls also run under the binding so
    non-traced paths resolve consistently.
    """

    def __init__(
        self,
        step_builder: Callable[[], Callable[..., Any]],
        blocks: Mapping[str, Sequence[str]] | None = None,
        registry: Any = None,
        baseline_target: str = "ref",
        tag: str = "",
    ) -> None:
        self.tag = tag or getattr(step_builder, "__qualname__", "")
        if registry is None:
            from repro.core.blocks import registry as registry_mod

            registry = registry_mod
        self.registry = registry
        self.step_builder = step_builder
        if blocks is None:
            blocks = {b: registry.targets(b) for b in registry.blocks()}
        axes = []
        for name, targets in blocks.items():
            targets = list(dict.fromkeys(targets))
            # baseline first: the un-offloaded formulation when present
            if baseline_target in targets:
                targets.remove(baseline_target)
                targets.insert(0, baseline_target)
            axes.append(Axis(name, tuple(targets)))
        self.axes = tuple(axes)
        # (block, target) -> reason, filled by mark_illegal() from a
        # repro.analysis legality report; consulted by pruned()
        self._illegal: dict[tuple[str, str], str] = {}

    @classmethod
    def from_patterns(
        cls,
        step_builder: Callable[[], Callable[..., Any]],
        patterns: Sequence[Mapping[str, str]],
        registry: Any = None,
    ) -> "BindingSpace":
        """Space covering an explicit list of binding patterns.

        Blocks absent from some pattern get the ``DEFAULT_TARGET`` sentinel
        choice (leave the registry's default binding in place).
        """
        blocks: dict[str, list[str]] = {}
        for pat in patterns:
            for name, target in pat.items():
                blocks.setdefault(name, [])
                if target not in blocks[name]:
                    blocks[name].append(target)
        for name in blocks:
            if any(name not in pat for pat in patterns):
                blocks[name].insert(0, DEFAULT_TARGET)
        return cls(
            step_builder,
            blocks,
            registry=registry,
            baseline_target=DEFAULT_TARGET,
        )

    def mark_illegal(
        self, verdicts: Mapping[tuple[str, str], str]
    ) -> None:
        """Record statically-illegal ``(block, target)`` bindings with their
        reasons.  Candidates selecting any of them are reported by
        ``pruned()`` and skipped by every search strategy.  The
        ``DEFAULT_TARGET`` sentinel is never illegal (it is whatever the
        registry would do anyway), and marking it is rejected."""
        for (block, target), reason in verdicts.items():
            if target == DEFAULT_TARGET:
                raise ValueError(
                    f"cannot mark default binding of '{block}' illegal"
                )
            self._illegal[(block, target)] = str(reason)

    def pruned(self, cand: Candidate) -> str | None:
        for a, c in zip(self.axes, cand):
            label = a.choices[c]
            if label == DEFAULT_TARGET:
                continue
            reason = self._illegal.get((a.name, label))
            if reason is not None:
                return f"{a.name}->{label}: {reason}"
        return None

    def binding_of(self, cand: Candidate) -> dict[str, str]:
        """The registry binding for a candidate (all axes, sans defaults)."""
        return {
            a.name: a.choices[c]
            for a, c in zip(self.axes, cand)
            if a.choices[c] != DEFAULT_TARGET
        }

    def deploy_mapping(self, cand: Candidate) -> dict[str, str]:
        """Persisted plans must pin *every* measured axis, baseline choices
        included: a plan that omitted a block left on ``ref`` would deploy
        under the registry's default preference (xla-first) — a binding
        that was never the measured winner."""
        return self.binding_of(cand)

    def build(self, cand: Candidate) -> Callable[..., Any]:
        self.validate(cand)
        binding = self.binding_of(cand)
        with self.registry.bind(binding):
            fn = self.step_builder()

        def run(*args: Any, **kwargs: Any) -> Any:
            with self.registry.bind(binding):
                return fn(*args, **kwargs)

        return run

    @contextlib.contextmanager
    def bind(self, cand: Candidate):
        with self.registry.bind(self.binding_of(cand)):
            yield
