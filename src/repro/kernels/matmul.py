"""Blocked MXU matmul — the cuBLAS-analogue shelf entry.

Grid (M/bm, N/bn, K/bk) with the K dimension innermost ("arbitrary"
semantics) so the f32 accumulator tile stays resident in VMEM across the
contraction.  Block shapes default to 128x128x128: MXU-aligned (128 lanes,
8-sublane f32 tiles) and small enough that a (bm,bk)+(bk,bn)+(bm,bn) working
set (~192 KiB at f32) fits VMEM (~16 MiB) with ample double-buffering room.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {k} vs {k2}")
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"shapes ({m},{k})x({k},{n}) must tile by "
            f"({block_m},{block_n},{block_k}); pad first (interface adapter "
            "handles this)"
        )
    grid = (m // block_m, n // block_n, k // block_k)
    out_dtype = jnp.promote_types(a.dtype, b.dtype)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)


def _schur_kernel(c_ref, a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    """o = c - a @ b (the LU trailing update), fused accumulate."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = c_ref[...].astype(jnp.float32)

    acc_ref[...] -= jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def schur_update_pallas(
    c: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused C - A@B.  Saves one HBM round trip of C versus matmul-then-sub —
    this is why LU registers its own shelf kernel instead of reusing matmul."""
    m, k = a.shape
    _, n = b.shape
    if c.shape != (m, n):
        raise ValueError(f"c shape {c.shape} != ({m},{n})")
    if m % block_m or n % block_n or k % block_k:
        raise ValueError("shapes must tile by the block sizes; pad first")
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_schur_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(c, a, b)
