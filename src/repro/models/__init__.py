"""Model zoo: unified decoder LM covering the 10 assigned architectures."""

from repro.models import lm  # noqa: F401
from repro.models.params import (  # noqa: F401
    ParamMeta,
    abstract_params,
    count_params,
    init_params,
    param_bytes,
    spec_tree,
)
