"""Device memory envelopes: what a candidate program must fit inside.

The paper's FPGA path gates every offload pattern on a *resource-fit*
check — reject patterns whose HLS resource estimate exceeds the board —
before any measurement is spent.  Our GPU/TPU analogue needs the board
side of that inequality: a :class:`DeviceEnvelope` names a target's
high-bandwidth memory (HBM, or host RAM on CPU backends) and, where it
matters for kernel tiling, the fast on-chip scratch (TPU VMEM / GPU
shared memory).

Two sources:

* :func:`probe_device_envelope` asks the live ``jax.devices()`` runtime
  (``device.memory_stats()["bytes_limit"]`` where the backend exposes it;
  CPU backends expose nothing and degrade to host RAM via psutil).
* :data:`STATIC_ENVELOPES` is an overridable table of named targets for
  cross-compile "what-if" planning — size a serve config for an
  ``a100-40g`` from a CPU CI container, or against the synthetic
  ``tiny-32m`` board the preflight tests reject configs on.

:func:`resolve_envelope` is the one entry point the analysis passes use:
it accepts an envelope object, a static-table name, ``"host"``/None/True
(probe the live runtime), and nothing else.
"""

from __future__ import annotations

import dataclasses

MiB = 1 << 20
GiB = 1 << 30


@dataclasses.dataclass(frozen=True)
class DeviceEnvelope:
    """Memory capacity of one offload target.

    ``memory_bytes`` is the working-set bound (HBM, or host RAM for CPU
    backends); ``vmem_bytes`` the fast on-chip scratch a tiled kernel's
    working tiles must fit (TPU VMEM; None where tiling is the compiler's
    problem).  ``source`` records whether the numbers were probed from
    the live runtime or declared statically.
    """

    name: str
    platform: str  # "cpu" | "gpu" | "tpu"
    memory_bytes: int
    vmem_bytes: int | None = None
    source: str = "static"  # "static" | "probed"
    notes: str = ""

    def headroom_bytes(self, need_bytes: int) -> int:
        """Bytes left after ``need_bytes`` (negative = does not fit)."""
        return self.memory_bytes - int(need_bytes)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        mem = self.memory_bytes / GiB
        vmem = (
            f", vmem {self.vmem_bytes / MiB:.0f} MiB"
            if self.vmem_bytes
            else ""
        )
        return f"{self.name} ({self.platform}, {mem:.1f} GiB{vmem}, {self.source})"


#: Named what-if targets for cross-compile planning.  Capacities are the
#: published per-device numbers (approximate where vendors round); VMEM
#: is the per-core budget a Pallas kernel's resident tiles must fit.
STATIC_ENVELOPES: dict[str, DeviceEnvelope] = {
    e.name: e
    for e in (
        DeviceEnvelope("tpu-v4", "tpu", 32 * GiB, vmem_bytes=16 * MiB,
                       notes="32 GiB HBM2 per chip; ~16 MiB VMEM per core"),
        DeviceEnvelope("tpu-v5e", "tpu", 16 * GiB, vmem_bytes=16 * MiB,
                       notes="16 GiB HBM2 per chip"),
        DeviceEnvelope("tpu-v5p", "tpu", 95 * GiB, vmem_bytes=16 * MiB,
                       notes="95 GiB HBM2e per chip"),
        DeviceEnvelope("a100-40g", "gpu", 40 * GiB,
                       notes="A100 SXM/PCIe 40 GiB HBM2"),
        DeviceEnvelope("a100-80g", "gpu", 80 * GiB,
                       notes="A100 80 GiB HBM2e"),
        DeviceEnvelope("h100-80g", "gpu", 80 * GiB,
                       notes="H100 SXM 80 GiB HBM3"),
        DeviceEnvelope("l4-24g", "gpu", 24 * GiB,
                       notes="L4 24 GiB GDDR6 (inference tier)"),
        DeviceEnvelope("cpu-host-16g", "cpu", 16 * GiB,
                       notes="CI-container class host; the lint default so "
                             "ratcheted verdicts are host-independent"),
        DeviceEnvelope("tiny-32m", "cpu", 32 * MiB,
                       notes="synthetic undersized board for preflight "
                             "rejection tests and CI smoke"),
    )
}


def _host_memory_bytes() -> int:
    """Total host RAM, best effort (psutil, then sysconf, then 16 GiB)."""
    try:
        import psutil

        return int(psutil.virtual_memory().total)
    except Exception:  # noqa: BLE001 — psutil is optional
        pass
    try:
        import os

        return os.sysconf("SC_PAGE_SIZE") * os.sysconf("SC_PHYS_PAGES")
    except (ValueError, OSError, AttributeError):
        return 16 * GiB


def probe_device_envelope(device=None) -> DeviceEnvelope:
    """Envelope of a live ``jax`` device.

    GPU/TPU backends report an allocator ``bytes_limit`` through
    ``memory_stats()``; CPU backends return None there, so the probe
    degrades to total host RAM (the CPU "HBM" is the host's).
    """
    import jax

    if device is None:
        device = jax.devices()[0]
    stats = None
    try:
        stats = device.memory_stats()
    except Exception:  # noqa: BLE001 — older backends raise instead
        stats = None
    limit = 0
    if stats:
        limit = int(
            stats.get("bytes_limit")
            or stats.get("bytes_reservable_limit")
            or 0
        )
    kind = getattr(device, "device_kind", device.platform)
    if limit > 0:
        return DeviceEnvelope(
            name=str(kind), platform=device.platform,
            memory_bytes=limit, source="probed",
        )
    return DeviceEnvelope(
        name=f"host:{kind}", platform=device.platform,
        memory_bytes=_host_memory_bytes(), source="probed",
        notes="backend exposes no memory_stats; host RAM used",
    )


def resolve_envelope(spec) -> DeviceEnvelope:
    """One resolution policy for every pass.

    ``DeviceEnvelope`` passes through; ``None``/``True``/``"host"`` probe
    the live runtime; any other string looks up :data:`STATIC_ENVELOPES`
    (unknown names fail loudly with the known ones listed).
    """
    if isinstance(spec, DeviceEnvelope):
        return spec
    if spec is None or spec is True or spec == "host":
        return probe_device_envelope()
    if isinstance(spec, str):
        try:
            return STATIC_ENVELOPES[spec]
        except KeyError:
            raise KeyError(
                f"unknown device envelope '{spec}'; known: "
                f"{sorted(STATIC_ENVELOPES)} (or 'host' to probe)"
            ) from None
    raise TypeError(
        f"envelope spec must be a DeviceEnvelope, a name, 'host' or None; "
        f"got {type(spec).__name__}"
    )
