"""Step builders shared by the trainer, the server and the dry-run.

Each builder returns a pure function suitable for jax.jit with explicit
in/out shardings; abstract-value builders produce the matching
ShapeDtypeStruct trees (``input_specs``) so the dry-run lowers the exact
production program with zero allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import lm
from repro.models import params as pm
from repro.optim.adamw import AdamW, OptState
from repro.optim.schedule import warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    base_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    microbatch: int | None = None  # grad-accumulation chunks of the batch


# -- abstract inputs (the dry-run contract) -------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    tok = lambda bb, ss: jax.ShapeDtypeStruct((bb, ss), jnp.int32)
    if shape.kind == "train":
        if cfg.frontend == "patch_embed":
            return {
                "embeds": jax.ShapeDtypeStruct(
                    (b, s, cfg.d_model), jnp.dtype(cfg.compute_dtype)
                ),
                "labels": tok(b, s),
            }
        return {"tokens": tok(b, s), "labels": tok(b, s)}
    if shape.kind == "prefill":
        if cfg.frontend == "patch_embed":
            return {
                "embeds": jax.ShapeDtypeStruct(
                    (b, s, cfg.d_model), jnp.dtype(cfg.compute_dtype)
                )
            }
        return {"tokens": tok(b, s)}
    # decode: one new token; the seq_len lives in the cache
    return {"tokens": tok(b, 1)}


def abstract_state(cfg: ArchConfig, opt: AdamW | None = None):
    """(params, opt_state) as ShapeDtypeStructs."""
    metas = lm.build_metas(cfg)
    params = pm.abstract_params(metas)
    if opt is None:
        return params, None
    mdt = jnp.dtype(opt.moment_dtype)
    mom = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, mdt), params)
    opt_state = OptState(
        mu=mom,
        nu=jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, mdt), params),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )
    return params, opt_state


def abstract_cache(
    cfg: ArchConfig,
    shape: ShapeConfig,
    page_size: int | None = None,
    n_pages: int | None = None,
):
    """Abstract KV cache for this cell — contiguous, or block-paged when
    ``page_size``/``n_pages`` are given.  The paged tree includes the
    ``pages`` page-table operand (``(B, max_pages)`` int32) the decode
    program gathers through, so the dry-run lowers the exact paged
    serving program with zero allocation."""
    metas = lm.cache_metas_tree(
        cfg, shape.global_batch, shape.seq_len,
        page_size=page_size, n_pages=n_pages,
    )
    tree = pm.abstract_params(metas)
    if page_size is not None:
        max_pages = -(-shape.seq_len // page_size)
        tree["pages"] = jax.ShapeDtypeStruct(
            (shape.global_batch, max_pages), jnp.int32
        )
    return tree


# -- steps ------------------------------------------------------------------------


def make_train_step(
    cfg: ArchConfig,
    opt: AdamW,
    hyper: TrainHyper = TrainHyper(),
    grad_shardings: Any = None,
):
    """``grad_shardings``: optional pytree of NamedSharding matching params.
    Constraining gradients to the parameter sharding makes GSPMD emit
    reduce-scatters into the ZeRO shards instead of all-reducing the full
    replicated gradient tree (at 35 GB+ of f32 grads the difference is the
    entire collective budget of the step)."""

    def loss_of(params, batch):
        return lm.loss_fn(params, batch, cfg)

    def _constrain_grads(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(
            jax.lax.with_sharding_constraint, grads, grad_shardings
        )

    def train_step(params, opt_state, batch):
        if hyper.microbatch and hyper.microbatch > 1:
            n = hyper.microbatch

            def micro(carry, mb):
                acc, metr_acc = carry
                (_, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True
                )(params, mb)
                grads = _constrain_grads(grads)
                acc = jax.tree.map(jnp.add, acc, grads)
                metr_acc = jax.tree.map(jnp.add, metr_acc, metrics)
                return (acc, metr_acc), None

            mbs = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch
            )
            # accumulate grads in the moment dtype: a full f32 grad tree is
            # 4 bytes/param resident for the whole step — at 480B params
            # ZeRO-sharded over 256 chips that alone is 7.5 GB/chip
            acc_dt = jnp.dtype(opt.moment_dtype)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params
            )
            zero_m = {"loss": 0.0, "ce": 0.0, "aux": 0.0}
            zero_m = jax.tree.map(jnp.float32, zero_m)
            (grads, metrics), _ = jax.lax.scan(micro, (zero_g, zero_m), mbs)
            grads = jax.tree.map(lambda g: g / n, grads)
            metrics = jax.tree.map(lambda m: m / n, metrics)
        else:
            (_, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch
            )
            grads = _constrain_grads(grads)
        lr = warmup_cosine(
            opt_state.step, hyper.base_lr, hyper.warmup_steps, hyper.total_steps
        )
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, shape: ShapeConfig):
    cache_metas = lm.cache_metas_tree(cfg, shape.global_batch, shape.seq_len)

    def prefill_step(params, batch):
        cache = pm.init_params(cache_metas, 0)  # zeros (+ index 0)
        # serving samples from the LAST position only: run the backbone over
        # the full prompt but project just the final hidden state — the full
        # (B, S, V) logits tensor (tens of GB at 32k x 128k-vocab) is never
        # materialised.
        x, _, new_cache = lm.backbone(params, batch, cfg, "prefill", cache)
        logits_last = lm.head(params, x[:, -1:, :], cfg)
        new_cache["index"] = jnp.full(
            (shape.global_batch,), shape.seq_len, jnp.int32
        )
        return logits_last[:, 0, :], new_cache

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, cache, batch):
        logits, new_cache = lm.decode_step(params, batch["tokens"], cfg, cache)
        return logits[:, 0, :], new_cache

    return decode_step
