"""Host-interface matching (paper §3.4 C-1 / C-2).

When a function block is replaced by an accelerated implementation (a Pallas
kernel / XLA library on TPU; cuFFT / an IP core in the paper), the host-side
program and the replacement must agree on the calling interface.  The paper's
rules, implemented here:

* C-1 — interfaces agree: generate the glue and proceed (no user interaction).
* C-2 — interfaces differ:
    - pure dtype differences that a cast fixes (float vs double in the paper;
      f32/f64/bf16 here) are adapted **without** user confirmation;
    - replacement omits *optional* caller arguments: dropped automatically;
    - anything else (argument count/meaning, return arity) requires explicit
      user confirmation before a verification trial is attempted.

TPU-specific extension (the analogue of matching an IP core's port widths):
accelerated TPU blocks frequently require lane-aligned shapes (multiples of
128).  ``pad_to`` / ``unpad_from`` provide shape adaptation, and
``InterfaceSpec`` entries may declare an ``align`` requirement which the
adapter satisfies transparently — alignment padding is value-preserving, so,
like casts, it needs no confirmation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import numpy as np

# Cast lattice: which automatic dtype adaptations are considered "benign".
# (paper: "float と double 等キャストすればよいだけであれば、特にユーザ確認
#  せずに試行に入ってもよい")
_CASTABLE = {
    ("float64", "float32"),
    ("float32", "float64"),
    ("float32", "bfloat16"),
    ("bfloat16", "float32"),
    ("float64", "bfloat16"),
    ("bfloat16", "float64"),
    ("int32", "int64"),
    ("int64", "int32"),
    ("complex128", "complex64"),
    ("complex64", "complex128"),
}


@dataclasses.dataclass(frozen=True)
class Param:
    """One parameter of a block interface."""

    name: str
    dtype: str  # numpy dtype name, e.g. "float32", "complex64"
    rank: int | None = None  # None = any rank
    optional: bool = False
    align: int | None = None  # required divisor of trailing dims (TPU lanes)


@dataclasses.dataclass(frozen=True)
class InterfaceSpec:
    """Callable interface: ordered params and return dtypes."""

    params: tuple[Param, ...]
    returns: tuple[str, ...]  # dtype names of outputs

    @property
    def required(self) -> tuple[Param, ...]:
        return tuple(p for p in self.params if not p.optional)


class InterfaceMismatch(Exception):
    """Raised when adaptation needs user confirmation and policy denies it."""


@dataclasses.dataclass
class Policy:
    """What may be adapted silently (paper C-2 defaults)."""

    auto_cast: bool = True
    auto_drop_optional: bool = True
    auto_pad: bool = True
    # callback invoked for semantic interface changes; returns True to allow.
    confirm: Callable[[str], bool] = lambda msg: False


@dataclasses.dataclass
class Adaptation:
    """A concrete plan for wrapping a replacement behind the source interface."""

    arg_casts: tuple[tuple[int, str] | None, ...]  # per-src-arg: (dst idx, dtype)
    ret_casts: tuple[str | None, ...]
    pads: tuple[int | None, ...]  # per-dst-arg alignment
    dropped: tuple[str, ...]  # names of source args dropped (optionals)
    confirmed: tuple[str, ...]  # messages the user confirmed
    exact: bool  # True => C-1 (no adaptation needed)

    def wrap(self, fn: Callable[..., Any]) -> Callable[..., Any]:
        """Wrap ``fn`` (replacement) so it accepts source-interface calls."""

        arg_casts = self.arg_casts
        ret_casts = self.ret_casts
        pads = self.pads

        def adapted(*args: Any) -> Any:
            fwd: list[Any] = []
            orig_shapes: list[tuple[int, ...] | None] = []
            for i, plan in enumerate(arg_casts):
                if plan is None:  # dropped source argument
                    continue
                _, dt = plan
                a = args[i]
                if dt is not None and hasattr(a, "astype"):
                    a = a.astype(dt)
                j = len(fwd)
                pad = pads[j] if j < len(pads) else None
                if pad is not None and hasattr(a, "shape") and a.ndim >= 1:
                    orig_shapes.append(tuple(a.shape))
                    a = pad_to(a, pad)
                else:
                    orig_shapes.append(None)
                fwd.append(a)
            out = fn(*fwd)
            outs = out if isinstance(out, tuple) else (out,)
            adapted_outs = []
            for k, o in enumerate(outs):
                # un-pad outputs whose shape was inflated together with arg 0
                if (
                    orig_shapes
                    and orig_shapes[0] is not None
                    and hasattr(o, "shape")
                    and o.ndim == len(orig_shapes[0])
                    and all(
                        so >= sg for so, sg in zip(o.shape, orig_shapes[0])
                    )
                    and tuple(o.shape) != orig_shapes[0]
                ):
                    o = unpad_from(o, orig_shapes[0])
                dt = ret_casts[k] if k < len(ret_casts) else None
                if dt is not None and hasattr(o, "astype"):
                    o = o.astype(dt)
                adapted_outs.append(o)
            return adapted_outs[0] if len(adapted_outs) == 1 else tuple(adapted_outs)

        adapted.__name__ = getattr(fn, "__name__", "adapted")
        adapted.__wrapped__ = fn  # type: ignore[attr-defined]
        return adapted


def pad_to(x: Any, align: int) -> Any:
    """Zero-pad the trailing two dims (or last dim for rank-1) to ``align``."""
    if align is None or x.ndim == 0:
        return x
    shape = list(x.shape)
    ndims = min(2, x.ndim)
    pad_width = [(0, 0)] * x.ndim
    changed = False
    for d in range(x.ndim - ndims, x.ndim):
        rem = (-shape[d]) % align
        if rem:
            pad_width[d] = (0, rem)
            changed = True
    if not changed:
        return x
    return np.pad(x, pad_width) if isinstance(x, np.ndarray) else _jnp_pad(x, pad_width)


def _jnp_pad(x: Any, pad_width: Sequence[tuple[int, int]]) -> Any:
    import jax.numpy as jnp

    return jnp.pad(x, pad_width)


def unpad_from(x: Any, shape: tuple[int, ...]) -> Any:
    slices = tuple(slice(0, s) for s in shape)
    return x[slices]


def match_interfaces(
    src: InterfaceSpec, dst: InterfaceSpec, policy: Policy | None = None
) -> Adaptation:
    """Compute the adaptation plan from a source call interface to a
    replacement interface, following the paper's C-1/C-2 rules.

    Raises InterfaceMismatch when a semantic change is needed and the policy's
    ``confirm`` callback declines it.
    """

    policy = policy or Policy()
    confirmed: list[str] = []

    def ask(msg: str) -> None:
        if not policy.confirm(msg):
            raise InterfaceMismatch(msg)
        confirmed.append(msg)

    n_src, n_dst = len(src.params), len(dst.params)
    arg_casts: list[tuple[int, str] | None] = []
    dropped: list[str] = []
    exact = True

    if n_src < len(dst.required):
        ask(
            f"replacement requires {len(dst.required)} args but source "
            f"provides {n_src}; call with replacement defaults?"
        )
        exact = False

    for i, sp in enumerate(src.params):
        if i < n_dst:
            dp = dst.params[i]
            if sp.dtype == dp.dtype:
                arg_casts.append((i, None))
            elif (sp.dtype, dp.dtype) in _CASTABLE:
                if not policy.auto_cast:
                    ask(f"cast arg '{sp.name}' {sp.dtype}->{dp.dtype}?")
                arg_casts.append((i, dp.dtype))
                exact = False
            else:
                ask(
                    f"arg '{sp.name}' type {sp.dtype} incompatible with "
                    f"replacement '{dp.name}' type {dp.dtype}; reinterpret?"
                )
                arg_casts.append((i, dp.dtype))
                exact = False
            if sp.rank is not None and dp.rank is not None and sp.rank != dp.rank:
                ask(
                    f"arg '{sp.name}' rank {sp.rank} != replacement rank "
                    f"{dp.rank}; reshape semantics change?"
                )
                exact = False
        else:
            # Source passes more arguments than the replacement takes.
            if sp.optional and policy.auto_drop_optional:
                arg_casts.append(None)
                dropped.append(sp.name)
                exact = False
            else:
                ask(
                    f"source arg '{sp.name}' has no replacement counterpart; "
                    "drop it?"
                )
                arg_casts.append(None)
                dropped.append(sp.name)
                exact = False

    # Returns.
    if len(src.returns) != len(dst.returns):
        ask(
            f"return arity differs: source {len(src.returns)} vs "
            f"replacement {len(dst.returns)}; accept replacement outputs?"
        )
        exact = False
    ret_casts: list[str | None] = []
    for k, rs in enumerate(src.returns):
        if k >= len(dst.returns):
            break
        rd = dst.returns[k]
        if rs == rd:
            ret_casts.append(None)
        elif (rd, rs) in _CASTABLE:
            if not policy.auto_cast:
                ask(f"cast return {rd}->{rs}?")
            ret_casts.append(rs)
            exact = False
        else:
            ask(f"return type {rd} incompatible with expected {rs}; cast anyway?")
            ret_casts.append(rs)
            exact = False

    pads = tuple(p.align for p in dst.params)
    if any(p is not None for p in pads):
        if not policy.auto_pad:
            ask("replacement requires lane-aligned shapes; zero-pad inputs?")
        exact = exact and all(p is None for p in pads)

    return Adaptation(
        arg_casts=tuple(arg_casts),
        ret_casts=tuple(ret_casts),
        pads=pads,
        dropped=tuple(dropped),
        confirmed=tuple(confirmed),
        exact=exact,
    )


def spec_from_arrays(
    args: Sequence[Any], returns: Sequence[Any], optional_from: int | None = None
) -> InterfaceSpec:
    """Build an InterfaceSpec by inspecting example arrays."""

    params = []
    for i, a in enumerate(args):
        a = np.asarray(a)
        params.append(
            Param(
                name=f"arg{i}",
                dtype=a.dtype.name,
                rank=a.ndim,
                optional=optional_from is not None and i >= optional_from,
            )
        )
    rets = tuple(np.asarray(r).dtype.name for r in returns)
    return InterfaceSpec(params=tuple(params), returns=rets)
