"""Mamba-2 block (SSD): in_proj -> causal depthwise conv -> selective scan
-> gated RMSNorm -> out_proj.

The scan itself goes through the "ssd_scan" FunctionBlock (ref = sequential
recurrence, xla = chunked SSD, pallas = chunked SSD with the Pallas
intra-chunk kernel).  Decode keeps O(1) state per layer: the conv window
(d_conv-1 last inputs) and the SSM state (H, N, P) — this is why SSM archs
run the 500k-context shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import blocks
from repro.models.params import ParamMeta
from repro.models.layers import tp_out_einsum
from repro.sharding.utils import constrain


def ssm_metas(cfg: ArchConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    dt = cfg.param_dtype
    di = s.d_inner(d)
    h = s.n_heads(d)
    cd = s.conv_dim(d)
    d_in_proj = 2 * di + 2 * s.d_state + h  # z, xBC, dt
    return {
        "in_proj": ParamMeta((d, d_in_proj), ("embed", "ssm_inner"), dt),
        "conv_w": ParamMeta((s.d_conv, cd), (None, "ssm_inner"), dt, scale=0.1),
        "conv_b": ParamMeta((cd,), ("ssm_inner",), dt, init="zeros"),
        "a_log": ParamMeta((h,), ("ssm_heads",), dt, init="ssm_a"),
        "d_skip": ParamMeta((h,), ("ssm_heads",), dt, init="ones"),
        "dt_bias": ParamMeta((h,), ("ssm_heads",), dt, init="dt_bias"),
        "norm": ParamMeta((di,), ("ssm_inner",), dt, init="ones"),
        "out_proj": ParamMeta((di, d), ("ssm_inner", "embed"), dt),
    }


def ssm_state_metas(cfg: ArchConfig, batch: int) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    h = s.n_heads(d)
    return {
        "conv": ParamMeta(
            (batch, s.d_conv - 1, s.conv_dim(d)),
            ("act_batch", None, "ssm_inner"), "float32", init="zeros",
        ),
        "ssm": ParamMeta(
            (batch, h, s.d_state, s.head_dim),
            ("act_batch", "ssm_heads_act", None, None), "float32", init="zeros",
        ),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C)."""
    dconv, c = w.shape
    out = jax.lax.conv_general_dilated(
        xbc,
        w.reshape(dconv, 1, c).astype(xbc.dtype),
        window_strides=(1,),
        padding=[(dconv - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c,
    )
    return out + b.astype(xbc.dtype)


def _split_zxbcdt(zxbcdt: jax.Array, cfg: ArchConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    cd = s.conv_dim(cfg.d_model)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + cd]
    dt = zxbcdt[..., di + cd :]
    return z, xbc, dt


def ssm_forward(
    p: dict,
    x: jax.Array,  # (B, S, D)
    cfg: ArchConfig,
    state: dict | None = None,
    mode: str = "train",
):
    s = cfg.ssm
    b, seq, d = x.shape
    cdty = jnp.dtype(cfg.compute_dtype)
    di = s.d_inner(d)
    h = s.n_heads(d)
    xc = x.astype(cdty)

    zxbcdt = jnp.einsum("bsd,dk->bsk", xc, p["in_proj"].astype(cdty))
    zxbcdt = constrain(zxbcdt, "act_batch", None, "ssm_inner_act")
    z, xbc, dt_raw = _split_zxbcdt(zxbcdt, cfg)

    if mode == "decode":
        assert state is not None
        window = jnp.concatenate([state["conv"].astype(cdty), xbc], axis=1)
        conv_out = jnp.einsum(
            "bwc,wc->bc", window, p["conv_w"].astype(cdty)
        ) + p["conv_b"].astype(cdty)
        conv_out = conv_out[:, None, :]
        new_conv = window[:, 1:, :]
    else:
        conv_out = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        new_conv = xbc[:, -(s.d_conv - 1) :, :] if state is not None else None
    xbc_a = jax.nn.silu(conv_out)

    x_ssm = xbc_a[..., :di].reshape(b, seq, h, s.head_dim)
    bmat = xbc_a[..., di : di + s.d_state]
    cmat = xbc_a[..., di + s.d_state :]
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,)

    if mode == "decode":
        # one-step recurrence against the carried state
        ssm_prev = state["ssm"].astype(jnp.float32)  # (B,H,N,P)
        dt0 = dt[:, 0]  # (B,H)
        decay = jnp.exp(a[None, :] * dt0)
        upd = jnp.einsum(
            "bh,bn,bhp->bhnp", dt0, bmat[:, 0].astype(jnp.float32),
            x_ssm[:, 0].astype(jnp.float32),
        )
        ssm_new = ssm_prev * decay[..., None, None] + upd
        y = jnp.einsum(
            "bn,bhnp->bhp", cmat[:, 0].astype(jnp.float32), ssm_new
        )[:, None]  # (B,1,H,P)
        new_state = {"conv": new_conv.astype(state["conv"].dtype),
                     "ssm": ssm_new.astype(state["ssm"].dtype)}
    else:
        h0 = state["ssm"].astype(jnp.float32) if state is not None else None
        y, ssm_fin = blocks.call(
            "ssd_scan", x_ssm, dt, a, bmat, cmat, chunk=s.chunk, h0=h0
        )
        new_state = None
        if state is not None:
            new_state = {
                "conv": new_conv.astype(state["conv"].dtype),
                "ssm": ssm_fin.astype(state["ssm"].dtype),
            }

    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * x_ssm.astype(
        jnp.float32
    )
    y = y.reshape(b, seq, di)
    # gated RMSNorm (Mamba-2): norm(y * silu(z)) * w
    g = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(g * g, axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(ms + cfg.norm_eps) * p["norm"].astype(jnp.float32)
    out = tp_out_einsum("bsk,kd->bsd", g.astype(cdty),
                        p["out_proj"].astype(cdty), cdty)
    return out, new_state
