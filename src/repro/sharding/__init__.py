from repro.sharding.utils import (  # noqa: F401
    constrain,
    current_mesh,
    current_rules,
    resolve_spec,
    use_sharding,
)
from repro.sharding.specs import (  # noqa: F401
    DEFAULT_RULES,
    rules_for,
)
