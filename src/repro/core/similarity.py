"""Similarity detection (paper §3.4 B-2) — the Deckard analogue.

Deckard [Jiang et al., ICSE'07] detects code clones by mapping every AST
subtree to a *characteristic vector* — occurrence counts of node kinds in the
subtree (with small subtrees merged upward) — then clustering vectors by
Euclidean distance with a size-sensitive threshold.  The paper runs Deckard
between application functions (A-2 candidates) and the reference code stored
in the pattern DB, and treats above-threshold pairs as "this local function is
a copied/modified version of a known offloadable block".

This module implements the same algorithm over Python ASTs:

* ``char_vector(code)`` — counts of a fixed vocabulary of AST node kinds,
  augmented with loop-nest-depth buckets (Deckard's q-level vectors).
* ``similarity(a, b)``  — 1 - ||va - vb||_1 / (||va||_1 + ||vb||_1), a
  size-normalised distance in [0, 1]; 1.0 = identical vectors.  This is the
  "1 - normalised distance" form of Deckard's clustering criterion.

As in the paper, *newly written independent code* will not pass the threshold
— only copies and light modifications (renames, comments, constant tweaks,
small edits) will.  The default threshold (0.85) is calibrated by the tests
against exactly that scenario.
"""

from __future__ import annotations

import ast
import dataclasses
import math
import textwrap
from typing import Iterable

# The node-kind vocabulary.  Deckard uses "relevant" parse-tree nodes; we use
# the structural Python AST kinds, skipping trivia (Load/Store ctx etc.).
_VOCAB = (
    "FunctionDef", "arguments", "arg", "Return",
    "Assign", "AugAssign", "AnnAssign",
    "For", "While", "If", "Break", "Continue",
    "BoolOp", "BinOp", "UnaryOp", "Compare", "Call", "IfExp",
    "Attribute", "Subscript", "Name", "Constant", "Tuple", "List", "Slice",
    "Add", "Sub", "Mult", "Div", "FloorDiv", "Mod", "Pow",
    "BitXor", "BitAnd", "BitOr", "LShift", "RShift",
    "Lt", "Gt", "LtE", "GtE", "Eq", "NotEq", "USub",
    "Lambda", "ListComp", "Dict", "Starred", "keyword",
)
_INDEX = {k: i for i, k in enumerate(_VOCAB)}
_DEPTH_BUCKETS = 4  # loop-nest depth histogram appended to the vector


@dataclasses.dataclass(frozen=True)
class CharVector:
    """Deckard characteristic vector for one code fragment."""

    counts: tuple[int, ...]

    @property
    def size(self) -> int:
        return sum(self.counts)

    def l1(self) -> int:
        return sum(self.counts)

    def distance(self, other: "CharVector") -> float:
        return sum(abs(a - b) for a, b in zip(self.counts, other.counts))


def _iter_nodes(tree: ast.AST) -> Iterable[tuple[ast.AST, int]]:
    """Yield (node, loop_depth) pairs."""
    stack: list[tuple[ast.AST, int]] = [(tree, 0)]
    while stack:
        node, depth = stack.pop()
        yield node, depth
        bump = 1 if isinstance(node, (ast.For, ast.While)) else 0
        for child in ast.iter_child_nodes(node):
            stack.append((child, depth + bump))


def char_vector(code: str | ast.AST) -> CharVector:
    if isinstance(code, str):
        tree = ast.parse(textwrap.dedent(code))
    else:
        tree = code
    counts = [0] * (len(_VOCAB) + _DEPTH_BUCKETS)
    for node, depth in _iter_nodes(tree):
        kind = type(node).__name__
        idx = _INDEX.get(kind)
        if idx is not None:
            counts[idx] += 1
        if isinstance(node, (ast.For, ast.While)):
            counts[len(_VOCAB) + min(depth, _DEPTH_BUCKETS - 1)] += 1
        # operators live one level down in BinOp/Compare nodes
        if isinstance(node, ast.BinOp):
            op_idx = _INDEX.get(type(node.op).__name__)
            if op_idx is not None:
                counts[op_idx] += 1
        if isinstance(node, ast.UnaryOp):
            op_idx = _INDEX.get(type(node.op).__name__)
            if op_idx is not None:
                counts[op_idx] += 1
        if isinstance(node, ast.Compare):
            for op in node.ops:
                op_idx = _INDEX.get(type(op).__name__)
                if op_idx is not None:
                    counts[op_idx] += 1
    return CharVector(counts=tuple(counts))


def similarity(code_a: str | CharVector, code_b: str | CharVector) -> float:
    """Size-normalised similarity in [0, 1]."""
    va = code_a if isinstance(code_a, CharVector) else char_vector(code_a)
    vb = code_b if isinstance(code_b, CharVector) else char_vector(code_b)
    denom = va.l1() + vb.l1()
    if denom == 0:
        return 1.0
    return 1.0 - va.distance(vb) / denom


def cosine(code_a: str | CharVector, code_b: str | CharVector) -> float:
    """Cosine similarity variant (used as a secondary gate)."""
    va = code_a if isinstance(code_a, CharVector) else char_vector(code_a)
    vb = code_b if isinstance(code_b, CharVector) else char_vector(code_b)
    dot = sum(a * b for a, b in zip(va.counts, vb.counts))
    na = math.sqrt(sum(a * a for a in va.counts))
    nb = math.sqrt(sum(b * b for b in vb.counts))
    if na == 0 or nb == 0:
        return 1.0 if na == nb else 0.0
    return dot / (na * nb)


DEFAULT_THRESHOLD = 0.85


@dataclasses.dataclass(frozen=True)
class SimilarityHit:
    """An above-threshold match between local code and a DB reference."""

    local_name: str
    db_name: str
    score: float


def find_similar(
    func_defs,  # Iterable[ast_analysis.FuncDef]
    db_entries,  # Iterable[pattern_db.ReplacementEntry] with reference_code
    threshold: float = DEFAULT_THRESHOLD,
) -> list[SimilarityHit]:
    """B-2: match local function definitions against DB reference code."""
    hits: list[SimilarityHit] = []
    refs = [(e, char_vector(e.reference_code)) for e in db_entries if e.reference_code]
    for fd in func_defs:
        if not fd.source:
            continue
        try:
            v = char_vector(fd.source)
        except SyntaxError:  # pragma: no cover
            continue
        best: SimilarityHit | None = None
        for entry, ref_v in refs:
            s = similarity(v, ref_v)
            # secondary cosine gate guards against size-coincidence matches
            if s >= threshold and cosine(v, ref_v) >= threshold:
                if best is None or s > best.score:
                    best = SimilarityHit(fd.name, entry.name, s)
        if best is not None:
            hits.append(best)
    return hits
