"""Mamba-2 SSD (state-space duality) chunked scan kernel.

The SSD decomposition splits the selective-scan recurrence

    h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t ,   y_t = C_t h_t

into (i) an *intra-chunk* part that is pure matmul work (MXU-friendly:
G = C B^T masked by the decay kernel), (ii) a per-chunk output state, and
(iii) a cheap *inter-chunk* recurrence over chunk states.  The kernel below
computes (i)+(ii) for one (batch, head, chunk) per program — all tiles live
in VMEM: x (L,P), B/C (L,N), the (L,L) decay/score matrices.  The O(S)
inter-chunk scan runs in jnp on top (``ops.ssd_scan``).

This is the TPU-native adaptation of a GPU selective-scan: instead of a
warp-level scan primitive, reshape the work so the MXU eats the quadratic
intra-chunk part and the sequential part shrinks by a factor of L.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(
    x_ref,  # (1, L, 1, P)
    dt_ref,  # (1, L, 1)
    a_ref,  # (1, 1)
    b_ref,  # (1, L, N)
    c_ref,  # (1, L, N)
    y_ref,  # (1, L, 1, P)
    state_ref,  # (1, 1, 1, N, P)
    cumdecay_ref,  # (1, L, 1)
    total_ref,  # (1, 1, 1)
    *,
    chunk: int,
):
    L = chunk
    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32).reshape(L, 1)  # (L, 1)
    a = a_ref[0, 0].astype(jnp.float32)  # scalar (negative)
    bm = b_ref[0].astype(jnp.float32)  # (L, N)
    cm = c_ref[0].astype(jnp.float32)  # (L, N)

    a_seg = a * dt  # (L, 1)
    a_cum = jnp.cumsum(a_seg, axis=0)  # (L, 1)
    a_tot = a_cum[L - 1, 0]

    # decay kernel Lambda[i,j] = exp(a_cum[i]-a_cum[j]) on i>=j
    diff = a_cum - a_cum.reshape(1, L)  # (L, L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    lam = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    g = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)  # (L, L)
    w = g * lam * dt.reshape(1, L)  # weight includes dt_j
    y = jnp.dot(w, x, preferred_element_type=jnp.float32)  # (L, P)

    # chunk output state: sum_j exp(a_tot - a_cum_j) dt_j B_j x_j^T
    sw = dt * jnp.exp(a_tot - a_cum)  # (L, 1)
    state = jnp.dot((bm * sw).T, x, preferred_element_type=jnp.float32)  # (N,P)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    state_ref[0, 0, 0] = state.astype(state_ref.dtype)
    cumdecay_ref[0, :, 0] = jnp.exp(a_cum[:, 0]).astype(cumdecay_ref.dtype)
    total_ref[0, 0, 0] = jnp.exp(a_tot).astype(total_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunks_pallas(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    a: jax.Array,  # (H,)
    bmat: jax.Array,  # (B, S, N)
    cmat: jax.Array,  # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Per-chunk SSD terms.  Returns (y_intra, states, cumdecay, totals)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk} != 0")
    nc = s // chunk
    grid = (b, h, nc)
    a2 = a.reshape(h, 1).astype(jnp.float32)

    return pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, c_: (b_, c_, h_)),
            pl.BlockSpec((1, 1), lambda b_, h_, c_: (h_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec(
                (1, 1, 1, n, p), lambda b_, h_, c_: (b_, c_, h_, 0, 0)
            ),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, c_: (b_, c_, h_)),
            pl.BlockSpec((1, 1, 1), lambda b_, h_, c_: (b_, c_, h_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, n, p), jnp.float32),
            jax.ShapeDtypeStruct((b, s, h), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a2, bmat, cmat)
