"""SearchStrategy implementations over any SearchSpace.

All strategies measure through a shared ``MeasurementCache`` and produce a
``PlanReport`` whose trials keep the compile/runtime split per candidate.
Winner selection goes through a pluggable ``Objective``
(``objectives.Latency`` by default) — strategies never compare
``trial.seconds`` directly, so power-aware objectives work everywhere.

  SingleThenCombine   the paper's §4.2 Step-3 procedure, generalised to
                      n-ary axes: baseline, every (axis, choice) alone,
                      then the combination of per-axis winners, adopted
                      only if it beats the best single.
  GeneticSearch       the prior-work loop-offload GA (paper §3.2, refs
                      [32][33]), now working over arbitrary axis
                      cardinalities (n-ary genome: gene = choice index).
  CostGuidedSearch    rank candidates by a static cost model (HLO roofline
                      by default) and measure only the top-k — the FPGA
                      pre-filter the paper motivates with hours-long
                      compilations.
  ExhaustiveSearch    measure a listed (or fully enumerated) candidate set.
"""

from __future__ import annotations

import dataclasses
import random
import time
import warnings
from typing import Any, Callable, Iterable, Sequence

from repro.core import verify
from repro.core.planner.cache import MeasurementCache
from repro.core.planner.objectives import Objective, resolve_objective
from repro.core.planner.space import Candidate, SearchSpace


@dataclasses.dataclass
class PlanTrial:
    candidate: Candidate
    pattern: tuple[str, ...]  # axes moved off baseline, sorted
    mapping: dict[str, str]  # axis -> non-baseline choice label
    seconds: float
    compile_seconds: float
    speedup: float  # vs the report's baseline
    cached: bool  # satisfied from the MeasurementCache
    energy_joules: float | None = None  # per call, when a PowerMeter is wired
    energy_provenance: str | None = None  # "measured" | "estimated" | None
    score: float = 0.0  # objective score; lower is better


@dataclasses.dataclass
class PlanReport:
    # the measured baseline candidate; when a strategy skips the baseline
    # (ExhaustiveSearch(include_baseline=False)), this is the first measured
    # trial and all speedups are relative to that reference instead
    baseline_seconds: float
    trials: list[PlanTrial]
    best: PlanTrial
    search_seconds: float
    evaluations: int  # newly measured (non-cached) trials
    strategy: str
    generations: list[float] | None = None  # GA: best speedup per generation
    objective: str = "latency"  # objective that selected ``best``
    pruned: int = 0  # candidates skipped by the static legality pre-filter
    pruned_reasons: dict[str, str] = dataclasses.field(default_factory=dict)

    def trial(self, pattern: Iterable[str]) -> PlanTrial | None:
        key = tuple(sorted(pattern))
        for t in self.trials:
            if t.pattern == key:
                return t
        return None


def to_verification_report(report: PlanReport) -> verify.VerificationReport:
    """Downgrade a PlanReport to the legacy ``verify.VerificationReport``."""
    trials = [
        verify.Trial(t.pattern, t.seconds, t.speedup) for t in report.trials
    ]
    best = verify.Trial(
        report.best.pattern, report.best.seconds, report.best.speedup
    )
    return verify.VerificationReport(
        baseline_seconds=report.baseline_seconds,
        trials=trials,
        best=best,
        search_seconds=report.search_seconds,
    )


def rank_candidates_by_cost(
    space: SearchSpace,
    args: Sequence[Any],
    cost_fn: Callable[[SearchSpace, Candidate, Sequence[Any]], float]
    | None = None,
    skip: Callable[[Candidate], bool] | None = None,
) -> list[tuple[float, Candidate]]:
    """Every non-baseline candidate with its static cost estimate, sorted
    cheapest first.  Unrankable candidates (cost_fn raised) estimate as
    inf and sort last; callers detect a fully failed model by checking
    ``all(est == inf)``.  ``cost_fn`` defaults to the HLO roofline.
    ``skip`` drops candidates before the (trace-and-lower) cost model runs
    — the legality pre-filter seam, so illegal bindings cost nothing."""
    if cost_fn is None:
        from repro.core.planner.cost import make_roofline_cost_fn

        cost_fn = make_roofline_cost_fn()
    baseline = space.baseline()
    ranked: list[tuple[float, Candidate]] = []
    for cand in space.enumerate():
        if cand == baseline:
            continue
        if skip is not None and skip(cand):
            continue
        try:
            est = float(cost_fn(space, cand, args))
        except Exception:  # noqa: BLE001 — unrankable candidate
            est = float("inf")
        ranked.append((est, cand))
    ranked.sort(key=lambda rc: rc[0])
    return ranked


class SearchStrategy:
    name = "base"

    def search(
        self,
        space: SearchSpace,
        args: Sequence[Any],
        cache: MeasurementCache | None = None,
        repeats: int = 3,
        min_seconds: float = 0.0,
        objective: Objective | str | None = None,
    ) -> PlanReport:
        raise NotImplementedError


class _Run:
    """Bookkeeping shared by the concrete strategies: measure via the cache,
    collect unique trials, track baseline and evaluation counts.  All winner
    selection goes through ``objective.score`` (lower is better), never
    directly through ``trial.seconds``."""

    def __init__(
        self,
        space: SearchSpace,
        args: Sequence[Any],
        cache: MeasurementCache,
        repeats: int,
        min_seconds: float,
        objective: Objective | str | None = None,
    ) -> None:
        self.space = space
        self.args = args
        self.cache = cache
        self.repeats = repeats
        self.min_seconds = min_seconds
        self.objective = resolve_objective(objective)
        self.t0 = time.perf_counter()
        self.misses0 = cache.misses
        self.trials: list[PlanTrial] = []
        self._seen: dict[tuple, PlanTrial] = {}
        self.baseline_seconds: float | None = None
        self._pruned: dict[tuple, str] = {}  # canonical -> reason

    def _trial_from(
        self, cand: Candidate, m: verify.Measurement, cached: bool
    ) -> PlanTrial:
        base = self.baseline_seconds
        trial = PlanTrial(
            candidate=tuple(cand),
            pattern=self.space.pattern(cand),
            mapping=self.space.mapping_of(cand),
            seconds=m.seconds,
            compile_seconds=m.compile_seconds,
            speedup=(base / m.seconds) if base else 1.0,
            cached=cached,
            energy_joules=m.energy_joules,
            energy_provenance=m.energy_provenance,
        )
        trial.score = self.objective.score(trial)
        if base is None:
            self.baseline_seconds = m.seconds
            trial.speedup = 1.0
        return trial

    def is_pruned(self, cand: Candidate) -> bool:
        """True when the space's static pre-filter rejects this candidate.
        The baseline is never pruned — every report needs its reference
        measurement, and the un-offloaded program is definitionally legal."""
        cand = tuple(cand)
        if cand == self.space.baseline():
            return False
        key = self.space.canonical(cand)
        if key in self._pruned:
            return True
        reason = self.space.pruned(cand)
        if reason is not None:
            self._pruned[key] = reason
            return True
        return False

    def prune(self, cands: Sequence[Candidate]) -> list[Candidate]:
        """Drop statically-illegal candidates, recording each skip (once
        per canonical pattern) for the report's ``pruned`` count."""
        return [tuple(c) for c in cands if not self.is_pruned(c)]

    def measure(self, cand: Candidate) -> PlanTrial:
        return self.measure_many([cand])[0]

    def measure_many(self, cands: Sequence[Candidate]) -> list[PlanTrial]:
        """Bulk measurement: every not-yet-seen candidate goes to the cache
        (and through its executor) in one batch, so independent trials can
        run concurrently.  Returns one trial per candidate, in order."""
        cands = [tuple(c) for c in cands]
        fresh: list[Candidate] = []
        fresh_keys: set[tuple] = set()
        for cand in cands:
            key = self.cache.key_for(self.space, cand, self.args)
            if key not in self._seen and key not in fresh_keys:
                fresh.append(cand)
                fresh_keys.add(key)
        if fresh:
            measured = self.cache.measure_many(
                self.space,
                fresh,
                self.args,
                repeats=self.repeats,
                min_seconds=self.min_seconds,
            )
            for cand, (m, cached) in zip(fresh, measured):
                key = self.cache.key_for(self.space, cand, self.args)
                trial = self._trial_from(cand, m, cached)
                self._seen[key] = trial
                self.trials.append(trial)
        return [
            self._seen[self.cache.key_for(self.space, c, self.args)]
            for c in cands
        ]

    def seconds_of(self, cand: Candidate) -> float:
        return self.measure(cand).seconds

    def score_of(self, cand: Candidate) -> float:
        """Objective score of a candidate (the strategies' fitness)."""
        return self.measure(cand).score

    def report(self, strategy: str, generations: list[float] | None = None) -> PlanReport:
        best = min(self.trials, key=lambda t: t.score)
        base = self.baseline_seconds or best.seconds
        for t in self.trials:
            t.speedup = base / t.seconds
        return PlanReport(
            baseline_seconds=base,
            trials=self.trials,
            best=best,
            search_seconds=time.perf_counter() - self.t0,
            evaluations=self.cache.misses - self.misses0,
            strategy=strategy,
            generations=generations,
            objective=self.objective.name,
            pruned=len(self._pruned),
            pruned_reasons={
                "+".join(f"{n}={t}" for n, t in key): reason
                for key, reason in self._pruned.items()
            },
        )


class SingleThenCombine(SearchStrategy):
    """Paper §4.2: measure each block offloaded alone, then the combination
    of individually-improving blocks, adopting it only if it beats the best
    single.  For n-ary axes, "alone" means each (axis, choice) pair alone,
    and the combination takes each axis's best improving choice."""

    name = "single_then_combine"

    def search(
        self,
        space: SearchSpace,
        args: Sequence[Any],
        cache: MeasurementCache | None = None,
        repeats: int = 3,
        min_seconds: float = 0.0,
        objective: Objective | str | None = None,
    ) -> PlanReport:
        cache = MeasurementCache() if cache is None else cache
        run = _Run(space, args, cache, repeats, min_seconds, objective)

        baseline = space.baseline()
        base_t = run.measure(baseline)

        # every (axis, choice) measured alone — independent trials, so the
        # whole round goes to the executor as one batch
        singles: list[tuple[int, int, Candidate]] = []
        for i, axis in enumerate(space.axes):
            for c in range(1, len(axis.choices)):
                cand = list(baseline)
                cand[i] = c
                singles.append((i, c, tuple(cand)))
        # statically-illegal bindings are pruned, not timed (paper Step 1)
        singles = [s for s in singles if not run.is_pruned(s[2])]
        trials = run.measure_many([cand for _, _, cand in singles])

        # best improving choice per axis ("improving" by the configured
        # objective, not necessarily by wall time)
        winners: dict[int, int] = {}
        best_scores: dict[int, float] = {}
        for (i, c, _cand), t in zip(singles, trials):
            if t.score < best_scores.get(i, base_t.score):
                best_scores[i] = t.score
                winners[i] = c

        if len(winners) >= 2:
            combo = list(baseline)
            for i, c in winners.items():
                combo[i] = c
            # paper: the combination is adopted only if faster than the best
            # single pattern — run.report picks the global minimum, so a
            # slower combination simply doesn't win
            if not run.is_pruned(tuple(combo)):
                run.measure(tuple(combo))

        return run.report(self.name)


class GeneticSearch(SearchStrategy):
    """Elitist generational GA with tournament selection, single-point
    crossover and per-gene mutation (prior work, paper §3.2).  Genes index
    into each axis's choice list, so the genome is binary on a SubsetSpace
    and n-ary on a BindingSpace.

    With ``seed_from_cost=True`` the initial population is not uniform
    random: candidates are ranked by a static cost model (the HLO roofline
    by default, same ranking CostGuidedSearch uses as a measurement
    pre-filter) and the cheapest ones seed generation zero, so the GA
    starts from the cost model's belief instead of noise.
    """

    name = "genetic"

    def __init__(
        self,
        population: int = 8,
        generations: int = 8,
        mutation_rate: float = 0.1,
        elite: int = 2,
        tournament: int = 3,
        seed: int = 0,
        seed_from_cost: bool = False,
        cost_fn: Callable[[SearchSpace, Candidate, Sequence[Any]], float]
        | None = None,
        max_enumeration: int = 1024,
    ) -> None:
        self.population = population
        self.generations = generations
        self.mutation_rate = mutation_rate
        self.elite = elite
        self.tournament = tournament
        self.seed = seed
        self.seed_from_cost = seed_from_cost
        self.cost_fn = cost_fn
        self.max_enumeration = max_enumeration

    def _cost_seeded(
        self, space: SearchSpace, args: Sequence[Any]
    ) -> list[Candidate]:
        """Initial genomes from the static cost ranking (cheapest first),
        or [] when the space is too large / no candidate is rankable."""
        if space.size() > self.max_enumeration:
            warnings.warn(
                f"seed_from_cost: space has {space.size()} candidates "
                f"(> max_enumeration={self.max_enumeration}); seeding "
                "randomly instead",
                stacklevel=2,
            )
            return []
        ranked = rank_candidates_by_cost(space, args, self.cost_fn)
        if not ranked or all(est == float("inf") for est, _ in ranked):
            warnings.warn(
                "seed_from_cost: cost model failed on every candidate; "
                "seeding randomly instead",
                stacklevel=2,
            )
            return []
        # baseline always participates so the GA can report "don't offload"
        seeds = [space.baseline()]
        seeds.extend(c for _, c in ranked[: max(self.population - 1, 1)])
        return seeds[: self.population]

    def _mutate_gene(
        self, rng: random.Random, axis_card: int, gene: int
    ) -> int:
        if axis_card <= 1:
            return gene
        if axis_card == 2:
            return 1 - gene
        other = rng.randrange(axis_card - 1)
        return other + 1 if other >= gene else other

    def search(
        self,
        space: SearchSpace,
        args: Sequence[Any],
        cache: MeasurementCache | None = None,
        repeats: int = 3,
        min_seconds: float = 0.0,
        objective: Objective | str | None = None,
    ) -> PlanReport:
        cache = MeasurementCache() if cache is None else cache
        run = _Run(space, args, cache, repeats, min_seconds, objective)
        rng = random.Random(self.seed)
        cards = [len(a.choices) for a in space.axes]
        n_genes = len(cards)

        run.measure(space.baseline())

        def fitness(cand: Candidate) -> float:
            # pruned genomes survive in the pool (their genes may recombine
            # into legal children) but are never measured and never win
            if run.is_pruned(cand):
                return float("inf")
            return run.score_of(cand)

        pop: list[Candidate] = []
        if self.seed_from_cost:
            pop = self._cost_seeded(space, args)
        guard = 0
        while len(pop) < self.population and guard < self.population * 50:
            g = tuple(rng.randrange(c) for c in cards)
            if g not in pop:
                pop.append(g)
            guard += 1

        history: list[float] = []
        base = run.baseline_seconds or 1.0
        for _gen in range(self.generations):
            # measure the whole generation as one batch (the executor may
            # run its members concurrently); fitness below replays from
            # the per-run trial table.  Pruned members are skipped here.
            run.measure_many(run.prune(pop))
            scored = sorted(pop, key=fitness)
            # Fig. 4 curve stays a *speedup* (time ratio) regardless of the
            # objective that ranks the population
            legal_best = next(
                (c for c in scored if not run.is_pruned(c)), space.baseline()
            )
            history.append(base / run.measure(legal_best).seconds)
            nxt: list[Candidate] = scored[: self.elite]
            while len(nxt) < self.population:

                def pick() -> Candidate:
                    cand = [
                        pop[rng.randrange(len(pop))]
                        for _ in range(self.tournament)
                    ]
                    return min(cand, key=fitness)

                a, b = pick(), pick()
                if n_genes > 1:
                    cut = rng.randrange(1, n_genes)
                    child = a[:cut] + b[cut:]
                else:
                    child = a
                child = tuple(
                    self._mutate_gene(rng, card, gene)
                    if rng.random() < self.mutation_rate
                    else gene
                    for card, gene in zip(cards, child)
                )
                nxt.append(child)
            pop = nxt

        return run.report(self.name, generations=history)


class ExhaustiveSearch(SearchStrategy):
    """Measure every candidate in a listed set (or the whole space).

    With ``include_baseline=False`` the report's baseline (and therefore
    every speedup) is the first listed candidate, not the space baseline —
    fine for picking a winner, misleading if the report is persisted as a
    Plan whose speedup readers take as "vs un-offloaded".
    """

    name = "exhaustive"

    def __init__(
        self,
        candidates: Sequence[Candidate] | None = None,
        include_baseline: bool = True,
        max_enumeration: int = 4096,
    ) -> None:
        self.candidates = candidates
        self.include_baseline = include_baseline
        self.max_enumeration = max_enumeration

    def search(
        self,
        space: SearchSpace,
        args: Sequence[Any],
        cache: MeasurementCache | None = None,
        repeats: int = 3,
        min_seconds: float = 0.0,
        objective: Objective | str | None = None,
    ) -> PlanReport:
        cache = MeasurementCache() if cache is None else cache
        run = _Run(space, args, cache, repeats, min_seconds, objective)
        if self.candidates is not None:
            cands = list(self.candidates)
        else:
            if space.size() > self.max_enumeration:
                raise ValueError(
                    f"space has {space.size()} candidates; pass an explicit "
                    f"candidate list or raise max_enumeration"
                )
            cands = list(space.enumerate())
        if self.include_baseline:
            run.measure(space.baseline())
        run.measure_many(run.prune(cands))
        return run.report(self.name)


class CostGuidedSearch(SearchStrategy):
    """Rank candidates by a static cost model, measure only the top-k.

    The paper motivates this for FPGA: a single candidate compilation takes
    hours, so candidates are narrowed by arithmetic intensity *before* any
    measurement.  ``cost_fn(space, candidate, args) -> estimated seconds``
    defaults to the HLO roofline model (``planner.cost``), which requires
    the built variants to be jax-traceable; candidates whose cost cannot be
    estimated rank last, and if no candidate can be ranked the strategy
    degrades to exhaustive measurement with a warning.
    """

    name = "cost_guided"

    def __init__(
        self,
        top_k: int = 4,
        cost_fn: Callable[[SearchSpace, Candidate, Sequence[Any]], float]
        | None = None,
        max_enumeration: int = 1024,
    ) -> None:
        self.top_k = top_k
        self.cost_fn = cost_fn
        self.max_enumeration = max_enumeration

    def search(
        self,
        space: SearchSpace,
        args: Sequence[Any],
        cache: MeasurementCache | None = None,
        repeats: int = 3,
        min_seconds: float = 0.0,
        objective: Objective | str | None = None,
    ) -> PlanReport:
        cache = MeasurementCache() if cache is None else cache
        run = _Run(space, args, cache, repeats, min_seconds, objective)

        if space.size() > self.max_enumeration:
            raise ValueError(
                f"space has {space.size()} candidates; CostGuidedSearch "
                f"enumerates the space — raise max_enumeration or shrink it"
            )
        # legality-pruned candidates are skipped before the cost model even
        # traces them: an illegal binding may not lower at all
        ranked = rank_candidates_by_cost(
            space, args, self.cost_fn, skip=run.is_pruned
        )

        run.measure(space.baseline())
        if ranked and all(est == float("inf") for est, _ in ranked):
            warnings.warn(
                "CostGuidedSearch: cost model failed on every candidate; "
                "falling back to exhaustive measurement",
                stacklevel=2,
            )
            chosen = [cand for _, cand in ranked]
        else:
            chosen = [cand for _, cand in ranked[: max(self.top_k, 1)]]
        run.measure_many(chosen)
        return run.report(self.name)
