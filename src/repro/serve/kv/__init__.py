"""``repro.serve.kv`` — the block-paged KV-cache memory subsystem.

The serving engine's hottest memory structure is the KV cache.  The
contiguous layout reserves ``max_len`` rows per slot — a worst-case
reservation, so a short request strands the tail of its slot and total
resident tokens is fixed at ``n_slots x max_len`` no matter what the
traffic looks like.  This package applies the paper's blockification move
to serving memory: the cache becomes an explicit *function block* with its
own storage (:class:`PagePool`), its own interface
(``alloc`` / ``ensure`` / ``free`` with exact accounting) and its own
per-request indirection (:class:`PageTable`), vLLM-style.

* :class:`PagePool` — host-side accounting for a device pool of
  ``n_pages`` fixed-size pages (plus one *null page* that absorbs writes
  from freed or still-prefilling slots).  Deterministic reuse order,
  double-free and foreign-page detection, :class:`PoolExhausted` on
  overflow.
* :class:`PageTable` — per-slot page lists and resident-token lengths;
  its :meth:`PageTable.array` view is the ``(n_slots, max_pages)`` int32
  operand the jitted decode program gathers K/V through.

Capacity becomes a *shared* pool: admission gates on free pages instead
of free slots, eviction returns pages immediately, and total resident
tokens is bounded by ``n_pages x page_size`` — not ``n_slots x max_len``.
"""

from repro.serve.kv.pool import (  # noqa: F401
    PagePool,
    PageTable,
    PoolExhausted,
    pages_for,
)
