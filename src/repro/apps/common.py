"""Staged-application machinery for the loop-offload baseline.

The prior-work loop offloader ([32][33], reproduced here as the GA baseline)
decides *per loop nest* whether to execute on the CPU (interpreted, naive) or
on the accelerator.  An application is expressed as a sequence of stages —
each stage is one loop nest with a naive implementation and an accelerated
(vectorised, JIT-compiled) implementation.

Key fidelity point: every offloaded stage pays the host<->device boundary
(here: numpy <-> JAX device transfer + dispatch), exactly the per-loop
transfer overhead that limits loop-level offloading in the paper and that
function-block offloading eliminates by replacing the *whole* block with one
device-resident implementation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Stage:
    """One loop nest of an application."""

    name: str
    naive: Callable[[Any], Any]  # numpy in / numpy out, python loops
    offloaded: Callable[[Any], Any]  # jax in / jax out, jit-able


def build_staged_variant(
    stages: Sequence[Stage], genome: Sequence[int]
) -> Callable[[Any], Any]:
    """Build the application variant selected by ``genome``.

    genome[i] == 1 -> stage i runs its offloaded implementation (with the
    host->device->host round trip); 0 -> naive CPU loop.
    """

    import jax
    import jax.numpy as jnp

    if len(genome) != len(stages):
        raise ValueError(f"genome length {len(genome)} != stages {len(stages)}")

    jitted = [jax.jit(s.offloaded) for s in stages]

    def _to_host(x: Any) -> Any:
        if isinstance(x, tuple):
            return tuple(_to_host(e) for e in x)
        return np.asarray(x)

    def _to_dev(x: Any) -> Any:
        if isinstance(x, tuple):
            return tuple(_to_dev(e) for e in x)
        return jnp.asarray(x)

    def run(x: Any) -> Any:
        state = _to_host(x)
        for i, stage in enumerate(stages):
            if genome[i]:
                out = jitted[i](_to_dev(state))
                state = _to_host(out)  # explicit device->host transfer
            else:
                state = stage.naive(state)
        return state

    run.__name__ = "variant_" + "".join(str(int(b)) for b in genome)
    return run
