"""Checkpoint manager: atomicity, retention, restore, determinism."""

import json
import os
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": rng.standard_normal((4, 4)).astype(np.float32),
                   "b": rng.standard_normal(4).astype(np.float32)},
        "opt": {"mu": rng.standard_normal((4, 4)).astype(np.float32)},
        "step": np.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(10, tree, blocking=True)
    step, restored = mgr.restore(_tree(seed=99))
    assert step == 10
    np.testing.assert_array_equal(restored["params"]["w"], tree["params"]["w"])
    np.testing.assert_array_equal(restored["step"], tree["step"])


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, _tree(1))
    mgr.wait()
    assert mgr.latest_step() == 5


def test_retention_keeps_last_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=True)
    assert mgr.steps() == [3, 4]


def test_half_written_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, _tree(), blocking=True)
    # simulate a crash mid-write: directory without manifest
    broken = tmp_path / "step_00000009"
    broken.mkdir()
    (broken / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 3  # not 9
    step, _ = mgr.restore(_tree())
    assert step == 3


def test_restore_missing_key_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"a": np.zeros(2)}, blocking=True)
    with pytest.raises(KeyError):
        mgr.restore({"a": np.zeros(2), "new_key": np.zeros(3)})


@settings(max_examples=10, deadline=None)
@given(
    shapes=st.lists(
        st.tuples(st.integers(1, 5), st.integers(1, 5)), min_size=1, max_size=4
    ),
    seed=st.integers(0, 2**16),
)
def test_roundtrip_property(tmp_path_factory, shapes, seed):
    rng = np.random.default_rng(seed)
    tree = {f"k{i}": rng.standard_normal(s).astype(np.float32)
            for i, s in enumerate(shapes)}
    d = tmp_path_factory.mktemp("ckpt")
    mgr = CheckpointManager(d)
    mgr.save(1, tree, blocking=True)
    _, restored = mgr.restore(tree)
    for k in tree:
        np.testing.assert_array_equal(restored[k], tree[k])


def test_restore_shape_mismatch_fails_loudly(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": np.zeros((4, 4), np.float32)}, blocking=True)
    with pytest.raises(ValueError, match="does not match the current model"):
        mgr.restore({"w": np.zeros((8, 8), np.float32)})
