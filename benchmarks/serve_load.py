"""Poisson load generator for the serving engine.

Drives :class:`repro.serve.ServeEngine` with an open-loop request trace —
exponential inter-arrival times (a Poisson process, the standard serving
load model), mixed prompt/generation lengths — and reports what the
power-saving follow-up work (arXiv:2110.11520) evaluates offloads under:
sustained-load throughput (tok/s), request latency and TTFT percentiles
(p50/p99), and joules/token with measured-vs-estimated provenance.

  PYTHONPATH=src python benchmarks/serve_load.py --arch llama3.2-1b \
      --reduced --requests 16 --rate 8 --meter auto

``--fast`` shrinks the trace for CI (``make serve-bench``).  ``--plan-dir``
binds each phase to its committed zoo plan, so the benchmark measures the
*deployed* offload pattern, not the default bindings.  ``--json-out PATH``
additionally writes a machine-readable snapshot (``BENCH_serve.json``) with
throughput, percentiles (including TTFT-from-admission and queue wait),
energy provenance, per-phase telemetry, engine stats/metrics and the git
revision, so successive runs diff cleanly.  ``--trace-out PATH`` turns the
request-lifecycle tracer on and writes a Chrome/Perfetto trace of the
measured run (``python -m repro.obs.timeline PATH`` summarises it);
``--metrics-out PATH`` dumps the engine's Prometheus registry.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time

import numpy as np

sys.path.insert(
    0, os.path.join(os.path.dirname(__file__), "..", "src")
)

from repro.launch.serve import (  # noqa: E402
    add_engine_args,
    build_engine,
    format_kv_metrics,
    make_requests,
    percentile,
    write_obs_outputs,
)
from repro.obs.timeline import span_summary  # noqa: E402
from repro.serve import Request  # noqa: E402


def run_trace(engine, requests, arrivals, max_seconds: float = 600.0):
    """Open-loop drive: submit each request at its arrival time (relative
    to the trace start), stepping the engine in between.  Returns the
    observed makespan in seconds (completions stay on the engine)."""
    t0 = time.perf_counter()
    pending = list(zip(arrivals, requests))
    pending.reverse()  # pop() takes the earliest
    while pending or engine.scheduler.has_work:
        now = time.perf_counter() - t0
        while pending and pending[-1][0] <= now:
            engine.submit(pending.pop()[1])
        if engine.scheduler.has_work:
            engine.step()
        elif pending:
            # idle gap before the next arrival: sleep it off instead of
            # spinning (open-loop arrivals must not be accelerated)
            time.sleep(min(pending[-1][0] - now, 0.05))
        if time.perf_counter() - t0 > max_seconds:
            raise RuntimeError(f"trace still running after {max_seconds}s")
    return time.perf_counter() - t0


def git_sha() -> str:
    """Revision stamp for the snapshot; "unknown" outside a git checkout."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
    except Exception:  # noqa: BLE001 — the snapshot is still useful
        return "unknown"


def snapshot(engine, args, makespan, completions) -> dict:
    """The machine-readable result record ``--json-out`` writes."""
    stats = engine.stats
    gen_tokens = sum(len(c.tokens) for c in completions)
    latencies = [c.latency for c in completions]
    ttfts = [c.ttft for c in completions]
    ttfts_admitted = [c.ttft_admitted for c in completions]
    queue_waits = [c.queue_wait for c in completions]
    phases = {}
    for phase in ("prefill", "decode"):
        t = engine.telemetry[phase]
        phases[phase] = {
            "calls": t.calls,
            "seconds": t.seconds,
            "tokens": t.tokens,
            "tokens_per_second": t.tokens_per_second,
            "joules": t.joules,
            "joules_per_token": t.joules_per_token,
            "provenance": t.provenance,
        }
    joules = (
        (engine.telemetry["prefill"].joules or 0.0)
        + (engine.telemetry["decode"].joules or 0.0)
        if any(engine.telemetry[p].joules is not None
               for p in ("prefill", "decode"))
        else None
    )
    # prefill-vs-decode split of the metered phase time — where the
    # engine's compute actually went, independent of queueing
    phase_seconds = {
        p: engine.telemetry[p].seconds for p in ("prefill", "decode")
    }
    total_phase = sum(phase_seconds.values())
    spans = None
    if engine.tracer.enabled and len(engine.tracer):
        spans = span_summary(engine.tracer.to_chrome()["traceEvents"])
    return {
        "schema": 2,
        "benchmark": "serve_load",
        "git_sha": git_sha(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "arch": engine.cfg.name,
        "reduced": bool(args.reduced),
        "trace": {
            "requests": args.requests,
            "rate_per_s": args.rate,
            "prompt_len": args.prompt_len,
            "len_jitter": args.len_jitter,
            "gen": args.gen,
            "gen_jitter": args.gen_jitter,
            "seed": args.seed,
            "fast": bool(args.fast),
        },
        "engine": {
            "slots": engine.n_slots,
            "max_len": engine.max_len,
            "sampler": args.sampler,
            "meter": args.meter,
            "plan_dir": args.plan_dir,
            "page_size": args.page_size,
            "n_pages": args.n_pages,
            "decode_impl": args.decode_impl,
            "prefill_bucket": args.prefill_bucket,
            "prefill_chunk": args.prefill_chunk,
            "step_budget": args.step_budget,
        },
        "makespan_s": makespan,
        "throughput_tok_s": gen_tokens / makespan if makespan else 0.0,
        "generated_tokens": gen_tokens,
        "latency_ms": {
            "p50": percentile(latencies, 0.5) * 1e3,
            "p99": percentile(latencies, 0.99) * 1e3,
        },
        "ttft_ms": {
            "p50": percentile(ttfts, 0.5) * 1e3,
            "p99": percentile(ttfts, 0.99) * 1e3,
        },
        "ttft_admitted_ms": {
            "p50": percentile(ttfts_admitted, 0.5) * 1e3,
            "p99": percentile(ttfts_admitted, 0.99) * 1e3,
        },
        "queue_wait_ms": {
            "p50": percentile(queue_waits, 0.5) * 1e3,
            "p99": percentile(queue_waits, 0.99) * 1e3,
        },
        "preemptions": stats.preemptions,
        "phase_split": {
            "prefill_s": phase_seconds["prefill"],
            "decode_s": phase_seconds["decode"],
            "prefill_frac": (
                phase_seconds["prefill"] / total_phase if total_phase else 0.0
            ),
        },
        "spans": spans,
        "energy": {
            "joules": joules,
            "joules_per_token": (
                joules / max(gen_tokens, 1) if joules is not None else None
            ),
            "provenance": (
                engine.telemetry["decode"].provenance
                or engine.telemetry["prefill"].provenance
            ),
        },
        "phases": phases,
        "stats": dataclasses.asdict(stats),
        "metrics": engine.metrics(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    add_engine_args(ap)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="mean arrival rate, requests/second (Poisson)")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--len-jitter", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--gen-jitter", type=int, default=4)
    ap.add_argument("--fast", action="store_true",
                    help="tiny trace on the reduced config (CI smoke)")
    ap.add_argument("--json-out", default=None,
                    help="write a machine-readable snapshot (e.g. "
                         "BENCH_serve.json) next to the printed report")
    ap.add_argument("--preflight", action="store_true",
                    help="static capacity check against --envelope before "
                         "the load run; abort when the config cannot fit")
    args = ap.parse_args()
    if args.fast:
        args.reduced = True
        args.requests = min(args.requests, 8)
        args.prompt_len, args.len_jitter = 12, 4
        args.gen, args.gen_jitter = 8, 3
        args.rate = max(args.rate, 8.0)
        args.slots = min(args.slots, 3)
        args.max_len = min(args.max_len, 64)

    if args.preflight:
        from repro.launch.serve import preflight

        rc = preflight(args)
        if rc != 0:
            raise SystemExit(rc)

    engine = build_engine(args)
    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    requests = make_requests(engine.cfg, args, rng)

    # warmup outside the measured trace: prefill retraces per (padded)
    # prompt length, so compile EVERY length the trace will submit — plus
    # one decode step — or the measured percentiles report XLA compile
    # time instead of serving time; then zero every counter so the warmup
    # never shows up as served traffic
    for length in sorted({len(r.prompt) for r in requests}):
        engine.submit(Request(list(range(1, length + 1)), max_new_tokens=2))
    engine.run_until_idle(max_steps=1000)
    engine.reset_stats()

    makespan = run_trace(engine, requests, arrivals)
    completions = list(engine.completions.values())
    assert len(completions) == args.requests, (
        f"{len(completions)}/{args.requests} requests completed"
    )

    stats = engine.stats
    gen_tokens = sum(len(c.tokens) for c in completions)
    latencies = [c.latency for c in completions]
    ttfts = [c.ttft for c in completions]
    ttfts_admitted = [c.ttft_admitted for c in completions]
    queue_waits = [c.queue_wait for c in completions]
    decode = engine.telemetry["decode"]
    prefill = engine.telemetry["prefill"]

    print(f"arch={engine.cfg.name} slots={engine.n_slots} "
          f"requests={args.requests} rate={args.rate}/s "
          f"makespan={makespan:.2f}s")
    print(prefill.summary())
    print(decode.summary())
    print(f"throughput: {gen_tokens / makespan:.1f} generated tok/s "
          f"({gen_tokens} tokens)")
    print(f"latency: p50 {percentile(latencies, 0.5)*1e3:.1f} ms  "
          f"p99 {percentile(latencies, 0.99)*1e3:.1f} ms")
    print(f"ttft:    p50 {percentile(ttfts, 0.5)*1e3:.1f} ms  "
          f"p99 {percentile(ttfts, 0.99)*1e3:.1f} ms")
    # ttft includes the queue wait; the admitted variant isolates the
    # model-side prefill latency from the scheduler's queueing
    print(f"ttft from admit: "
          f"p50 {percentile(ttfts_admitted, 0.5)*1e3:.1f} ms  "
          f"p99 {percentile(ttfts_admitted, 0.99)*1e3:.1f} ms  "
          f"(queue wait p50 {percentile(queue_waits, 0.5)*1e3:.1f} ms  "
          f"p99 {percentile(queue_waits, 0.99)*1e3:.1f} ms)")
    joules = (
        (prefill.joules or 0.0) + (decode.joules or 0.0)
        if (prefill.joules is not None or decode.joules is not None)
        else None
    )
    if joules is not None:
        prov = decode.provenance or prefill.provenance
        print(f"energy: {joules:.1f} J, "
              f"{joules / max(gen_tokens, 1):.3g} J/token [{prov}]")
    else:
        print("energy: no meter (--meter auto for telemetry)")
    print(f"continuous batching: {stats.slot_reuses} slot reuses, "
          f"max {stats.max_active} concurrent, "
          f"{stats.steps} engine steps")
    print(format_kv_metrics(engine))

    write_obs_outputs(engine, args)
    if args.json_out:
        record = snapshot(engine, args, makespan, completions)
        with open(args.json_out, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        print(f"snapshot written: {args.json_out}")


if __name__ == "__main__":
    main()
