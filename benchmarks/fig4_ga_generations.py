"""Paper Fig. 4: best performance (vs all-CPU) per GA generation for the
Fourier-transform application under prior-work loop offloading [33]."""

from __future__ import annotations

import argparse
import warnings

from benchmarks.common import emit


def run(n: int = 192, generations: int = 8, population: int = 8,
        seed: int = 0) -> list[float]:
    warnings.filterwarnings("ignore")
    from repro.apps import fourier
    from repro.core import planner

    x = fourier.make_input(n)
    space = planner.SubsetSpace.from_genome_builder(
        fourier.build_fft_variant, len(fourier.FFT_STAGES)
    )
    cache = planner.MeasurementCache()
    rep = planner.GeneticSearch(
        population=population, generations=generations, seed=seed
    ).search(space, (x,), cache=cache, repeats=1)
    for gen, speedup in enumerate(rep.generations or []):
        emit(f"fig4.gen{gen}", rep.baseline_seconds / max(speedup, 1e-9),
             f"best_speedup={speedup:.2f}x")
    # the same curve by trials measured (not generations): Fig. 4's x-axis
    # when each measurement is the unit of cost
    from repro.metering import search_trace

    for p in search_trace(cache):
        emit(f"fig4.trial{p.trial}", p.best_seconds,
             f"speedup={rep.baseline_seconds / p.best_seconds:.2f}x")
    emit(
        "fig4.final", rep.best.seconds,
        f"best_speedup={rep.best.speedup:.2f}x genome="
        f"{''.join(map(str, rep.best.candidate))} evals={rep.evaluations} "
        f"search={rep.search_seconds:.1f}s",
    )
    return list(rep.generations or [])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=192)
    ap.add_argument("--generations", type=int, default=8)
    ap.add_argument("--population", type=int, default=8)
    args = ap.parse_args()
    run(args.n, args.generations, args.population)


if __name__ == "__main__":
    main()
