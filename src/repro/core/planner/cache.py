"""MeasurementCache — shared memoisation of measured trials.

On real hardware every trial is a compile+run (hours per FPGA candidate in
the paper), so no strategy may re-measure a pattern another strategy — or an
earlier generation — already visited.  Entries are keyed by the space
signature plus the canonical (order-independent) pattern, and keep the
compile-time / runtime split from ``verify.measure`` so search-time curves
(paper Fig. 4) stay reconstructable.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from repro.core import verify
from repro.core.planner.space import Candidate, SearchSpace


@dataclasses.dataclass
class CacheRecord:
    key: tuple
    measurement: verify.Measurement
    hits: int = 0


def args_fingerprint(args: Sequence[Any]) -> tuple:
    """Cheap structural identity of a measured workload's arguments.

    Arrays are keyed by shape+dtype (not contents — re-hashing a 2048^2
    input per lookup would dwarf short measurements), scalars by value.
    Together with the space signature (which carries the builder tag) this
    keeps one application's timings from answering for another's.
    """
    parts = []
    for a in args:
        shape = getattr(a, "shape", None)
        if shape is not None:
            parts.append(("array", tuple(shape), str(getattr(a, "dtype", ""))))
        elif isinstance(a, (bool, int, float, str, bytes, type(None))):
            # type name included: 1, 1.0 and True hash/compare equal in
            # Python but can select different computation paths
            parts.append(("value", type(a).__name__, a))
        else:
            parts.append(("object", type(a).__name__))
    return tuple(parts)


class MeasurementCache:
    def __init__(self, meter: Any = None) -> None:
        """``meter``: optional ``objectives.PowerMeter`` whose begin/end
        hooks bracket every new measurement; the joules it reports are
        stored on the measurement (and replayed on cache hits) so
        energy-aware objectives can rank trials.

        Attach the meter for the cache's whole lifetime: entries measured
        before a meter existed replay ``energy_joules=None``, which
        energy-aware objectives score with their time-proportional
        fallback — mixing metered and estimated joules in one ranking.
        """
        self._data: dict[tuple, CacheRecord] = {}
        self.meter = meter
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._data)

    def key_for(
        self, space: SearchSpace, cand: Candidate, args: Sequence[Any] = ()
    ) -> tuple:
        return (space.signature(), args_fingerprint(args), space.canonical(cand))

    def lookup(
        self, space: SearchSpace, cand: Candidate, args: Sequence[Any] = ()
    ) -> verify.Measurement | None:
        rec = self._data.get(self.key_for(space, cand, args))
        return None if rec is None else rec.measurement

    def measure(
        self,
        space: SearchSpace,
        cand: Candidate,
        args: Sequence[Any],
        repeats: int = 3,
        min_seconds: float = 0.0,
        warmup: int = 1,
    ) -> tuple[verify.Measurement, bool]:
        """Measure a candidate, or return the cached measurement.

        Returns ``(measurement, cached)`` where ``cached`` is True when no
        new measurement was taken.  A hit replays the stored measurement
        regardless of ``repeats``/``min_seconds`` — the first measurement
        of a pattern wins.
        """
        key = self.key_for(space, cand, args)
        rec = self._data.get(key)
        if rec is not None:
            rec.hits += 1
            self.hits += 1
            return rec.measurement, True
        fn = space.build(cand)
        if self.meter is not None:
            self.meter.begin()
        m = verify.measure(
            fn, args, repeats=repeats, warmup=warmup, min_seconds=min_seconds
        )
        if self.meter is not None:
            m.energy_joules = self.meter.end(m, space=space, candidate=cand)
        self._data[key] = CacheRecord(key, m)
        self.misses += 1
        return m, False

    @property
    def evaluations(self) -> int:
        """Number of actually-measured (non-cached) trials so far."""
        return self.misses
