"""Code-Pattern DB (paper §3.4 B-1/B-2, §4.1).

The paper keeps a MySQL database keyed by library name, holding for each
offloadable function block: the accelerated replacement (GPU library / FPGA IP
core), its code or executable, its *usage recipe* (利用手法), and reference
code used by the similarity detector.  Here the DB is a JSON-persistable
registry whose "executables" are dotted import paths into this package (the
TPU shelf lives in ``repro.kernels``), so entries survive serialisation the
same way executable paths did in MySQL.
"""

from __future__ import annotations

import dataclasses
import importlib
import json
import pathlib
from typing import Any, Callable, Iterable, Mapping

from repro.core.interface import InterfaceSpec, Param


def _spec_to_json(spec: InterfaceSpec) -> dict:
    return {
        "params": [dataclasses.asdict(p) for p in spec.params],
        "returns": list(spec.returns),
    }


def _spec_from_json(d: Mapping[str, Any]) -> InterfaceSpec:
    return InterfaceSpec(
        params=tuple(Param(**p) for p in d["params"]),
        returns=tuple(d["returns"]),
    )


@dataclasses.dataclass
class ReplacementEntry:
    """One row of the Code-Pattern DB.

    name           canonical block name ("fft2d", "lu", "matmul", ...)
    source_names   call names this entry replaces (A-1 keys): the "external
                   library list" of the paper.
    impl           dotted path to the accelerated callable
                   (e.g. "repro.kernels.ops:fft2") — the cuFFT/IP-core slot.
    target         execution target: "xla" | "tpu-pallas" | "cpu-ref"
    interface      replacement interface (for C-1/C-2 matching)
    reference_code source text registered for similarity detection (B-2);
                   None => this entry is only found via name match (B-1).
    usage_recipe   free-text recipe: how the host program calls the block
                   (the paper registers利用手法 with each executable).
    cost_hint      arithmetic-intensity style hints used by the dry-run
                   pre-filter (the FPGA "narrow before measuring" step).
    """

    name: str
    source_names: tuple[str, ...]
    impl: str
    target: str = "xla"
    interface: InterfaceSpec | None = None
    reference_code: str | None = None
    usage_recipe: str = ""
    cost_hint: dict = dataclasses.field(default_factory=dict)

    def resolve(self) -> Callable[..., Any]:
        """Import and return the replacement callable."""
        mod_name, _, attr = self.impl.partition(":")
        mod = importlib.import_module(mod_name)
        fn: Any = mod
        for part in attr.split("."):
            fn = getattr(fn, part)
        return fn

    def to_json(self) -> dict:
        d = {
            "name": self.name,
            "source_names": list(self.source_names),
            "impl": self.impl,
            "target": self.target,
            "interface": _spec_to_json(self.interface) if self.interface else None,
            "reference_code": self.reference_code,
            "usage_recipe": self.usage_recipe,
            "cost_hint": self.cost_hint,
        }
        return d

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "ReplacementEntry":
        return cls(
            name=d["name"],
            source_names=tuple(d["source_names"]),
            impl=d["impl"],
            target=d.get("target", "xla"),
            interface=_spec_from_json(d["interface"]) if d.get("interface") else None,
            reference_code=d.get("reference_code"),
            usage_recipe=d.get("usage_recipe", ""),
            cost_hint=dict(d.get("cost_hint", {})),
        )


class CodePatternDB:
    """Name-keyed + similarity-searchable registry of replacements."""

    def __init__(self, entries: Iterable[ReplacementEntry] = ()) -> None:
        self._entries: dict[str, ReplacementEntry] = {}
        self._by_source: dict[str, str] = {}
        for e in entries:
            self.register(e)

    # -- registration ------------------------------------------------------
    def register(self, entry: ReplacementEntry) -> None:
        self._entries[entry.name] = entry
        for src in entry.source_names:
            self._by_source[src] = entry.name

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def entries(self) -> list[ReplacementEntry]:
        return list(self._entries.values())

    def get(self, name: str) -> ReplacementEntry:
        return self._entries[name]

    # -- A-1 / B-1: library-name matching ----------------------------------
    @property
    def known_library_names(self) -> set[str]:
        """The external-library list used by Step-1 code analysis."""
        return set(self._by_source)

    def lookup_by_call(self, call_name: str) -> ReplacementEntry | None:
        """B-1: find a replacement for a detected library call."""
        name = self._by_source.get(call_name)
        if name is None:
            # also accept an unqualified trailing component ("np.fft.fft2" ~ "fft2")
            tail = call_name.rsplit(".", 1)[-1]
            name = self._by_source.get(tail)
        return self._entries.get(name) if name else None

    # -- B-2: similarity candidates ----------------------------------------
    def entries_with_reference(self) -> list[ReplacementEntry]:
        return [e for e in self._entries.values() if e.reference_code]

    # -- persistence ---------------------------------------------------------
    def save(self, path: str | pathlib.Path) -> None:
        path = pathlib.Path(path)
        path.write_text(
            json.dumps([e.to_json() for e in self._entries.values()], indent=2)
        )

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "CodePatternDB":
        data = json.loads(pathlib.Path(path).read_text())
        return cls(ReplacementEntry.from_json(d) for d in data)


def default_db() -> CodePatternDB:
    """The stock pattern DB shipped with the framework.

    Mirrors the paper's evaluation setup: FFT and LU entries whose
    replacements are this repo's accelerated TPU implementations, plus the
    block shelf used by the model zoo (matmul, attention, rmsnorm, ssd).
    Reference code snippets (for B-2/Deckard matching) are the naive apps.
    """

    from repro.apps import fourier, matrix  # local import to avoid cycles

    f32 = "float32"
    f64 = "float64"
    entries = [
        ReplacementEntry(
            name="fft2d",
            source_names=("fft2d", "fft2d_nr", "np.fft.fft2", "fft2"),
            impl="repro.kernels.ops:fft2d",
            target="tpu-pallas",
            interface=InterfaceSpec(
                params=(Param("x", "complex64", rank=2),),
                returns=("complex64",),
            ),
            reference_code=fourier.REFERENCE_CODE,
            usage_recipe=(
                "y = fft2d(x): 2-D complex FFT via MXU matmul-DFT stages; "
                "x (n,m) complex64, n,m powers of two >= 128 preferred."
            ),
            cost_hint={"flops_per_elem": "5*log2(n*m)", "intensity": "high"},
        ),
        ReplacementEntry(
            name="lu",
            source_names=("ludcmp", "ludcmp_nr", "lu_factor", "scipy.linalg.lu"),
            impl="repro.kernels.ops:lu_nr_compat",
            target="tpu-pallas",
            interface=InterfaceSpec(
                params=(Param("a", f32, rank=2),),
                returns=(f32, "int32", f32),
            ),
            reference_code=matrix.REFERENCE_CODE,
            usage_recipe=(
                "lu, indx, d = lu_nr_compat(a): blocked right-looking LU with "
                "partial pivoting (NR-shaped interface); trailing updates hit "
                "the MXU schur_update kernel.  Pads internally to 128."
            ),
            cost_hint={"flops": "2/3*n^3", "intensity": "n/3"},
        ),
        ReplacementEntry(
            name="matmul",
            source_names=("matmul", "np.matmul", "np.dot", "matmul_nr"),
            impl="repro.kernels.ops:matmul",
            target="tpu-pallas",
            interface=InterfaceSpec(
                params=(
                    Param("a", f32, rank=2, align=128),
                    Param("b", f32, rank=2, align=128),
                ),
                returns=(f32,),
            ),
            usage_recipe="c = matmul(a, b): VMEM-tiled MXU matmul.",
            cost_hint={"flops": "2*m*n*k", "intensity": "min(m,n,k)/2"},
        ),
        ReplacementEntry(
            name="attention",
            source_names=("attention", "scaled_dot_product_attention", "sdpa"),
            impl="repro.kernels.ops:flash_attention",
            target="tpu-pallas",
            usage_recipe=(
                "o = flash_attention(q, k, v, causal=True): online-softmax "
                "fused attention, VMEM-tiled over kv blocks."
            ),
            cost_hint={"flops": "4*b*h*s^2*d", "intensity": "s/2"},
        ),
        ReplacementEntry(
            name="rmsnorm",
            source_names=("rmsnorm", "rms_norm"),
            impl="repro.kernels.ops:rmsnorm",
            target="tpu-pallas",
            usage_recipe="y = rmsnorm(x, w, eps): fused mean-square + scale.",
            cost_hint={"intensity": "low"},
        ),
        ReplacementEntry(
            name="ssd_scan",
            source_names=("ssd_scan", "mamba_chunk_scan", "selective_scan"),
            impl="repro.kernels.ops:ssd_scan",
            target="tpu-pallas",
            usage_recipe=(
                "y, final_state = ssd_scan(x, dt, A, B, C, chunk): Mamba-2 "
                "state-space-duality chunked scan (intra-chunk matmul + "
                "inter-chunk recurrence)."
            ),
            cost_hint={"intensity": "chunk/2"},
        ),
    ]
    return CodePatternDB(entries)
