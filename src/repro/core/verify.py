"""Verification-environment measurement primitives (paper Step 3).

"Being registered as fast" does not guarantee speed in situ, so the paper
measures candidate patterns in a verification environment.  This module
owns the *measurement* primitives:

  ``measure``          device-blocking median-of-repeats timing with the
                       compile (warm-up) time split out, and an optional
                       ``min_seconds`` floor that re-runs short kernels
                       until the timed window is long enough to be stable;
  ``verify_numerics``  the functional check a winning pattern must pass
                       before deployment.

The pattern *search* itself lives in ``repro.core.planner``: the paper's
single-then-combine procedure is ``planner.SingleThenCombine`` over a
``planner.SubsetSpace``, the FPGA-motivated "narrow candidates before the
hours-long compile" pre-filter is ``planner.CostGuidedSearch`` on the HLO
roofline model, and all strategies share one ``planner.MeasurementCache``.
``search_offload_pattern`` below is a deprecated shim kept for existing
callers; new code should use the planner directly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Sequence


def _block(x: Any) -> None:
    if hasattr(x, "block_until_ready"):
        x.block_until_ready()
    elif isinstance(x, (tuple, list)):
        for e in x:
            _block(e)


@dataclasses.dataclass
class Measurement:
    seconds: float  # median runtime
    compile_seconds: float  # first (warm-up) call minus median
    repeats: int
    energy_joules: float | None = None  # per call, when a PowerMeter is wired
    # "measured" (hardware counter over the trial window) vs "estimated"
    # (modelled, e.g. time-proportional draw or apportioned from a fused
    # window); None when no meter produced a reading.  Kept on every
    # measurement so mixed metered/estimated rankings stay auditable.
    energy_provenance: str | None = None


def measure(
    fn: Callable[..., Any],
    args: Sequence[Any],
    repeats: int = 3,
    warmup: int = 1,
    min_seconds: float = 0.0,
) -> Measurement:
    """Median seconds per call; ``min_seconds`` > 0 repeats each timed
    window until it spans at least that much wall time (per-call time is
    the window divided by the call count), which stabilises sub-millisecond
    kernels whose single-call time is dominated by timer/dispatch noise."""
    t0 = time.perf_counter()
    for _ in range(max(warmup, 0)):
        _block(fn(*args))
    warm = time.perf_counter() - t0
    times = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        calls = 0
        while True:
            _block(fn(*args))
            calls += 1
            elapsed = time.perf_counter() - t0
            if elapsed >= min_seconds:
                break
        times.append(elapsed / calls)
    times.sort()
    med = times[len(times) // 2]
    return Measurement(
        seconds=max(med, 1e-9),
        compile_seconds=max(warm - med, 0.0),
        repeats=len(times),
    )


@dataclasses.dataclass
class Trial:
    pattern: tuple[str, ...]  # names of blocks offloaded in this variant
    seconds: float
    speedup: float  # vs baseline


@dataclasses.dataclass
class VerificationReport:
    baseline_seconds: float
    trials: list[Trial]
    best: Trial
    search_seconds: float  # total wall time of the search (paper headline)

    def trial(self, pattern: Iterable[str]) -> Trial | None:
        key = tuple(sorted(pattern))
        for t in self.trials:
            if tuple(sorted(t.pattern)) == key:
                return t
        return None


def search_offload_pattern(
    build_variant: Callable[[frozenset[str]], Callable[..., Any]],
    candidates: Sequence[str],
    args: Sequence[Any],
    repeats: int = 3,
    prefilter: Callable[[str], bool] | None = None,
) -> VerificationReport:
    """Deprecated shim: the paper's single-then-combine measured search.

    ``build_variant(subset)`` must return a callable implementing the
    application with exactly ``subset`` blocks offloaded (empty set =
    unmodified baseline).  New code should use
    ``planner.SingleThenCombine().search(planner.SubsetSpace(...), ...)``
    directly — this wrapper survives only for source compatibility.
    """
    from repro.core import planner

    names = [c for c in candidates if prefilter is None or prefilter(c)]
    space = planner.SubsetSpace(build_variant, names)
    report = planner.SingleThenCombine().search(
        space, args, cache=planner.MeasurementCache(), repeats=repeats
    )
    return planner.to_verification_report(report)


def verify_numerics(
    original: Callable[..., Any],
    substituted: Callable[..., Any],
    args: Sequence[Any],
    rtol: float = 1e-3,
    atol: float = 1e-3,
) -> bool:
    """Functional check that a substitution preserves results (the paper's
    動作検証 step before deployment).

    Structure-aware: outputs may be arrays, tuples (engine apps) or whole
    pytrees (bound model steps) — structures must match leaf for leaf.
    Low-precision floats (bfloat16) widen to f64 and complex stays complex
    so the tolerance arithmetic is well-defined.
    """
    import numpy as np

    a = original(*args)
    b = substituted(*args)

    try:
        import jax

        la, ta = jax.tree.flatten(a)
        lb, tb = jax.tree.flatten(b)
        if ta != tb:
            return False
    except Exception:  # noqa: BLE001 — no jax: fall back to tuples/arrays
        la = list(a) if isinstance(a, (tuple, list)) else [a]
        lb = list(b) if isinstance(b, (tuple, list)) else [b]
        if len(la) != len(lb):
            return False

    def widen(x):
        # complex stays complex; float (incl. bfloat16, numpy kind 'V')
        # widens to f64 so allclose arithmetic is well-defined
        if x.dtype.kind == "c":
            return x.astype(np.complex128)
        if x.dtype.kind in "fV":
            return x.astype(np.float64)
        return x

    for x, y in zip(la, lb):
        x = np.asarray(x)
        y = np.asarray(y)
        if x.shape != y.shape:
            return False
        if not np.allclose(widen(x), widen(y), rtol=rtol, atol=atol):
            return False
    return True
