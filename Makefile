PYTHON ?= python

# Tier-1 verification (ROADMAP): the full suite, fail-fast.
.PHONY: test
test:
	./scripts/test.sh full

# Planner + core tests only — skips the slow kernel sweeps and end-to-end
# system/arch tests.  This is what CI runs on every push.  The file list
# lives in scripts/test.sh (single source of truth).
.PHONY: test-fast
test-fast:
	./scripts/test.sh fast

# Critical-tier lint (see ruff.toml): syntax errors, undefined names.
.PHONY: lint
lint:
	$(PYTHON) -m ruff check src tests benchmarks examples

# Metering smoke: search two tiny stores in-process under different
# objectives and diff them (the power/performance trade-off table).
.PHONY: report
report:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) $(PYTHON) -m repro.metering.report --selftest

# Serving load smoke: Poisson arrival trace through the ServeEngine on the
# reduced config — tok/s, p50/p99 latency and joules/token with provenance.
# The machine-readable snapshot lands in BENCH_serve.json for run-over-run
# diffs.
.PHONY: serve-bench
serve-bench:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) $(PYTHON) benchmarks/serve_load.py --fast --meter auto --json-out BENCH_serve.json

# Same trace on the block-paged KV cache (chunked prefill on): pool
# utilization / stranded / fragmentation stats alongside the tok/s numbers.
.PHONY: serve-bench-paged
serve-bench-paged:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) $(PYTHON) benchmarks/serve_load.py --fast --meter auto --page-size 16 --prefill-chunk 8 --json-out BENCH_serve_paged.json

# Paged-attention microbench: fused page walk vs gathered view across
# page sizes — measured latency where the kernel can run, static
# peak-live-bytes everywhere.  Snapshot lands in BENCH_paged_attn.json.
.PHONY: bench-paged-attn
bench-paged-attn:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) $(PYTHON) benchmarks/paged_attention_bench.py --json-out BENCH_paged_attn.json

# Observability demo: run the fast serving trace with the lifecycle
# tracer on, write trace-demo.json (loadable at ui.perfetto.dev) and a
# Prometheus snapshot, then print the terminal span summary.
.PHONY: trace-demo
trace-demo:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) $(PYTHON) benchmarks/serve_load.py --fast --trace-out trace-demo.json --metrics-out trace-demo-metrics.txt
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) $(PYTHON) -m repro.obs.timeline trace-demo.json --check

# Static analysis: legality + resource-envelope + hot-path + paging
# passes over every zoo (arch, phase) program and two tiny serve engines,
# ratcheted against the checked-in analysis_baseline.json — CI fails only
# on NEW findings.  Resource verdicts check the static cpu-host-16g
# envelope so they are identical on every host; then a serve preflight
# proves the static capacity gate passes for a config that fits.
.PHONY: analyze
analyze:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) $(PYTHON) -m repro.analysis.lint --resources --fail-on-new
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) $(PYTHON) -m repro.launch.serve --arch llama3.2-1b --reduced --slots 2 --max-len 64 --page-size 16 --envelope cpu-host-16g --preflight

# Static capacity check of a serve deployment without booting the engine
# (override ARCH/ENVELOPE/PREFLIGHT_ARGS as needed).
.PHONY: preflight
ARCH ?= llama3.2-1b
ENVELOPE ?= host
PREFLIGHT_ARGS ?= --reduced --slots 2 --max-len 64
preflight:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) $(PYTHON) -m repro.launch.serve --arch $(ARCH) --envelope $(ENVELOPE) $(PREFLIGHT_ARGS) --preflight

.PHONY: deps-dev
deps-dev:
	$(PYTHON) -m pip install -r requirements-dev.txt
