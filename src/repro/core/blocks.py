"""FunctionBlock registry — the paper's technique as a first-class framework
feature.

Models in ``repro.models`` do not hard-code their compute implementations;
they invoke *named function blocks* (``call("rmsnorm", ...)``).  Every block
name has one or more registered implementations, tagged by execution target:

    "ref"     pure-jnp oracle (the naive/XLA-default path)
    "xla"     XLA-optimised jnp formulation
    "pallas"  Pallas TPU kernel (the cuFFT/IP-core shelf)

The offload engine's Step 3 selects a *binding* per block for the current
environment — by verification-environment measurement on a real machine, or
by dry-run cost analysis when only the compiler is available (the FPGA-style
pre-filter).  Bindings are scoped via a context manager so a training step
can be traced under a chosen offload pattern; this is how "offload pattern"
becomes a compile-time property of the jitted program.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Iterable, Iterator, Mapping


@dataclasses.dataclass(frozen=True)
class Impl:
    block: str
    target: str  # "ref" | "xla" | "pallas"
    fn: Callable[..., Any]
    note: str = ""


class FunctionBlockRegistry:
    def __init__(self) -> None:
        self._impls: dict[str, dict[str, Impl]] = {}
        self._local = threading.local()

    # -- registration --------------------------------------------------------
    def register(
        self, block: str, target: str, fn: Callable[..., Any], note: str = ""
    ) -> None:
        self._impls.setdefault(block, {})[target] = Impl(block, target, fn, note)

    def implementation(self, block: str, target: str) -> Impl:
        return self._impls[block][target]

    def blocks(self) -> list[str]:
        return sorted(self._impls)

    def targets(self, block: str) -> list[str]:
        return sorted(self._impls.get(block, {}))

    def shelf_fingerprint(self, blocks: Iterable[str] | None = None) -> str:
        """Hash of the *currently registered* implementations for the named
        blocks: (block, target, fn source) plus bound partial arguments.
        Registry state is import-order dependent (modules may re-register
        a block at import time), so persisted-plan fingerprints should use
        a registration-time snapshot instead — see
        ``repro.kernels.SHELF_FINGERPRINT`` / ``implementations_fingerprint``."""
        names = sorted(blocks) if blocks is not None else self.blocks()
        return implementations_fingerprint(
            (block, target, self._impls[block][target].fn)
            for block in names
            for target in self.targets(block)
        )

    # -- binding --------------------------------------------------------------
    @property
    def _bindings(self) -> dict[str, str]:
        b = getattr(self._local, "bindings", None)
        if b is None:
            b = {}
            self._local.bindings = b
        return b

    @contextlib.contextmanager
    def bind(self, mapping: Mapping[str, str]) -> Iterator[None]:
        """Scope a block->target binding (an offload pattern)."""
        saved = dict(self._bindings)
        self._bindings.update(mapping)
        try:
            yield
        finally:
            self._local.bindings = saved

    def resolve(self, block: str) -> Callable[..., Any]:
        impls = self._impls.get(block)
        if not impls:
            raise KeyError(f"unknown function block '{block}'")
        target = self._bindings.get(block)
        if target is None:
            # default preference: xla formulation, else ref
            for t in ("xla", "ref", "pallas"):
                if t in impls:
                    return impls[t].fn
            raise KeyError(f"block '{block}' has no usable implementation")
        return impls[target].fn

    def call(self, block: str, *args: Any, **kwargs: Any) -> Any:
        return self.resolve(block)(*args, **kwargs)

    def current_pattern(self) -> dict[str, str]:
        return dict(self._bindings)


def implementations_fingerprint(
    impls: "Iterable[tuple[str, str, Callable[..., Any]]]",
) -> str:
    """Hash (block, target, fn) triples by fn *source* (plus bound partial
    arguments), order-insensitively.  A kernel rewrite changes the hash,
    which invalidates stored plans measured against the old code
    (PlanStore fingerprint component)."""
    import functools
    import hashlib
    import inspect

    parts = []
    for block, target, fn in impls:
        bound = ""
        while isinstance(fn, functools.partial):
            bound += repr((fn.args, sorted((fn.keywords or {}).items())))
            fn = fn.func
        try:
            src = inspect.getsource(fn)
        except (OSError, TypeError):  # builtins / C extensions
            src = repr(fn)
        parts.append(f"{block}|{target}|{bound}|{src}")
    h = hashlib.sha256()
    for p in sorted(parts):
        h.update(p.encode())
    return h.hexdigest()[:16]


# Global registry used by the model zoo.
registry = FunctionBlockRegistry()


def call(block: str, *args: Any, **kwargs: Any) -> Any:
    return registry.call(block, *args, **kwargs)


def bind(mapping: Mapping[str, str]):
    return registry.bind(mapping)


def register(block: str, target: str, note: str = ""):
    """Decorator: ``@register("rmsnorm", "pallas")``."""

    def deco(fn: Callable[..., Any]) -> Callable[..., Any]:
        registry.register(block, target, fn, note)
        return fn

    return deco
