"""Mamba-2 SSD chunked kernel vs sequential-scan oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _inputs(rng, b=2, s=128, h=4, p=16, n=8):
    x = (rng.standard_normal((b, s, h, p)) * 0.5).astype(np.float32)
    dt = (np.abs(rng.standard_normal((b, s, h))) * 0.1).astype(np.float32)
    a = (-np.abs(rng.standard_normal(h))).astype(np.float32)
    bm = (rng.standard_normal((b, s, n)) * 0.3).astype(np.float32)
    cm = (rng.standard_normal((b, s, n)) * 0.3).astype(np.float32)
    return tuple(map(jnp.asarray, (x, dt, a, bm, cm)))


@pytest.mark.parametrize("chunk", [16, 32, 64, 128])
def test_xla_chunked_matches_sequential(chunk, rng):
    args = _inputs(rng)
    y_ref, h_ref = ref.ssd_ref(*args)
    y, h = ops.ssd_scan(*args, chunk=chunk, backend="xla")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-5)


@pytest.mark.parametrize("chunk", [32, 64])
def test_pallas_chunks_match_sequential(chunk, rng):
    args = _inputs(rng)
    y_ref, h_ref = ref.ssd_ref(*args)
    y, h = ops.ssd_scan(*args, chunk=chunk, backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-5)


def test_initial_state_carries(rng):
    args = _inputs(rng, s=64)
    y1, h1 = ops.ssd_scan(*args, chunk=32, backend="xla")
    # split the sequence: scan first half, feed state into second half
    x, dt, a, bm, cm = args
    y_a, h_a = ops.ssd_scan(
        x[:, :32], dt[:, :32], a, bm[:, :32], cm[:, :32], chunk=32, backend="xla"
    )
    y_b, h_b = ops.ssd_scan(
        x[:, 32:], dt[:, 32:], a, bm[:, 32:], cm[:, 32:], chunk=32,
        backend="xla", h0=h_a,
    )
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y1[:, 32:]), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_b), np.asarray(h1), atol=2e-5)


def test_nondivisible_seq_padding(rng):
    args = _inputs(rng, s=100)
    y_ref, h_ref = ref.ssd_ref(*args)
    y, h = ops.ssd_scan(*args, chunk=32, backend="xla")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-5)


def test_decay_mask_is_causal(rng):
    # output at position t must not depend on inputs at positions > t
    args = _inputs(rng, b=1, s=64)
    x, dt, a, bm, cm = args
    y1, _ = ops.ssd_scan(*args, chunk=32, backend="xla")
    x2 = x.at[:, 48:].set(999.0)
    y2, _ = ops.ssd_scan(x2, dt, a, bm, cm, chunk=32, backend="xla")
    np.testing.assert_allclose(
        np.asarray(y1[:, :48]), np.asarray(y2[:, :48]), atol=1e-5
    )
