import os
import sys

# Tests run on the single host device (the dry-run sets its own flags in a
# separate process).  Keep CPU feature parity deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
