"""Interface matching C-1/C-2: casts silent, semantic changes gated."""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.interface import (
    Adaptation,
    InterfaceMismatch,
    InterfaceSpec,
    Param,
    Policy,
    match_interfaces,
    pad_to,
    spec_from_arrays,
    unpad_from,
)


def _spec(*dtypes, returns=("float32",), optional_from=None):
    params = tuple(
        Param(f"a{i}", dt, optional=(optional_from is not None and i >= optional_from))
        for i, dt in enumerate(dtypes)
    )
    return InterfaceSpec(params=params, returns=tuple(returns))


def test_exact_match_is_c1():
    a = match_interfaces(_spec("float32"), _spec("float32"))
    assert a.exact and a.dropped == ()


def test_cast_without_confirmation():
    # paper: float/double casts proceed without asking the user
    a = match_interfaces(_spec("float64"), _spec("float32"))
    assert not a.exact
    assert a.arg_casts[0] == (0, "float32")


def test_optional_arg_dropped_silently():
    src = _spec("float32", "float32", optional_from=1)
    dst = _spec("float32")
    a = match_interfaces(src, dst)
    assert a.dropped == ("a1",)


def test_required_mismatch_needs_confirmation():
    src = _spec("float32", "float32")  # both required
    dst = _spec("float32")
    with pytest.raises(InterfaceMismatch):
        match_interfaces(src, dst)


def test_confirmation_callback_allows():
    src = _spec("float32", "float32")
    dst = _spec("float32")
    msgs = []
    pol = Policy(confirm=lambda m: msgs.append(m) or True)
    a = match_interfaces(src, dst, pol)
    assert a.confirmed and msgs


def test_return_arity_mismatch_gated():
    src = _spec("float32", returns=("float32", "int64", "float64"))
    dst = _spec("float32", returns=("float32",))
    with pytest.raises(InterfaceMismatch):
        match_interfaces(src, dst)


def test_wrap_applies_casts_and_unpads():
    src = _spec("float64")
    dst = InterfaceSpec(
        params=(Param("x", "float32", align=4),), returns=("float32",)
    )
    a = match_interfaces(src, dst)

    def impl(x):
        assert x.dtype == np.float32
        assert x.shape[-1] % 4 == 0
        return x * 2.0

    fn = a.wrap(impl)
    out = fn(np.ones((3, 5), np.float64))
    assert out.shape == (3, 5)
    np.testing.assert_allclose(np.asarray(out), 2.0)


def test_spec_from_arrays():
    s = spec_from_arrays(
        [np.zeros((2, 2), np.float64), np.int32(3)], [np.zeros(2, np.float32)]
    )
    assert s.params[0].dtype == "float64" and s.params[0].rank == 2
    assert s.returns == ("float32",)


# -- hypothesis properties -------------------------------------------------

_dtypes = st.sampled_from(["float32", "float64", "bfloat16"])


@given(st.lists(_dtypes, min_size=1, max_size=4))
def test_identity_always_exact(dts):
    spec = _spec(*dts)
    a = match_interfaces(spec, spec)
    assert a.exact


@given(_dtypes, _dtypes)
def test_float_casts_never_raise(src_dt, dst_dt):
    a = match_interfaces(_spec(src_dt), _spec(dst_dt))
    assert a.arg_casts[0][1] in (None, dst_dt)


@given(
    st.integers(1, 64), st.integers(1, 64),
    st.sampled_from([1, 2, 4, 8, 128]),
)
def test_pad_unpad_roundtrip(n, m, align):
    x = np.arange(n * m, dtype=np.float32).reshape(n, m)
    padded = pad_to(x, align)
    assert padded.shape[-1] % align == 0 and padded.shape[-2] % align == 0
    back = unpad_from(padded, x.shape)
    np.testing.assert_array_equal(back, x)
