"""AdamW with global-norm clipping and configurable moment dtype.

Moments may be stored in bf16 (``moment_dtype="bfloat16"``) — the memory
knob that lets the 236B/480B MoE configs fit 16 GB/chip HBM (see the
dry-run memory analysis).  Update math always runs in f32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    mu: Any
    nu: Any
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"

    def init(self, params: Any) -> OptState:
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return OptState(
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
            step=jnp.zeros((), jnp.int32),
        )

    def update(
        self, grads: Any, state: OptState, params: Any, lr: jax.Array
    ) -> tuple[Any, OptState]:
        # global-norm clip (f32 accumulation)
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)
            )
        )
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        step = state.step + 1
        c1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** step.astype(jnp.float32)
        mdt = jnp.dtype(self.moment_dtype)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m32 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v32 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            mhat = m32 / c1
            vhat = v32 / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # no decay on norms/biases/scalars
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        newm = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        newv = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return newp, OptState(mu=newm, nu=newv, step=step)
