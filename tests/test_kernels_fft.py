"""Matmul-DFT FFT kernel vs numpy oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.fft import complex_matmul_pallas, dft_matrix


@pytest.mark.parametrize("n,m", [(128, 128), (256, 128), (128, 256)])
def test_fft2d_pallas_matches_numpy(n, m, rng):
    x = (rng.standard_normal((n, m)) + 1j * rng.standard_normal((n, m))).astype(
        np.complex64
    )
    out = ops.fft2d(jnp.asarray(x), backend="pallas", interpret=True)
    want = np.fft.fft2(x)
    scale = np.abs(want).max()
    assert np.abs(np.asarray(out) - want).max() / scale < 1e-5


@pytest.mark.parametrize("n", [256, 512])
def test_four_step_variant_matches(n, rng):
    x = (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))).astype(
        np.complex64
    )
    out = ops.fft2d(
        jnp.asarray(x), backend="pallas", variant="four-step", interpret=True
    )
    want = np.fft.fft2(x)
    assert np.abs(np.asarray(out) - want).max() / np.abs(want).max() < 1e-5


def test_complex_matmul_kernel(rng):
    ar = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    ai = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    br = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    bi = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    yr, yi = complex_matmul_pallas(ar, ai, br, bi, interpret=True)
    want = (np.asarray(ar) + 1j * np.asarray(ai)) @ (
        np.asarray(br) + 1j * np.asarray(bi)
    )
    np.testing.assert_allclose(np.asarray(yr), want.real, atol=1e-3)
    np.testing.assert_allclose(np.asarray(yi), want.imag, atol=1e-3)


def test_dft_matrix_unitary_up_to_scale():
    fr, fi = dft_matrix(64)
    f = fr + 1j * fi
    prod = f @ f.conj().T
    np.testing.assert_allclose(prod, 64 * np.eye(64), atol=1e-3)


def test_fft2d_xla_backend(rng):
    x = (rng.standard_normal((64, 64)) + 1j * rng.standard_normal((64, 64))).astype(
        np.complex64
    )
    out = ops.fft2d(jnp.asarray(x), backend="xla")
    np.testing.assert_allclose(
        np.asarray(out), np.fft.fft2(x).astype(np.complex64), rtol=1e-4, atol=1e-3
    )
