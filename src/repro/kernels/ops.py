"""Jit'd public wrappers for the kernel shelf, with environment dispatch.

Every wrapper picks its implementation from the deployment environment —
the environment-adaptive behaviour of the paper: the same call runs the
Pallas kernel on a TPU backend and the XLA-native formulation elsewhere.
``backend=`` overrides ("pallas" | "xla"); ``interpret=True`` runs the Pallas
kernel body in Python (how the kernels are validated on this CPU container).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.attention import flash_attention_pallas
from repro.kernels import paged_attention as _paged
from repro.kernels.fft import dft_matrix, fft2d_pallas
from repro.kernels.lu import lu_blocked
from repro.kernels.matmul import matmul_pallas, schur_update_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd import ssd_chunks_pallas


def _auto_backend(backend: str | None) -> str:
    if backend is not None:
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# -- matmul (cuBLAS analogue) --------------------------------------------------


def matmul(a, b, *, backend: str | None = None, interpret: bool = False):
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    if _auto_backend(backend) == "pallas":
        return matmul_pallas(a, b, interpret=interpret)
    return _ref.matmul_ref(a, b)


def schur_update(c, a, b, *, backend: str | None = None, interpret: bool = False):
    if _auto_backend(backend) == "pallas":
        return schur_update_pallas(c, a, b, interpret=interpret)
    return _ref.schur_update_ref(c, a, b)


# -- fft2d (cuFFT analogue) ----------------------------------------------------


@functools.partial(jax.jit, static_argnames=("backend", "variant", "interpret"))
def fft2d(
    x,
    *,
    backend: str | None = None,
    variant: str = "direct",
    interpret: bool = False,
):
    """2-D complex FFT.  pallas: matmul-DFT stages on the MXU; xla: native."""
    x = jnp.asarray(x)
    if x.dtype not in (jnp.complex64, jnp.complex128):
        x = x.astype(jnp.complex64)
    if _auto_backend(backend) == "pallas":
        if variant == "four-step":
            return _fft2d_four_step(x, interpret=interpret)
        return fft2d_pallas(x.astype(jnp.complex64), interpret=interpret)
    return jnp.fft.fft2(x).astype(jnp.complex64)


def _fft1d_four_step_axis1(x: jax.Array, interpret: bool = False) -> jax.Array:
    """Four-step FFT along the last axis via two matmul-DFT stages.

    n = n1*n2:  X (rows, n) -> reshape (rows, n1, n2)
      1) DFT_n2 along axis2 (matmul with F_{n2})
      2) twiddle  w^{j1*k2}
      3) DFT_n1 along axis1 (matmul with F_{n1})
      4) transpose (k2, j1) -> index k2*n1 + j1
    Cost 2n(n1+n2) vs direct 2n^2 — the beyond-paper §Perf variant.
    """
    rows, n = x.shape
    n1 = 1 << ((n.bit_length() - 1) // 2)
    n2 = n // n1
    fr2, fi2 = dft_matrix(n2)
    f2 = jnp.asarray(fr2) + 1j * jnp.asarray(fi2)
    fr1, fi1 = dft_matrix(n1)
    f1 = jnp.asarray(fr1) + 1j * jnp.asarray(fi1)
    # x[j1*n2 + j2] -> (j1, j2); DFT over j1 first, twiddle, DFT over j2.
    xr = x.reshape(rows, n1, n2)
    y = jnp.einsum("ab,rbc->rac", f1.astype(x.dtype), xr)  # axis1 -> k1
    k1 = jnp.arange(n1)[:, None]
    j2 = jnp.arange(n2)[None, :]
    tw = jnp.exp(-2j * jnp.pi * (k1 * j2) / n).astype(x.dtype)
    y = y * tw[None]
    z = jnp.einsum("rac,cd->rad", y, f2.astype(x.dtype))  # axis2 -> k2
    # output index k = k2*n1 + k1  (transpose the two factors)
    return jnp.transpose(z, (0, 2, 1)).reshape(rows, n)


def _fft2d_four_step(x: jax.Array, interpret: bool = False) -> jax.Array:
    y = _fft1d_four_step_axis1(x, interpret)
    y = _fft1d_four_step_axis1(y.T, interpret).T
    return y.astype(jnp.complex64)


# -- LU (cuSOLVER getrf analogue) ----------------------------------------------


def lu(a, *, nb: int | None = None, backend: str | None = None,
       interpret: bool = False):
    """Blocked LU with partial pivoting.  Returns (lu_packed, piv).

    Arbitrary n: pads to a multiple of nb with an identity extension (pad
    rows can never be chosen as pivots for real columns).  The default block
    size adapts to the problem: small matrices are panel-dominated and want
    small blocks; large ones want MXU-aligned 128 panels (verified 9x at
    n=160, see EXPERIMENTS §Paper-repro).
    """
    a = jnp.asarray(a, dtype=jnp.float32)
    n = a.shape[0]
    if nb is None:
        nb = 128 if n >= 512 else 32
    npad = ((n + nb - 1) // nb) * nb
    if npad != n:
        ap = jnp.eye(npad, dtype=jnp.float32)
        ap = ap.at[:n, :n].set(a)
        ap = ap.at[jnp.arange(n), jnp.arange(n)].set(a[jnp.arange(n), jnp.arange(n)])
    else:
        ap = a
    use_pallas = _auto_backend(backend) == "pallas"
    lu_p, piv, _parity = lu_blocked(
        ap, nb=nb, n_real=n, use_pallas=use_pallas, interpret=interpret
    )
    return lu_p[:n, :n], piv[:n]


def lu_nr_compat(a, *, backend: str | None = None, interpret: bool = False):
    """Numerical-Recipes-shaped interface: returns (lu, indx, d).

    This is the DB-registered replacement for ``ludcmp`` — C-1 glue that
    matches the host program's expected (lu, indx, d) signature.
    """
    lu_p, piv = lu(a, backend=backend, interpret=interpret)
    n = piv.shape[0]
    swaps = jnp.sum(jnp.where(piv != jnp.arange(n, dtype=piv.dtype), 1, 0))
    d = jnp.where(swaps % 2 == 0, 1.0, -1.0).astype(jnp.float32)
    return lu_p, piv.astype(jnp.int32), d


# -- attention ------------------------------------------------------------------


def flash_attention(
    q, k, v, *, causal: bool = True, backend: str | None = None,
    interpret: bool = False,
):
    if _auto_backend(backend) == "pallas" and q.shape[2] > 1:
        return flash_attention_pallas(q, k, v, causal=causal, interpret=interpret)
    return _ref.attention_ref(q, k, v, causal=causal)


def paged_attention(
    q, k_pool, v_pool, pages, index, *, q_rope=None, kr_pool=None,
    scale: float | None = None, backend: str | None = None,
    interpret: bool | None = None,
):
    """Paged decode/extend attention through the page table.

    pallas: the fused page-walk kernel (no gathered K/V view); xla: the
    rolled gather + dense masked softmax.  When the pallas target is
    *forced* off-TPU (``backend="pallas"`` on this CPU container, e.g. a
    serve run with ``--decode-impl pallas``), ``interpret`` defaults on so
    the kernel body runs in Python — the parity path CPU CI proves
    token-identical.  On TPU the compiled Mosaic kernel runs as-is.
    """
    if _auto_backend(backend) == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return _paged.paged_attention_pallas(
            q, k_pool, v_pool, pages, index, q_rope=q_rope, kr_pool=kr_pool,
            scale=scale, interpret=interpret,
        )
    return _paged.paged_attention_xla(
        q, k_pool, v_pool, pages, index, q_rope=q_rope, kr_pool=kr_pool,
        scale=scale,
    )


# -- rmsnorm ---------------------------------------------------------------------


def rmsnorm(x, w, *, eps: float = 1e-6, backend: str | None = None,
            interpret: bool = False):
    if _auto_backend(backend) == "pallas":
        return rmsnorm_pallas(x, w, eps=eps, interpret=interpret)
    return _ref.rmsnorm_ref(x, w, eps=eps)


# -- Mamba-2 SSD scan -------------------------------------------------------------


def _ssd_chunks_jnp(x, dt, a, bmat, cmat, *, chunk: int):
    """XLA-native vectorised version of the per-chunk kernel terms."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    nc = s // chunk
    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    af = a.astype(jnp.float32)
    bf = bmat.astype(jnp.float32).reshape(b, nc, chunk, n)
    cf = cmat.astype(jnp.float32).reshape(b, nc, chunk, n)

    a_seg = dtf * af[None, None, None, :]  # (B,NC,L,H)
    a_cum = jnp.cumsum(a_seg, axis=2)
    a_tot = a_cum[:, :, -1, :]  # (B,NC,H)

    diff = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]  # (B,NC,L,L,H)
    ii = jnp.arange(chunk)[:, None]
    jj = jnp.arange(chunk)[None, :]
    lam = jnp.where((ii >= jj)[None, None, :, :, None], jnp.exp(diff), 0.0)
    g = jnp.einsum("bcin,bcjn->bcij", cf, bf)  # (B,NC,L,L)
    w = g[..., None] * lam * dtf[:, :, None, :, :]  # (B,NC,L,L,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xf)

    sw = dtf * jnp.exp(a_tot[:, :, None, :] - a_cum)  # (B,NC,L,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bf, sw, xf)

    cumdecay = jnp.exp(a_cum).reshape(b, s, h)
    totals = jnp.exp(a_tot)
    return (
        y_intra.reshape(b, s, h, p),
        states,
        cumdecay,
        totals,
    )


def _ssd_combine(y_intra, states, cumdecay, totals, cmat, h0, chunk: int):
    b, nc, h, n, p = states.shape
    s = nc * chunk
    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)

    sts = jnp.moveaxis(states, 1, 0)  # (NC,B,H,N,P)
    tots = jnp.moveaxis(totals, 1, 0)  # (NC,B,H)

    def body(hprev, inp):
        st, tot = inp
        hnew = hprev * tot[..., None, None] + st
        return hnew, hprev

    hfin, henter = jax.lax.scan(body, h0.astype(jnp.float32), (sts, tots))
    c_chunks = cmat.astype(jnp.float32).reshape(b, nc, chunk, n)
    y_inter = jnp.einsum("bcln,cbhnp->bclhp", c_chunks, henter)
    y_inter = y_inter * cumdecay.reshape(b, nc, chunk, h)[..., None]
    y = y_intra + y_inter.reshape(b, s, h, p)
    return y, hfin


def ssd_scan(
    x, dt, a, bmat, cmat, *, chunk: int = 128, h0=None,
    backend: str | None = None, interpret: bool = False,
):
    """Chunked SSD selective scan.  Returns (y, final_state)."""
    be = _auto_backend(backend)
    s = x.shape[1]
    chunk = min(chunk, s)
    if s % chunk:
        # pad with dt=0 steps: decay exp(0)=1 and update dt*B*x=0, so the
        # final state is untouched; padded outputs are sliced away.
        pad = chunk - s % chunk
        padded = ssd_scan(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            a,
            jnp.pad(bmat, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(cmat, ((0, 0), (0, pad), (0, 0))),
            chunk=chunk, h0=h0, backend=backend, interpret=interpret,
        )
        y, hfin = padded
        return y[:, :s], hfin
    if be == "pallas":
        y_i, states, cumdecay, totals = ssd_chunks_pallas(
            x, dt, a, bmat, cmat, chunk=chunk, interpret=interpret
        )
    elif be == "ref":
        return _ref.ssd_ref(x, dt, a, bmat, cmat, h0=h0)
    else:
        y_i, states, cumdecay, totals = _ssd_chunks_jnp(
            x, dt, a, bmat, cmat, chunk=chunk
        )
    return _ssd_combine(y_i, states, cumdecay, totals, cmat, h0, chunk)
