"""repro.analysis.resources: memory-envelope verifier + capacity planner.

Covers the liveness estimator against XLA's own ``memory_analysis()`` on
CPU, envelope resolution, the OOM pre-filter driven through a real
OffloadSession search (pruned and unpruned must commit the same winner),
capacity-planner math cross-checked against ``PagePool`` accounting, the
``--preflight`` CLI rejecting an undersized device, and the shelf
coverage + baseline-portability satellites.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    Diagnostic,
    DeviceEnvelope,
    ResourceHint,
    STATIC_ENVELOPES,
    check_binding_space_resources,
    estimate_memory,
    lint_shelf_coverage,
    plan_serve_capacity,
    resolve_envelope,
)
from repro.analysis.devices import MiB
from repro.analysis.resources import jaxpr_peak_bytes
from repro.core.blocks import FunctionBlockRegistry
from repro.core.planner import BindingSpace, SingleThenCombine
from repro.offload.session import OffloadSession


# -- liveness estimator -------------------------------------------------------


def _chain(x, w):
    for _ in range(4):
        x = jnp.tanh(x @ w)
    return x.sum()


def test_estimator_brackets_xla_memory_analysis():
    """The liveness estimate must be an upper bound on what the program
    irreducibly holds (arguments + outputs) and within a small factor of
    XLA's own compiled accounting — fusion makes XLA leaner, never the
    other way around by more than the chain's live intermediates."""
    x = np.zeros((256, 256), np.float32)
    w = np.zeros((256, 256), np.float32)
    est = estimate_memory(_chain, x, w)

    compiled = jax.jit(_chain).lower(x, w).compile()
    ma = compiled.memory_analysis()
    xla_total = (
        ma.argument_size_in_bytes
        + ma.output_size_in_bytes
        + ma.temp_size_in_bytes
    )
    assert est.peak_live_bytes >= x.nbytes + w.nbytes
    assert est.peak_live_bytes <= 4 * xla_total


def test_estimator_counts_operands_consts_and_intermediates():
    w = jnp.ones((128, 128))  # captured -> const of the traced program

    def f(x):
        return (x @ w).sum()

    x = np.zeros((128, 128), np.float32)
    est = estimate_memory(f, x)
    assert est.operand_bytes == x.nbytes
    assert est.const_bytes == 128 * 128 * 4
    assert est.peak_intermediate_bytes >= 128 * 128 * 4  # the product
    assert est.peak_live_bytes >= est.operand_bytes + est.const_bytes


def test_donation_credit_reduces_peak():
    def f(cache, delta):
        return jax.tree.map(lambda c: c + delta, cache)

    cache = {"k": np.zeros((64, 64), np.float32)}
    est_plain = estimate_memory(f, cache, 1.0)
    est_donated = estimate_memory(f, cache, 1.0, donate_argnums=(0,))
    assert est_donated.donated_bytes == 64 * 64 * 4
    assert est_donated.peak_live_bytes < est_plain.peak_live_bytes


def test_peak_walk_recurses_into_scan_bodies():
    def f(x):
        def body(carry, _):
            y = jnp.tanh(carry @ carry)
            return y, y

        return jax.lax.scan(body, x, None, length=8)

    x = np.zeros((64, 64), np.float32)
    closed = jax.make_jaxpr(f)(x)
    peak = jaxpr_peak_bytes(closed.jaxpr)
    # stacked ys (8, 64, 64) live at the end, plus the body's working set
    assert peak >= 8 * 64 * 64 * 4 + 64 * 64 * 4


# -- device envelopes ---------------------------------------------------------


def test_envelope_resolution():
    tiny = resolve_envelope("tiny-32m")
    assert tiny.memory_bytes == 32 * MiB
    assert tiny is STATIC_ENVELOPES["tiny-32m"]
    custom = DeviceEnvelope("mine", "cpu", 123)
    assert resolve_envelope(custom) is custom
    with pytest.raises(KeyError, match="tiny-32m"):
        resolve_envelope("no-such-board")
    with pytest.raises(TypeError):
        resolve_envelope(3.14)
    probed = resolve_envelope("host")
    assert probed.source == "probed"
    assert probed.memory_bytes > 0
    assert tiny.headroom_bytes(48 * MiB) < 0 < tiny.headroom_bytes(MiB)


# -- OOM pre-filter through a real search -------------------------------------


def _toy_registry():
    reg = FunctionBlockRegistry()
    reg.register("norm", "ref", lambda x: x * 1.0)
    reg.register("norm", "xla", lambda x: x + 0.0)
    reg.register("norm", "pallas", lambda x: x - 0.0)
    return reg


def _toy_space(reg):
    return BindingSpace(
        lambda: (lambda x: reg.call("norm", x)), registry=reg, tag="toy"
    )


#: Synthetic small board plus a hint that makes only the pallas binding
#: blow past it (candidates share the baseline's shapes, so overheads are
#: what differentiates them).
SMALL_ENVELOPE = DeviceEnvelope("test-64m", "cpu", 64 * MiB)
OOM_HINTS = {("norm", "pallas"): ResourceHint(workspace_bytes=128 * MiB)}


class FakeExecutor:
    """Deterministic 'measurements' keyed on the candidate's binding; never
    calls the built fn (mirrors tests/test_analysis.py)."""

    name = "fake"

    def __init__(self, times):
        self.times = times
        self.measured: list = []

    def run(self, jobs, meter=None):
        from repro.core.verify import Measurement

        out = []
        for job in jobs:
            binding = job.space.binding_of(job.candidate)
            self.measured.append(binding)
            out.append(Measurement(
                seconds=self.times[binding.get("norm", "ref")],
                compile_seconds=0.0, repeats=1,
            ))
        return out


TIMES = {"ref": 0.02, "xla": 0.001, "pallas": 5.0}


def _searched_session(resources):
    session = OffloadSession(
        _toy_space(_toy_registry()),
        args=(jnp.ones((4, 4)),),
        strategy=SingleThenCombine(),
        executor=FakeExecutor(TIMES),
        repeats=1,
        resources=SMALL_ENVELOPE if resources else False,
        resource_hints=OOM_HINTS if resources else None,
    )
    session.analyze()
    session.discover()
    plan = session.plan()
    return session, plan


def test_oom_candidate_pruned_with_winner_parity():
    pruned_session, pruned_plan = _searched_session(resources=True)
    control_session, control_plan = _searched_session(resources=False)

    # the envelope pass found the OOM pallas binding and skipped it
    report = pruned_session._report
    assert report.pruned > 0
    assert any("memory" in r for r in report.pruned_reasons.values())
    fake = pruned_session.cache.executor
    assert all(b.get("norm") != "pallas" for b in fake.measured)

    # the control search measured (and rejected on merit) the 5 s pallas
    control_fake = control_session.cache.executor
    assert any(b.get("norm") == "pallas" for b in control_fake.measured)
    assert getattr(control_session._report, "pruned", 0) == 0

    # identical committed winner: pruning changed cost, not the outcome
    assert pruned_plan.mapping == control_plan.mapping == {"norm": "xla"}
    rep = pruned_session.resources_report
    assert rep is not None
    assert ("norm", "pallas") in rep.oom
    assert rep.verdicts[("norm", "xla")].fits
    assert control_session.resources_report is None


def test_resource_report_diagnostics_are_info_with_envelope_platform():
    rep = check_binding_space_resources(
        _toy_space(_toy_registry()),
        (jnp.ones((4, 4)),),
        envelope=SMALL_ENVELOPE,
        hints=OOM_HINTS,
        program="toy",
    )
    diags = rep.diagnostics()
    assert diags and all(d.severity == "info" for d in diags)
    assert all(d.platform == "test-64m" for d in diags)
    oom = [d for d in diags if d.code == "resource-oom"]
    assert [d.subject for d in oom] == ["norm->pallas"]
    assert rep.counts()["oom"] == 1


def test_vmem_tile_verdict():
    env = DeviceEnvelope("tpu-ish", "tpu", 1 << 34, vmem_bytes=16 * MiB)
    rep = check_binding_space_resources(
        _toy_space(_toy_registry()),
        (jnp.ones((4, 4)),),
        envelope=env,
        hints={("norm", "pallas"): ResourceHint(vmem_tile_bytes=32 * MiB)},
    )
    v = rep.verdicts[("norm", "pallas")]
    assert v.status == "vmem-oom"
    assert "VMEM" in rep.oom[("norm", "pallas")]


# -- capacity planner vs PagePool accounting ----------------------------------


def test_capacity_plan_matches_pagepool_math():
    from repro.configs import get_config
    from repro.serve.kv.pool import PagePool, pages_for

    cfg = get_config("llama3.2-1b").reduced()
    n_slots, max_len, page_size = 3, 64, 16
    plan = plan_serve_capacity(
        cfg, n_slots=n_slots, max_len=max_len, page_size=page_size,
        envelope="cpu-host-16g",
    )
    n_pages = n_slots * pages_for(max_len, page_size)  # engine default
    assert plan.n_pages == n_pages
    assert plan.pool_tokens == PagePool(n_pages, page_size).token_capacity
    assert plan.fits and plan.headroom_bytes > 0
    # the linear model reproduces the exact configured cache bytes
    assert plan.cache_bytes > 0
    assert plan.per_page_bytes > 0
    assert plan.max_slots >= n_slots
    assert plan.max_pages >= n_pages


def test_full_config_rejected_by_tiny_envelope():
    """The full (non-reduced) 1B config is ~GiB of params from metadata
    alone — it can never fit the synthetic 32 MiB board, and the verdict
    is a ratchetable warning."""
    from repro.configs import get_config

    cfg = get_config("llama3.2-1b")
    plan = plan_serve_capacity(
        cfg, n_slots=2, max_len=64, envelope="tiny-32m",
    )
    assert not plan.fits
    assert plan.headroom_bytes < 0
    (diag,) = plan.diagnostics(program="serve:llama3.2-1b:capacity")
    assert diag.code == "capacity-oom"
    assert diag.severity == "warning"
    assert diag.platform == "tiny-32m"


def test_engine_plan_capacity_cross_checks_live_pool():
    from repro.configs import get_config
    from repro.serve import ServeEngine

    cfg = get_config("llama3.2-1b").reduced()
    engine = ServeEngine(
        cfg, n_slots=2, max_len=32, page_size=8, seed=0, quiet=True
    )
    plan = engine.plan_capacity("cpu-host-16g")
    assert plan.pool_tokens == engine.kv.pool.token_capacity
    assert plan.fits
    # fit + headroom land on the metrics registry for the re-planner
    prom = engine.registry.render_prometheus()
    assert "serve_capacity_fits 1" in prom
    assert "serve_capacity_headroom_bytes" in prom
    assert engine.lint(envelope="cpu-host-16g") == [
        d for d in engine.lint(envelope="cpu-host-16g")
        if d.code == "capacity-fit"
    ]


# -- preflight CLI ------------------------------------------------------------


def test_preflight_cli_rejects_undersized_device(capsys):
    from repro.launch.serve import main

    rc = main([
        "--arch", "llama3.2-1b", "--envelope", "tiny-32m", "--preflight",
    ])
    assert rc == 2
    out = capsys.readouterr()
    assert "DOES NOT FIT" in out.out
    assert "preflight: FAIL" in out.err


def test_preflight_cli_accepts_fitting_config(capsys):
    from repro.launch.serve import main

    rc = main([
        "--arch", "llama3.2-1b", "--reduced", "--envelope", "cpu-host-16g",
        "--page-size", "16", "--max-len", "64", "--preflight",
    ])
    assert rc == 0
    out = capsys.readouterr().out
    assert "preflight: OK" in out
    assert "FITS" in out


# -- shelf coverage + baseline portability satellites -------------------------


def test_shelf_declares_resource_hints_for_every_impl():
    from repro import kernels

    assert set(kernels.BLOCK_RESOURCES) == set(kernels.SHELF_IMPL_PAIRS)
    assert set(kernels.BLOCK_LEGALITY) == set(kernels.SHELF_IMPL_PAIRS)
    assert lint_shelf_coverage() == []
    # pallas kernels carry a VMEM tile footprint for the fit pass
    assert kernels.BLOCK_RESOURCES[("matmul", "pallas")].vmem_tile_bytes > 0


def test_shelf_coverage_flags_undeclared_impl():
    diags = lint_shelf_coverage(
        impls=(("newkernel", "pallas"),), legality={}, hints={}
    )
    (d,) = diags
    assert d.code == "shelf-coverage"
    assert d.severity == "warning"
    assert "BLOCK_LEGALITY" in d.message and "BLOCK_RESOURCES" in d.message


def test_platform_normalized_out_of_fingerprint():
    """The same finding made on a CPU CI host and a TPU production host
    must ratchet as one baseline entry."""
    on_cpu = Diagnostic("legality", "illegal-binding", "warning", "p",
                        "x->pallas", "msg", platform="cpu")
    on_tpu = Diagnostic("legality", "illegal-binding", "warning", "p",
                        "x->pallas", "msg", platform="tpu")
    assert on_cpu.fingerprint == on_tpu.fingerprint
    assert "cpu" not in on_cpu.fingerprint
    rt = Diagnostic.from_dict(on_cpu.to_dict())
    assert rt == on_cpu
    # legacy payloads without the field still load
    legacy = {k: v for k, v in on_cpu.to_dict().items() if k != "platform"}
    assert Diagnostic.from_dict(legacy).platform == ""
