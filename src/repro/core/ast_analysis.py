"""Step-1 code analysis (paper §3.4 A-1/A-2) — the Clang/libClang analogue.

The paper parses C/C++ with libClang to find (i) loop statements and their
trip structure for the prior loop-offload method, (ii) calls to external
libraries (A-1, matched against the DB's library list), and (iii) locally
defined classes/structs that may be copied-and-modified library code (A-2,
handed to the similarity detector).

Here the applications are Python/NumPy programs, so the direct analogue is
the stdlib ``ast`` module.  The report structure mirrors the paper's Step-1
output.
"""

from __future__ import annotations

import ast
import dataclasses
import inspect
import textwrap
from typing import Any, Callable, Iterable


@dataclasses.dataclass(frozen=True)
class CallSite:
    """A call to a known external library (A-1 hit)."""

    call_name: str  # dotted name as written, e.g. "np.fft.fft2"
    lineno: int
    enclosing: str  # enclosing function name ("<module>" at top level)


@dataclasses.dataclass(frozen=True)
class FuncDef:
    """A locally defined function/class (A-2 candidate)."""

    name: str
    lineno: int
    source: str  # source segment of the definition
    kind: str  # "function" | "class"
    calls: tuple[str, ...]  # dotted call names inside the def


@dataclasses.dataclass(frozen=True)
class LoopSite:
    """A loop statement (input to the prior-work loop offloader / GA)."""

    loop_id: int
    lineno: int
    enclosing: str
    kind: str  # "for" | "while"
    depth: int  # nesting depth, 0 = outermost
    body_len: int  # number of statements — crude arithmetic-intensity proxy


@dataclasses.dataclass
class SourceReport:
    """Everything Step 1 learned about one source unit."""

    library_calls: list[CallSite]
    func_defs: list[FuncDef]
    loops: list[LoopSite]
    source: str

    def calls_to(self, names: Iterable[str]) -> list[CallSite]:
        names = set(names)
        out = []
        for c in self.library_calls:
            if c.call_name in names or c.call_name.rsplit(".", 1)[-1] in names:
                out.append(c)
        return out


def _dotted_name(node: ast.AST) -> str | None:
    """Render ``a.b.c`` call targets; None for computed targets."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted_name(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


class _Analyzer(ast.NodeVisitor):
    def __init__(self, source: str, known_libraries: set[str]) -> None:
        self.source = source
        self.known = known_libraries
        self.known_tails = {k.rsplit(".", 1)[-1] for k in known_libraries}
        self.calls: list[CallSite] = []
        self.defs: list[FuncDef] = []
        self.loops: list[LoopSite] = []
        self._stack: list[str] = ["<module>"]
        self._loop_depth = 0
        self._loop_counter = 0

    # -- function / class definitions (A-2 candidates) ---------------------
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._record_def(node, "function")

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._record_def(node, "function")

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._record_def(node, "class")

    def _record_def(self, node: Any, kind: str) -> None:
        inner_calls: list[str] = []
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                nm = _dotted_name(sub.func)
                if nm:
                    inner_calls.append(nm)
        try:
            seg = ast.get_source_segment(self.source, node) or ""
        except Exception:  # pragma: no cover - malformed coordinates
            seg = ""
        self.defs.append(
            FuncDef(
                name=node.name,
                lineno=node.lineno,
                source=seg,
                kind=kind,
                calls=tuple(inner_calls),
            )
        )
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    # -- library calls (A-1) -------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        nm = _dotted_name(node.func)
        if nm is not None:
            tail = nm.rsplit(".", 1)[-1]
            if nm in self.known or tail in self.known_tails:
                self.calls.append(
                    CallSite(
                        call_name=nm,
                        lineno=node.lineno,
                        enclosing=self._stack[-1],
                    )
                )
        self.generic_visit(node)

    # -- loops (prior-work loop offloading input) ---------------------------
    def visit_For(self, node: ast.For) -> None:
        self._record_loop(node, "for")

    def visit_While(self, node: ast.While) -> None:
        self._record_loop(node, "while")

    def _record_loop(self, node: Any, kind: str) -> None:
        self.loops.append(
            LoopSite(
                loop_id=self._loop_counter,
                lineno=node.lineno,
                enclosing=self._stack[-1],
                kind=kind,
                depth=self._loop_depth,
                body_len=len(node.body),
            )
        )
        self._loop_counter += 1
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1


def analyze_source(source: str, known_libraries: set[str]) -> SourceReport:
    """Run Step-1 analysis over a source string."""
    source = textwrap.dedent(source)
    tree = ast.parse(source)
    az = _Analyzer(source, known_libraries)
    az.visit(tree)
    return SourceReport(
        library_calls=az.calls, func_defs=az.defs, loops=az.loops, source=source
    )


def analyze_callable(fn: Callable[..., Any], known_libraries: set[str]) -> SourceReport:
    """Step-1 analysis for a live Python callable (reads its source)."""
    return analyze_source(inspect.getsource(fn), known_libraries)


def analyze_module_of(fn: Callable[..., Any], known_libraries: set[str]) -> SourceReport:
    """Step-1 analysis over the whole module defining ``fn`` — matches the
    paper, which analyses the full application source, not one function."""
    mod = inspect.getmodule(fn)
    return analyze_source(inspect.getsource(mod), known_libraries)
