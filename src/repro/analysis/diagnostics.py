"""Typed diagnostics shared by every repro.analysis pass.

A :class:`Diagnostic` is one finding of one pass about one program (or one
block binding).  Reports aggregate diagnostics, serialize to JSON for the
lint CLI, and diff against a checked-in *baseline* file so CI fails only on
**new** violations — the same ratchet discipline as a type-checker baseline.

Severities:

* ``error``   — a contract violation (page aliasing, double write): always
  actionable, never baselined silently.
* ``warning`` — a hot-path hazard (host sync in the decode loop, retrace
  drift, constant-capture bloat): participates in ``--fail-on-new``.
* ``info``    — environment-dependent facts (a pallas binding illegal on
  this host's backend): recorded for the planner, exempt from the baseline
  ratchet because they flip between CPU CI and TPU production hosts.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Iterable, Mapping

SEVERITIES = ("info", "warning", "error")

#: Severities the baseline ratchet tracks (``info`` is host-dependent).
RATCHET_SEVERITIES = ("warning", "error")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: ``pass_name`` flagged ``subject`` inside ``program``."""

    pass_name: str  # "legality" | "hotpath" | "paging" | "resources"
    code: str  # machine-readable rule id, e.g. "host-sync"
    severity: str  # "info" | "warning" | "error"
    program: str  # traced program / zoo cell / engine program name
    subject: str  # block binding, output index, slot/page — the *what*
    message: str  # human-readable explanation
    platform: str = ""  # host backend / envelope the finding was made on

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity '{self.severity}'")

    @property
    def fingerprint(self) -> str:
        """Stable identity used for baseline matching.  Deliberately
        excludes ``message`` (rewording an explanation shouldn't churn the
        baseline file) and ``platform`` (the same finding on a CPU CI host
        and a TPU production host must ratchet as one entry — host facts
        are normalized out of the checked-in baseline)."""
        return f"{self.pass_name}:{self.code}:{self.program}:{self.subject}"

    def to_dict(self) -> dict[str, str]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Diagnostic":
        return cls(
            pass_name=d["pass_name"],
            code=d["code"],
            severity=d["severity"],
            program=d["program"],
            subject=d["subject"],
            message=d.get("message", ""),
            platform=d.get("platform", ""),
        )

    def __str__(self) -> str:
        plat = f" [{self.platform}]" if self.platform else ""
        return (
            f"{self.severity}[{self.pass_name}/{self.code}] "
            f"{self.program} :: {self.subject}{plat} — {self.message}"
        )


@dataclasses.dataclass
class AnalysisReport:
    """Aggregated diagnostics from one or more passes."""

    diagnostics: list[Diagnostic] = dataclasses.field(default_factory=list)

    def extend(self, diags: Iterable[Diagnostic]) -> None:
        self.diagnostics.extend(diags)

    def by_severity(self, severity: str) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == severity]

    def by_pass(self, pass_name: str) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.pass_name == pass_name]

    def counts(self) -> dict[str, int]:
        out = {s: 0 for s in SEVERITIES}
        for d in self.diagnostics:
            out[d.severity] += 1
        return out

    def ratchet_fingerprints(self) -> set[str]:
        """Fingerprints of the diagnostics the baseline ratchet tracks."""
        return {
            d.fingerprint
            for d in self.diagnostics
            if d.severity in RATCHET_SEVERITIES
        }

    def new_versus(self, baseline: "Baseline") -> list[Diagnostic]:
        """Ratchet-tracked diagnostics not present in the baseline —
        the set ``--fail-on-new`` fails on."""
        known = baseline.fingerprints
        return sorted(
            (
                d
                for d in self.diagnostics
                if d.severity in RATCHET_SEVERITIES
                and d.fingerprint not in known
            ),
            key=lambda d: d.fingerprint,
        )

    def to_dict(self) -> dict[str, Any]:
        return {
            "counts": self.counts(),
            "diagnostics": [
                d.to_dict()
                for d in sorted(
                    self.diagnostics, key=lambda d: d.fingerprint
                )
            ],
        }


@dataclasses.dataclass
class Baseline:
    """The checked-in set of accepted diagnostic fingerprints."""

    fingerprints: set[str] = dataclasses.field(default_factory=set)

    SCHEMA = 1

    @classmethod
    def load(cls, path: str | Path) -> "Baseline":
        path = Path(path)
        if not path.exists():
            return cls()
        data = json.loads(path.read_text())
        return cls(fingerprints=set(data.get("fingerprints", [])))

    def save(self, path: str | Path, report: AnalysisReport) -> None:
        """Rewrite the baseline from a report (``--update-baseline``)."""
        payload = {
            "schema": self.SCHEMA,
            "note": (
                "Accepted repro.analysis diagnostics; regenerate with "
                "`python -m repro.analysis.lint --update-baseline`."
            ),
            "fingerprints": sorted(report.ratchet_fingerprints()),
        }
        Path(path).write_text(json.dumps(payload, indent=2) + "\n")
