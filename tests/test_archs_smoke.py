"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and finite values (assignment requirement)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.launch.steps import TrainHyper, make_train_step
from repro.models import lm
from repro.optim.adamw import AdamW

B, S = 2, 32


def _batch(cfg, rng):
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.frontend == "patch_embed":
        return {
            "embeds": jnp.asarray(
                rng.standard_normal((B, S, cfg.d_model)), jnp.bfloat16
            ),
            "labels": labels,
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": labels,
    }


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finiteness(arch, rng):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, seed=0)
    batch = _batch(cfg, rng)
    logits, aux, _ = lm.forward(params, batch, cfg, mode="train")
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    opt = AdamW(moment_dtype=cfg.opt_dtype)
    step = jax.jit(make_train_step(cfg, opt, TrainHyper(total_steps=10)))
    params = lm.init_params(cfg, seed=0)
    opt_state = opt.init(params)
    batch = _batch(cfg, rng)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(opt_state2.step) == 1
    # parameters actually moved (warmup LR is tiny: check exact inequality)
    moved = any(
        not np.array_equal(np.asarray(b, np.float32), np.asarray(a, np.float32))
        for b, a in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved


@pytest.mark.parametrize(
    "arch", ["llama3.2-1b", "deepseek-v2-236b", "mamba2-2.7b", "zamba2-7b"]
)
def test_prefill_decode_consistency(arch, rng):
    """Decode against the cache must agree with full-sequence forward."""
    cfg = dataclasses.replace(
        get_config(arch).reduced(), compute_dtype="float32", remat="none"
    )
    params = lm.init_params(cfg, seed=0)
    s, maxlen = 16, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s + 1)), jnp.int32)
    logits_full, _, _ = lm.forward(params, {"tokens": toks}, cfg, mode="train")
    cache = lm.init_cache(cfg, B, maxlen)
    logits_pre, cache = lm.prefill(params, {"tokens": toks[:, :s]}, cfg, cache)
    logits_dec, cache = lm.decode_step(params, toks[:, s : s + 1], cfg, cache)
    tol = 5e-2 if cfg.moe else 1e-3  # MoE capacity drops differ with S
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, s]),
        rtol=tol, atol=tol,
    )
    assert np.all(np.asarray(cache["index"]) == s + 1)  # per-slot positions


def test_musicgen_vocab_is_encodec_sized():
    cfg = get_config("musicgen-large")
    assert cfg.vocab_size == 2048


def test_param_counts_match_billing():
    # sanity: computed param counts are in the advertised ballpark
    expect = {
        "arctic-480b": (430e9, 520e9),
        "deepseek-v2-236b": (210e9, 260e9),
        "command-r-35b": (28e9, 40e9),
        "mamba2-2.7b": (2.4e9, 3.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
