"""Serving-engine tests: continuous batching, streaming, sampling, plans.

All on the reduced llama config (non-MoE: MoE capacity drops depend on
batch composition, which would make cross-batch parity checks meaningless).
"""

import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.planner import Plan, PlanStore
from repro.core.planner.store import environment_fingerprint
from repro.serve import (
    Completion,
    Request,
    Sampler,
    ServeEngine,
    Token,
)

CFG = get_config("llama3.2-1b").reduced()


def _prompt(rng, n):
    return rng.integers(0, CFG.vocab_size, n).tolist()


def _engine(**kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("seed", 0)
    return ServeEngine(CFG, **kw)


# -- scheduling ---------------------------------------------------------------


def test_slot_admission_eviction_staggered(rng):
    """More requests than slots, staggered lengths: every request completes,
    freed slots are reused mid-flight, concurrency never exceeds n_slots."""
    engine = _engine(n_slots=2)
    lengths = [(5, 6), (9, 3), (4, 8), (7, 2), (6, 5)]
    ids = [
        engine.submit(Request(_prompt(rng, p), max_new_tokens=g))
        for p, g in lengths
    ]
    completions = engine.run_until_idle(max_steps=500)
    assert sorted(c.request_id for c in completions) == ids
    for (plen, gen), rid in zip(lengths, ids):
        c = engine.completions[rid]
        assert len(c.tokens) == gen
        assert c.finish_reason == "length"
        assert len(c.prompt) == plen
    stats = engine.stats
    assert stats.requests_completed == 5
    assert stats.max_active <= 2
    # the continuous-batching signature: served > n_slots requests in one
    # lifetime, so at least one slot was reused after an eviction
    assert stats.slot_reuses >= 3
    assert stats.decode_steps > 0


def test_scheduler_token_budget_defers_admissions(rng):
    """A tight token budget admits the queue gradually instead of
    prefilling everything into the first step — but never deadlocks."""
    engine = _engine(n_slots=4, max_tokens_per_step=12)
    for _ in range(4):
        engine.submit(Request(_prompt(rng, 10), max_new_tokens=3))
    first = engine.step()
    admitted_first = sum(
        1 for e in first if isinstance(e, Token) and e.phase == "prefill"
    )
    assert admitted_first == 1  # 10 prompt tokens: a second admission > 12
    engine.run_until_idle(max_steps=200)
    assert engine.stats.requests_completed == 4


def test_token_budget_charges_bucket_padded_prefill_cost(rng):
    """The budget bounds the tokens the prefill *program* runs, which with
    bucketing is the padded length, not the nominal prompt length."""
    engine = _engine(
        n_slots=4, max_tokens_per_step=20, prefill_bucket=16
    )
    for _ in range(3):
        engine.submit(Request(_prompt(rng, 10), max_new_tokens=2))
    first = engine.step()
    admitted = sum(
        1 for e in first if isinstance(e, Token) and e.phase == "prefill"
    )
    assert admitted == 1  # padded cost 16; a second padded 16 busts 20
    engine.run_until_idle(max_steps=100)
    assert engine.stats.requests_completed == 3


def test_max_active_counts_same_step_finishers(rng):
    """Requests that finish inside the step they were admitted still count
    toward peak concurrency."""
    engine = _engine(n_slots=2)
    engine.submit(Request(_prompt(rng, 4), max_new_tokens=1))
    engine.submit(Request(_prompt(rng, 5), max_new_tokens=1))
    engine.run_until_idle(max_steps=20)
    assert engine.stats.max_active == 2


def test_submit_rejects_oversized_request(rng):
    engine = _engine(max_len=16)
    with pytest.raises(ValueError, match="cache positions"):
        engine.submit(Request(_prompt(rng, 10), max_new_tokens=10))


# -- streaming ----------------------------------------------------------------


def test_streaming_token_order(rng):
    """Events stream in generation order: per request, token indices are
    0..n-1, token 0 comes from prefill, the rest from decode, and the
    Completion event arrives after its final token with the same ids."""
    engine = _engine(n_slots=2)
    reqs = [Request(_prompt(rng, 4 + i), max_new_tokens=3 + i)
            for i in range(3)]
    events = list(engine.stream(reqs))
    by_request: dict[int, list] = {}
    for event in events:
        by_request.setdefault(event.request_id, []).append(event)
    assert len(by_request) == 3
    for rid, evs in by_request.items():
        *tokens, completion = evs
        assert isinstance(completion, Completion)
        assert [t.index for t in tokens] == list(range(len(tokens)))
        assert tokens[0].phase == "prefill"
        assert all(t.phase == "decode" for t in tokens[1:])
        assert tuple(t.token_id for t in tokens) == completion.tokens
        assert completion.ttft <= completion.latency


# -- sampling -----------------------------------------------------------------


def test_sampler_determinism_under_fixed_seed(rng):
    """A request's sample path depends only on (seed, token index): the
    same request replayed in a different batch composition — different
    slot, different neighbours — yields the identical token sequence."""
    prompt = _prompt(rng, 6)
    req = lambda: Request(
        prompt, max_new_tokens=8,
        sampling=Sampler.with_temperature(0.8), seed=1234,
    )
    solo = _engine(n_slots=1)
    solo.submit(req())
    tokens_alone = solo.run_until_idle(max_steps=100)[0].tokens

    crowded = _engine(n_slots=3)
    filler = [Request(_prompt(rng, 9), max_new_tokens=4,
                      sampling=Sampler.with_top_k(20, 1.1))
              for _ in range(2)]
    crowded.submit(filler[0])
    crowded.submit(filler[1])
    rid = crowded.submit(req())
    crowded.run_until_idle(max_steps=200)
    assert crowded.completions[rid].tokens == tokens_alone


def test_sampler_policies_differ_and_validate():
    logits_seedless = Request((1, 2, 3), sampling=Sampler.greedy())
    assert logits_seedless.sampling.knobs == (0.0, 0)
    assert Sampler.with_temperature(0.7).knobs == (0.7, 0)
    assert Sampler.with_top_k(40, 0.8).knobs == (0.8, 40)
    assert Sampler.parse("top_k:40:0.8") == Sampler.with_top_k(40, 0.8)
    with pytest.raises(ValueError, match="sampler spec"):
        Sampler.parse("temperature")  # truncated spec: no bare IndexError
    with pytest.raises(ValueError, match="sampler spec"):
        Sampler.parse("top_k")
    with pytest.raises(ValueError):
        Sampler.with_temperature(0.0)
    with pytest.raises(ValueError):
        Sampler("top_k", temperature=1.0, top_k=0)
    with pytest.raises(ValueError):
        Sampler("nucleus")


def test_greedy_continuous_batching_matches_isolated_decode(rng):
    """Numerical integrity of the slot-managed cache: a greedy request
    decoded while other requests churn through neighbouring slots emits
    exactly the tokens it emits on an otherwise-empty engine."""
    cfg = dataclasses.replace(CFG, compute_dtype="float32", remat="none")
    prompt = _prompt(rng, 7)
    alone = ServeEngine(cfg, n_slots=1, max_len=64, seed=0)
    alone.submit(Request(prompt, max_new_tokens=10))
    expected = alone.run_until_idle(max_steps=100)[0].tokens

    busy = ServeEngine(cfg, n_slots=3, max_len=64, seed=0)
    busy.submit(Request(_prompt(rng, 3), max_new_tokens=2))
    busy.submit(Request(_prompt(rng, 11), max_new_tokens=6))
    rid = busy.submit(Request(prompt, max_new_tokens=10))
    busy.submit(Request(_prompt(rng, 5), max_new_tokens=9))  # reuses a slot
    busy.run_until_idle(max_steps=300)
    assert busy.completions[rid].tokens == expected
    assert busy.stats.slot_reuses >= 1


def test_prefill_bucketing_preserves_outputs(rng):
    """Bucket-padded prefill shares traces across prompt lengths without
    changing any output: padded KV rows are overwritten before the decode
    mask ever admits them."""
    cfg = dataclasses.replace(CFG, compute_dtype="float32", remat="none")
    prompts = [_prompt(rng, n) for n in (5, 7, 11)]

    def tokens_of(engine):
        ids = [engine.submit(Request(p, max_new_tokens=6)) for p in prompts]
        engine.run_until_idle(max_steps=200)
        return [engine.completions[i].tokens for i in ids]

    exact = tokens_of(ServeEngine(cfg, n_slots=2, max_len=64, seed=0))
    bucketed_engine = ServeEngine(
        cfg, n_slots=2, max_len=64, seed=0, prefill_bucket=8
    )
    assert tokens_of(bucketed_engine) == exact

    with pytest.raises(ValueError, match="SSM"):
        ServeEngine(
            get_config("mamba2-2.7b").reduced(), prefill_bucket=8
        )


# -- plan-aware phase dispatch -------------------------------------------------


def _store_with_zoo_plans(tmp_path, mapping):
    store = PlanStore(tmp_path)
    for kind in ("prefill", "decode"):
        store.save(Plan(
            key=f"zoo:llama3.2-1b:{kind}", space="sig",
            mapping=dict(mapping), pattern=tuple(mapping),
            baseline_seconds=1.0, best_seconds=0.5, speedup=2.0,
            strategy="exhaustive", evaluations=2, search_seconds=0.1,
            fingerprint=environment_fingerprint(), created_unix=0.0,
        ))
    return store


def test_plan_bound_phases_match_default_binding_outputs(rng, tmp_path):
    """With a zoo store present the engine binds each phase to its
    committed plan (both keys resolve, mappings attach) and — the paper's
    verify contract — the bound pattern reproduces the default-binding
    outputs."""
    cfg = dataclasses.replace(CFG, compute_dtype="float32", remat="none")
    _store_with_zoo_plans(tmp_path, {"rmsnorm": "ref", "attention": "ref"})
    prompts = [_prompt(rng, n) for n in (5, 9)]

    def run(**kw):
        engine = ServeEngine(cfg, n_slots=2, max_len=64, seed=0, **kw)
        ids = [engine.submit(Request(p, max_new_tokens=5)) for p in prompts]
        engine.run_until_idle(max_steps=200)
        return engine, [engine.completions[i].tokens for i in ids]

    default_engine, default_tokens = run()
    bound_engine, bound_tokens = run(plan_dir=str(tmp_path))

    assert default_engine.plan_keys == {"prefill": None, "decode": None}
    assert bound_engine.plan_keys == {
        "prefill": "zoo:llama3.2-1b:prefill",
        "decode": "zoo:llama3.2-1b:decode",
    }
    assert bound_engine._bindings["decode"] == {
        "rmsnorm": "ref", "attention": "ref"
    }
    assert bound_tokens == default_tokens


def test_explicit_plan_key_binds_both_phases(tmp_path, rng):
    store = PlanStore(tmp_path)
    store.save(Plan(
        key="custom:both", space="sig", mapping={"rmsnorm": "ref"},
        pattern=("rmsnorm",), baseline_seconds=1.0, best_seconds=0.5,
        speedup=2.0, strategy="exhaustive", evaluations=2,
        search_seconds=0.1, fingerprint=environment_fingerprint(),
        created_unix=0.0,
    ))
    engine = _engine(plan_dir=str(tmp_path), plan_keys="custom:both")
    assert engine.plan_keys == {
        "prefill": "custom:both", "decode": "custom:both"
    }
    assert engine._bindings["prefill"] == {"rmsnorm": "ref"}
    engine.submit(Request(_prompt(rng, 4), max_new_tokens=2))
    assert engine.run_until_idle(max_steps=50)[0].tokens


def test_explicit_plan_key_fails_loudly(tmp_path, rng):
    """A key the caller *named* must bind or raise — never silently fall
    back to default bindings (the resolve_meter contract); store-derived
    defaults still degrade quietly."""
    with pytest.raises(ValueError, match="not.*found/compatible"):
        _engine(plan_dir=str(tmp_path), plan_keys="zoo:llama3.2-1b:typo")
    with pytest.raises(ValueError, match="without plan_dir"):
        _engine(plan_keys="zoo:llama3.2-1b:prefill")


def test_reset_stats_zeroes_counters_only_when_idle(rng):
    engine = _engine()
    engine.submit(Request(_prompt(rng, 4), max_new_tokens=2))
    with pytest.raises(RuntimeError, match="busy"):
        engine.reset_stats()
    engine.run_until_idle(max_steps=50)
    assert engine.stats.requests_completed == 1
    engine.reset_stats()
    stats = engine.stats
    assert stats.requests_completed == 0
    assert stats.requests_submitted == 0
    assert stats.steps == 0
    assert stats.slot_reuses == 0
    assert engine.telemetry["decode"].calls == 0
    assert engine.monitor.steps == 0
    # the engine still serves after a reset (programs/cache untouched)
    engine.submit(Request(_prompt(rng, 4), max_new_tokens=2))
    assert len(engine.run_until_idle(max_steps=50)) == 1


def test_missing_plan_degrades_to_default_bindings(tmp_path, rng):
    """An empty store (or an incompatible plan) must serve, not crash."""
    engine = _engine(plan_dir=str(tmp_path))
    assert engine.plan_keys == {"prefill": None, "decode": None}
    engine.submit(Request(_prompt(rng, 4), max_new_tokens=2))
    assert len(engine.run_until_idle(max_steps=50)) == 1


# -- telemetry -----------------------------------------------------------------


def test_phase_telemetry_provenance_fields(rng):
    """Per-phase telemetry carries seconds/joules/provenance: a meter
    stamps its provenance, no meter means timing only."""
    metered = _engine(meter="psutil")
    metered.submit(Request(_prompt(rng, 5), max_new_tokens=4))
    metered.run_until_idle(max_steps=50)
    for phase in ("prefill", "decode"):
        tele = metered.telemetry[phase]
        assert tele.calls > 0
        assert tele.seconds > 0
        assert tele.tokens > 0
        assert tele.joules is not None and tele.joules > 0
        assert tele.provenance == "estimated"  # psutil is a model
        assert tele.joules_per_token > 0
        assert phase in tele.summary() and "J/tok" in tele.summary()

    unmetered = _engine()
    unmetered.submit(Request(_prompt(rng, 5), max_new_tokens=4))
    unmetered.run_until_idle(max_steps=50)
    tele = unmetered.telemetry["decode"]
    assert tele.seconds > 0 and tele.joules is None
    assert tele.provenance is None

    assert metered.monitor.steps > 0  # StepMonitor hooked into decode


def test_tpu_meter_degrades_cleanly_off_tpu():
    from repro.metering import METER_PROBE_ORDER, TpuMeter, resolve_meter

    names = [n for n, _ in METER_PROBE_ORDER]
    # the ROADMAP item: TPU telemetry probes ahead of the CPU models
    assert names.index("tpu") < names.index("rapl")
    assert names.index("tpu") < names.index("psutil")
    assert TpuMeter.provenance == "measured"
    if not TpuMeter.available():  # this container: no libtpu telemetry
        with pytest.raises(RuntimeError):
            TpuMeter()
        with pytest.raises(RuntimeError):
            resolve_meter("tpu")
