"""End-to-end offload engine (Steps 1-3) on the paper's applications."""

import numpy as np
import pytest

from repro.core import OffloadEngine, Policy
from repro.apps import fourier, matrix


@pytest.fixture(scope="module")
def engine():
    return OffloadEngine()


def test_fft_libcall_discovery_and_adapt(engine):
    x = fourier.make_input(64)
    res = engine.adapt(fourier.fourier_app_libcall, (x,), repeats=1)
    assert res.offload_pattern == ("fft2d",)
    assert res.numerics_ok
    assert res.verification.best.speedup > 1.0
    kinds = {d.kind for d in res.discoveries}
    assert "libcall" in kinds
    # the adapted app computes the right answer
    out = res.fn(x)
    np.testing.assert_allclose(
        np.asarray(out), np.fft.fft2(x), rtol=1e-3, atol=1e-2
    )


def test_fft_copied_code_discovery(engine):
    x = fourier.make_input(64)
    res = engine.adapt(fourier.fourier_app_copied, (x,), repeats=1)
    assert res.offload_pattern == ("fft2d",)
    assert res.discoveries[0].kind == "similar"
    assert res.discoveries[0].source_name == "my_fft2d"
    assert res.numerics_ok


def test_lu_libcall_adapt(engine):
    a = matrix.make_input(96)
    res = engine.adapt(matrix.matrix_app_libcall, (a,), repeats=1)
    assert res.offload_pattern == ("lu",)
    assert res.numerics_ok
    # determinant of an orthogonal matrix is +-1
    assert abs(abs(float(res.fn(a))) - 1.0) < 1e-2


def test_lu_copied_adapt(engine):
    a = matrix.make_input(96)
    res = engine.adapt(matrix.matrix_app_copied, (a,), repeats=1)
    assert res.offload_pattern == ("lu",)
    assert res.discoveries[0].kind == "similar"


def test_search_reports_baseline_and_trials(engine):
    x = fourier.make_input(32)
    res = engine.adapt(fourier.fourier_app_libcall, (x,), repeats=1)
    v = res.verification
    assert v.baseline_seconds > 0
    patterns = {t.pattern for t in v.trials}
    assert () in patterns  # baseline measured
    assert ("fft2d",) in patterns  # candidate measured alone
    assert v.search_seconds < 60  # "minutes, not hours" (paper headline)


def test_unrelated_code_not_discovered(engine):
    rep = engine.analyze(fourier.fourier_app_libcall)
    disc = engine.discover(rep, entry_fn="unrelated_helper")
    assert disc == []
