"""MeasurementCache — shared, thread-safe memoisation of measured trials.

On real hardware every trial is a compile+run (hours per FPGA candidate in
the paper), so no strategy may re-measure a pattern another strategy — or an
earlier generation — already visited.  Entries are keyed by the space
signature plus the canonical (order-independent) pattern, and keep the
compile-time / runtime split from ``verify.measure`` so search-time curves
(paper Fig. 4) stay reconstructable — ``records()`` returns them in
measurement order for ``repro.metering.report.search_trace``.

The *timed work* itself is delegated to a pluggable
``repro.metering.executors.MeasurementExecutor``: the default
``SerialExecutor`` reproduces the historical one-after-another behaviour,
``DeviceParallelExecutor`` measures independent candidates concurrently
(one per ``jax.device``), and ``BatchedExecutor`` fuses short variants into
one timed window.  ``measure_many`` is the bulk path strategies feed whole
GA generations / combine rounds through; ``measure`` is the single-trial
convenience over it.

Thread safety: record mutation and hit/miss accounting are guarded by one
lock, and an in-flight map prevents two threads from measuring the same key
concurrently (the second waits and replays the first's measurement as a
hit) — required once ``DeviceParallelExecutor`` drives the cache from
worker threads.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Sequence

from repro.core import verify
from repro.core.planner.space import Candidate, SearchSpace


@dataclasses.dataclass
class CacheRecord:
    key: tuple
    measurement: verify.Measurement
    hits: int = 0
    seq: int = 0  # insertion order (search-trace reconstruction)


def args_fingerprint(args: Sequence[Any]) -> tuple:
    """Cheap structural identity of a measured workload's arguments.

    Arrays are keyed by shape+dtype (not contents — re-hashing a 2048^2
    input per lookup would dwarf short measurements), scalars by value.
    Together with the space signature (which carries the builder tag) this
    keeps one application's timings from answering for another's.
    """
    parts = []
    for a in args:
        shape = getattr(a, "shape", None)
        if shape is not None:
            parts.append(("array", tuple(shape), str(getattr(a, "dtype", ""))))
        elif isinstance(a, (bool, int, float, str, bytes, type(None))):
            # type name included: 1, 1.0 and True hash/compare equal in
            # Python but can select different computation paths
            parts.append(("value", type(a).__name__, a))
        else:
            parts.append(("object", type(a).__name__))
    return tuple(parts)


class MeasurementCache:
    def __init__(
        self, meter: Any = None, executor: Any = None, metrics: Any = None
    ) -> None:
        """``meter``: optional ``objectives.PowerMeter`` whose begin/end
        hooks bracket every new measurement; the joules it reports are
        stored on the measurement (and replayed on cache hits) so
        energy-aware objectives can rank trials.  Attach the meter for the
        cache's whole lifetime: entries measured before a meter existed
        replay ``energy_joules=None``, which energy-aware objectives score
        with their time-proportional fallback — mixing metered and
        estimated joules in one ranking (each measurement's
        ``energy_provenance`` marks which it was).

        ``executor``: optional ``repro.metering`` executor (instance or
        name) that runs the timed work; defaults to serial measurement.

        ``metrics``: optional ``repro.obs.MetricsRegistry`` — hit/miss
        accounting writes through to ``planner_cache_{hits,misses}_total``
        (same increment that feeds ``self.hits``/``self.misses``, so the
        exported counters can never drift from the legacy fields).
        """
        self._data: dict[tuple, CacheRecord] = {}
        self.meter = meter
        self._executor = None
        if executor is not None:
            self.executor = executor
        # counters must exist before the hits/misses property setters run
        self._hits_c = self._misses_c = None
        if metrics is not None:
            self._hits_c = metrics.counter(
                "planner_cache_hits_total",
                "measurements replayed from the shared cache",
            )
            self._misses_c = metrics.counter(
                "planner_cache_misses_total",
                "measurements actually taken (compile+run trials)",
            )
        self.hits = 0
        self.misses = 0
        self._seq = 0
        self._lock = threading.Lock()
        self._inflight: dict[tuple, threading.Event] = {}

    # hit/miss accounting: plain-looking counters whose setters forward
    # positive deltas to the registry, so every `self.hits += 1` site —
    # present and future — feeds the exported metric automatically
    @property
    def hits(self) -> int:
        return self._hits

    @hits.setter
    def hits(self, value: int) -> None:
        delta = value - getattr(self, "_hits", 0)
        if delta > 0 and self._hits_c is not None:
            self._hits_c.inc(delta)
        self._hits = value

    @property
    def misses(self) -> int:
        return self._misses

    @misses.setter
    def misses(self, value: int) -> None:
        delta = value - getattr(self, "_misses", 0)
        if delta > 0 and self._misses_c is not None:
            self._misses_c.inc(delta)
        self._misses = value

    @property
    def executor(self) -> Any:
        """The configured executor, or None for the serial default."""
        return self._executor

    @executor.setter
    def executor(self, value: Any) -> None:
        if value is None:
            self._executor = None
            return
        from repro.metering.executors import resolve_executor

        self._executor = resolve_executor(value)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def key_for(
        self, space: SearchSpace, cand: Candidate, args: Sequence[Any] = ()
    ) -> tuple:
        return (space.signature(), args_fingerprint(args), space.canonical(cand))

    def lookup(
        self, space: SearchSpace, cand: Candidate, args: Sequence[Any] = ()
    ) -> verify.Measurement | None:
        with self._lock:
            rec = self._data.get(self.key_for(space, cand, args))
            return None if rec is None else rec.measurement

    def records(self) -> list[CacheRecord]:
        """All records in measurement (insertion) order — the raw material
        for search-trace reconstruction (paper Fig. 4)."""
        with self._lock:
            return sorted(self._data.values(), key=lambda r: r.seq)

    def measure(
        self,
        space: SearchSpace,
        cand: Candidate,
        args: Sequence[Any],
        repeats: int = 3,
        min_seconds: float = 0.0,
        warmup: int = 1,
    ) -> tuple[verify.Measurement, bool]:
        """Measure a candidate, or return the cached measurement.

        Returns ``(measurement, cached)`` where ``cached`` is True when no
        new measurement was taken.  A hit replays the stored measurement
        regardless of ``repeats``/``min_seconds`` — the first measurement
        of a pattern wins.
        """
        return self.measure_many(
            space,
            [cand],
            args,
            repeats=repeats,
            min_seconds=min_seconds,
            warmup=warmup,
        )[0]

    def measure_many(
        self,
        space: SearchSpace,
        cands: Sequence[Candidate],
        args: Sequence[Any],
        repeats: int = 3,
        min_seconds: float = 0.0,
        warmup: int = 1,
    ) -> list[tuple[verify.Measurement, bool]]:
        """Bulk path: measure every candidate not already cached, handing
        the whole miss set to the executor at once so independent trials
        can run concurrently (or fused).  Returns ``(measurement, cached)``
        per candidate, in input order; duplicate candidates within one call
        are measured once.
        """
        from repro.metering.executors import MeasureJob, SerialExecutor

        executor = self._executor
        if executor is None:
            executor = SerialExecutor()
        cands = list(cands)
        results: list[tuple[verify.Measurement, bool] | None] = [None] * len(
            cands
        )
        keys = [self.key_for(space, cand, args) for cand in cands]

        while True:
            to_measure: dict[tuple, Candidate] = {}
            primary: dict[tuple, int] = {}  # key -> index that measures it
            wait_for: list[threading.Event] = []
            with self._lock:
                for i, (key, cand) in enumerate(zip(keys, cands)):
                    if results[i] is not None:
                        continue
                    rec = self._data.get(key)
                    if rec is not None:
                        rec.hits += 1
                        self.hits += 1
                        results[i] = (rec.measurement, True)
                    elif key in to_measure:
                        # duplicate within this batch: measured once by its
                        # first occurrence, replayed below as a hit
                        pass
                    elif key in self._inflight:
                        # another thread is measuring this key right now;
                        # wait for its record instead of re-measuring
                        wait_for.append(self._inflight[key])
                    else:
                        to_measure[key] = cand
                        primary[key] = i
                        self._inflight[key] = threading.Event()

            if to_measure:
                miss_keys = list(to_measure)
                try:
                    jobs = [
                        MeasureJob(
                            fn=space.build(to_measure[key]),
                            args=args,
                            repeats=repeats,
                            min_seconds=min_seconds,
                            warmup=warmup,
                            space=space,
                            candidate=to_measure[key],
                        )
                        for key in miss_keys
                    ]
                    measured = executor.run(jobs, meter=self.meter)
                    if len(measured) != len(jobs):
                        raise RuntimeError(
                            f"executor {type(executor).__name__} returned "
                            f"{len(measured)} measurements for {len(jobs)} "
                            "jobs; executors must return one Measurement "
                            "per job, in order"
                        )
                except BaseException:
                    # release the in-flight claims so waiting threads can
                    # take over the measurement instead of deadlocking
                    with self._lock:
                        for key in miss_keys:
                            ev = self._inflight.pop(key, None)
                            if ev is not None:
                                ev.set()
                    raise
                with self._lock:
                    for key, m in zip(miss_keys, measured):
                        self._data[key] = CacheRecord(
                            key, m, seq=self._seq
                        )
                        self._seq += 1
                        self.misses += 1
                        results[primary[key]] = (m, False)
                        ev = self._inflight.pop(key, None)
                        if ev is not None:
                            ev.set()

            for ev in wait_for:
                # bounded wait: re-classification below retries (and takes
                # the measurement over) if the other thread failed or is
                # still running
                ev.wait(timeout=60.0)

            with self._lock:
                for i, key in enumerate(keys):
                    if results[i] is not None:
                        continue
                    rec = self._data.get(key)
                    if rec is not None:
                        # in-batch duplicate or another thread's record:
                        # replayed, so it counts as a hit
                        rec.hits += 1
                        self.hits += 1
                        results[i] = (rec.measurement, True)
                done = all(r is not None for r in results)
            if done:
                return [r for r in results if r is not None]

    @property
    def evaluations(self) -> int:
        """Number of actually-measured (non-cached) trials so far."""
        return self.misses
