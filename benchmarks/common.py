"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import sys
import time
from typing import Any, Callable, Sequence


def time_call(fn: Callable[..., Any], args: Sequence[Any], repeats: int = 3,
              warmup: int = 1) -> float:
    """Median seconds per call (device-blocking)."""
    def block(x):
        if hasattr(x, "block_until_ready"):
            x.block_until_ready()
        elif isinstance(x, (tuple, list)):
            for e in x:
                block(e)

    for _ in range(warmup):
        block(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        block(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds*1e6:.1f},{derived}", flush=True)


def emit_header() -> None:
    print("name,us_per_call,derived", flush=True)
