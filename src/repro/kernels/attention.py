"""Flash attention (forward) — VMEM-tiled online-softmax fused attention.

Grid (batch, q_head, q_blocks, kv_blocks), kv innermost so the running
(max, denom, acc) state stays in VMEM scratch across the kv sweep.  GQA is
handled in the BlockSpec index maps: the k/v block index uses
``q_head // group`` so no head replication is materialised in HBM.

Causal masking is applied inside the kernel with iota comparisons; fully
masked kv blocks skip their compute (the DMA still runs — block skipping via
a sparsity map is a §Perf follow-up, not needed for correctness).

Baseline block sizes 128x128: q/k/v/acc tiles at head_dim 128 are 64 KiB
each in f32 — comfortably double-buffered in ~16 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, block_q: int, block_kv: int, kv_steps: int
):
    j = pl.program_id(3)
    i = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # With causal masking, blocks strictly above the diagonal contribute
    # nothing: skip their FLOPs.
    needed = (not causal) or (j * block_kv <= (i + 1) * block_q - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bkv, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            q_idx = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0
            )
            kv_idx = j * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1
            )
            s = jnp.where(q_idx >= kv_idx, s, _NEG_INF)
        m_prev = m_ref[...]  # (bq, 128) broadcast lanes
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)  # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)  # (bq, 128)
        p = jnp.exp(s - m_new[:, :1])  # (bq, bkv)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 128)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j == kv_steps - 1)
    def _flush():
        denom = l_ref[...][:, :1]
        denom = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_kv", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KH, Skv, D)
    v: jax.Array,  # (B, KH, Skv, D)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kh, skv, _ = k.shape
    dv = v.shape[-1]
    if h % kh:
        raise ValueError(f"q heads {h} not a multiple of kv heads {kh}")
    group = h // kh
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    if sq % bq or skv % bkv:
        raise ValueError("sequence lengths must tile by block sizes")
    grid = (b, h, sq // bq, skv // bkv)
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            causal=causal,
            block_q=bq,
            block_kv=bkv,
            kv_steps=grid[3],
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec(
                (1, 1, bkv, d), lambda b_, h_, i, j: (b_, h_ // group, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, bkv, dv), lambda b_, h_, i, j: (b_, h_ // group, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, dv), lambda b_, h_, i, j: (b_, h_, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
