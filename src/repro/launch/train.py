"""Training driver.

Wires together: config -> synthetic data pipeline -> jitted train step ->
fault-tolerant loop (async checkpoints, restart/replay, straggler monitor).
On this CPU container it runs reduced configs end-to-end (see
examples/train_lm.py); on hardware the same driver takes the production
mesh via --mesh.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 100 --batch 8 --seq 128

A previously verified offload plan (committed by an ``OffloadSession``,
e.g. the ``repro.offload.zoo`` sweep) can be bound at startup with
--plan-dir/--plan-key — the step is then traced under that block->target
pattern with zero search or re-measurement.  With ``--plan-dir`` alone the
stored ``zoo:<arch>:train`` plan (when present) binds automatically;
``--plan-search`` searches and commits a missing plan first (using
``--executor`` to parallelise the measurement), and ``--meter`` reports the
run's power telemetry with measured/estimated provenance.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.checkpoint.manager import CheckpointManager
from repro.launch.steps import TrainHyper, make_train_step
from repro.models import lm
from repro.optim.adamw import AdamW
from repro.runtime.fault import FaultTolerantLoop
from repro.runtime.monitor import StepMonitor


@dataclasses.dataclass
class TrainState:
    params: object
    opt_state: object


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.layers:
        cfg = dataclasses.replace(
            cfg, n_layers=args.layers,
            block_pattern=None if cfg.block_pattern is None
            else cfg.pattern()[: args.layers],
        )
    data = SyntheticLMData(
        vocab_size=cfg.vocab_size,
        seq_len=args.seq,
        global_batch=args.batch,
        seed=args.seed,
    )
    opt = AdamW(moment_dtype=cfg.opt_dtype)
    hyper = TrainHyper(
        base_lr=args.lr, warmup_steps=min(50, args.steps // 10 + 1),
        total_steps=args.steps, microbatch=args.microbatch,
    )
    step_fn = jax.jit(make_train_step(cfg, opt, hyper), donate_argnums=(0, 1))
    params = lm.init_params(cfg, seed=args.seed)
    opt_state = opt.init(params)
    return cfg, data, step_fn, params, opt_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--plan-dir", default=None,
                    help="PlanStore directory with verified offload plans")
    ap.add_argument("--plan-key", default=None,
                    help="plan to load and bind at startup (zero search); "
                         "defaults to the stored zoo:<arch>:train plan "
                         "when present")
    ap.add_argument("--plan-search", action="store_true",
                    help="search+commit a missing zoo:<arch>:train plan "
                         "before binding (verification-environment step)")
    ap.add_argument("--plan-targets", default="ref,xla",
                    help="targets --plan-search searches over "
                         "(add 'pallas' on TPU hosts)")
    ap.add_argument("--executor", default="serial",
                    help="measurement executor for --plan-search: serial | "
                         "device-parallel | batched")
    ap.add_argument("--meter", default="none",
                    help="power telemetry for the run (and --plan-search): "
                         "none | auto | time | nvml | rapl | psutil")
    args = ap.parse_args()

    from repro.metering import meter_window, resolve_meter

    if args.plan_dir and not args.plan_key:
        from repro.offload.zoo import launch_plan_keys

        args.plan_key = launch_plan_keys(
            args.plan_dir,
            args.arch,
            ("train",),
            search=args.plan_search,
            targets=tuple(args.plan_targets.split(",")),
            executor=args.executor,
            meter=args.meter,
        )["train"]
        if args.plan_key is None:
            # dir-without-key is a legitimate "bind defaults when present"
            # configuration now; don't let attach print noise about it
            args.plan_dir = None
    meter = resolve_meter(args.meter)

    cfg, data, step_fn, params, opt_state = build(args)
    print(f"arch={cfg.name} params={lm.pm.count_params(lm.build_metas(cfg))/1e6:.1f}M")

    monitor = StepMonitor()
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    state = {"params": params, "opt": opt_state}
    last_metrics = {}

    def one_step(state, batch, step):
        nonlocal last_metrics
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(state["params"], state["opt"], b)
        last_metrics = jax.device_get(metrics)
        if step % args.log_every == 0:
            print(
                f"step {step:5d} loss {float(last_metrics['loss']):.4f} "
                f"({monitor.median_step()*1e3:.0f} ms/step)",
                flush=True,
            )
        return {"params": params, "opt": opt_state}

    loop = FaultTolerantLoop(
        step_fn=one_step,
        batch_fn=data.batch_at,
        ckpt=ckpt,
        ckpt_every=args.ckpt_every,
        monitor=monitor,
    )
    from repro.offload import OffloadSession

    t0 = time.time()
    with OffloadSession.attach(args.plan_dir, args.plan_key):
        with meter_window(meter) as tele:
            result = loop.run(state, args.steps)
    dt = time.time() - t0
    tokens = args.steps * args.batch * args.seq
    print(
        f"done: {result.completed_steps} steps, {result.restarts} restarts, "
        f"final loss {float(last_metrics.get('loss', np.nan)):.4f}, "
        f"{tokens/dt:.0f} tok/s"
    )
    if meter is not None:
        print(f"power: train loop {tele.summary()}")


if __name__ == "__main__":
    main()
