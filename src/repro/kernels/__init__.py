"""Kernel shelf: Pallas TPU kernels (+ XLA formulations + jnp oracles).

This package is the TPU analogue of the paper's accelerated-library shelf
(cuFFT / cuBLAS / cuSOLVER / FPGA IP cores).  Importing it registers every
kernel as a FunctionBlock implementation so the offload engine can bind
ref/xla/pallas per deployment environment.
"""

import functools

from repro.analysis.legality import TargetConstraints
from repro.analysis.resources import ResourceHint
from repro.core import blocks
from repro.kernels import attention_xla, ops, ref  # noqa: F401


def _register_all() -> list[tuple[str, str, object]]:
    r = blocks.registry
    impls = [
        # matmul
        ("matmul", "ref", ref.matmul_ref, "jnp.dot oracle"),
        ("matmul", "xla", ref.matmul_ref, "XLA dot"),
        ("matmul", "pallas",
         functools.partial(ops.matmul, backend="pallas"),
         "blocked MXU matmul"),
        # attention
        ("attention", "ref", ref.attention_ref, "softmax einsum oracle"),
        ("attention", "xla", attention_xla.attention_chunked,
         "chunked online-softmax attention (memory-safe at long context)"),
        ("attention", "pallas",
         functools.partial(ops.flash_attention, backend="pallas"),
         "flash attention, VMEM-tiled"),
        # paged attention (the serving decode/extend hot loop)
        ("paged_attention", "xla",
         functools.partial(ops.paged_attention, backend="xla"),
         "rolled page-walk gather + dense masked softmax"),
        ("paged_attention", "pallas",
         functools.partial(ops.paged_attention, backend="pallas"),
         "fused page-walk flash attention (no gathered K/V view)"),
        # rmsnorm
        ("rmsnorm", "ref", ref.rmsnorm_ref, "jnp oracle"),
        ("rmsnorm", "xla", ref.rmsnorm_ref, "XLA rmsnorm"),
        ("rmsnorm", "pallas",
         functools.partial(ops.rmsnorm, backend="pallas"),
         "fused rmsnorm"),
        # ssd scan
        ("ssd_scan", "ref", functools.partial(ops.ssd_scan, backend="ref"),
         "sequential scan oracle"),
        ("ssd_scan", "xla", functools.partial(ops.ssd_scan, backend="xla"),
         "chunked SSD, XLA"),
        ("ssd_scan", "pallas",
         functools.partial(ops.ssd_scan, backend="pallas"),
         "chunked SSD, Pallas intra-chunk"),
        # fft2d
        ("fft2d", "xla", functools.partial(ops.fft2d, backend="xla"),
         "XLA native fft2"),
        ("fft2d", "pallas", functools.partial(ops.fft2d, backend="pallas"),
         "matmul-DFT on MXU"),
        # lu
        ("lu", "xla", functools.partial(ops.lu, backend="xla"),
         "blocked LU, XLA trailing update"),
        ("lu", "pallas", functools.partial(ops.lu, backend="pallas"),
         "blocked LU, Pallas schur update"),
    ]
    for block, target, fn, note in impls:
        r.register(block, target, fn, note)
    return [(block, target, fn) for block, target, fn, _ in impls]


_SHELF_IMPLS = _register_all()

#: Block names registered by this package — the fixed "kernel shelf".
SHELF_BLOCKS = tuple(sorted({block for block, _, _ in _SHELF_IMPLS}))

#: Every registered (block, target) pair — the coverage universe the
#: shelf-coverage lint checks BLOCK_LEGALITY / BLOCK_RESOURCES against.
SHELF_IMPL_PAIRS = tuple((block, target) for block, target, _ in _SHELF_IMPLS)

#: Registration-time hash of the shelf sources, stamped into the PlanStore
#: environment fingerprint so a kernel rewrite invalidates stored plans.
#: Snapshotted from the registration list itself.  Registration is now
#: idempotent and import-order independent: every shelf target (including
#: attention/xla, which historically ``repro.models.attention``
#: re-registered at import time) is registered here, once, from its own
#: kernel module — re-importing any module re-registers identical
#: callables, so live registry state matches this snapshot regardless of
#: which package was imported first.
SHELF_FINGERPRINT = blocks.implementations_fingerprint(_SHELF_IMPLS)


def _legality_metadata() -> dict[tuple[str, str], TargetConstraints]:
    """Static envelope of every shelf implementation, consumed by the
    ``repro.analysis.legality`` pre-filter (paper Step 1): ref/xla
    formulations lower on any backend; the Pallas kernels are compiled
    Mosaic (``interpret=False``) and only lower on TPU hosts, over the
    MXU-tileable float dtypes."""
    anywhere = TargetConstraints()
    pallas_f32 = TargetConstraints(
        requires_platform=("tpu",),
        dtypes=("float32", "bfloat16"),
        notes="compiled Mosaic kernel; interpret mode is test-only",
    )
    out: dict[tuple[str, str], TargetConstraints] = {}
    for block in ("matmul", "attention", "rmsnorm", "ssd_scan"):
        out[(block, "ref")] = anywhere
        out[(block, "xla")] = anywhere
        out[(block, "pallas")] = pallas_f32
    out[("paged_attention", "xla")] = anywhere
    out[("paged_attention", "pallas")] = TargetConstraints(
        requires_platform=("tpu",),
        dtypes=("float32", "bfloat16"),
        notes="fused page-walk Mosaic kernel; scalar-prefetch page table; "
              "interpret mode is the CPU-CI parity path",
    )
    out[("fft2d", "xla")] = anywhere
    out[("fft2d", "pallas")] = TargetConstraints(
        requires_platform=("tpu",),
        dtypes=("float32", "complex64"),
        notes="matmul-DFT stages on the MXU",
    )
    out[("lu", "xla")] = anywhere
    out[("lu", "pallas")] = TargetConstraints(
        requires_platform=("tpu",),
        dtypes=("float32",),
        notes="blocked LU; Schur update is a float32 Pallas kernel",
    )
    return out


#: (block, target) -> TargetConstraints for the whole shelf.
BLOCK_LEGALITY = _legality_metadata()


def _resource_metadata() -> dict[tuple[str, str], ResourceHint]:
    """Memory-envelope hints for every shelf implementation, consumed by
    the ``repro.analysis.resources`` fit pass (the paper's Step 5
    resource check).  ref/xla formulations add no working-set overhead
    beyond the traced program; the Pallas kernels declare the resident
    VMEM tile footprint their grids keep on-chip (checked against
    ``DeviceEnvelope.vmem_bytes``) plus any HBM scratch."""
    plain = ResourceHint()
    f32 = 4
    tile = 128
    out: dict[tuple[str, str], ResourceHint] = {}
    for block in ("matmul", "attention", "rmsnorm", "ssd_scan"):
        out[(block, "ref")] = plain
        out[(block, "xla")] = plain
    out[("matmul", "pallas")] = ResourceHint(
        vmem_tile_bytes=3 * tile * tile * f32,
        notes="A/B/acc tiles resident per grid step",
    )
    out[("attention", "pallas")] = ResourceHint(
        vmem_tile_bytes=5 * tile * tile * f32,
        notes="q tile + streamed k/v tiles + acc + running stats",
    )
    # xla paged target: the page walk materialises the gathered
    # (B, max_pages * page_size) K/V view — roughly one extra cache-sized
    # copy per K/V leaf live in the decode program
    out[("paged_attention", "xla")] = ResourceHint(
        memory_multiplier=1.5,
        notes="gathered per-slot K/V view materialised per decode step",
    )
    # fused kernel: NO gather multiplier — the working set is the q rows
    # plus one (page_size, head_dim) block per K/V operand plus the
    # online-softmax scratch, all VMEM-resident per grid step
    out[("paged_attention", "pallas")] = ResourceHint(
        vmem_tile_bytes=4 * tile * tile * f32,
        notes="q rows + one K/V page block per operand + acc/stats "
              "scratch; no gathered view",
    )
    out[("rmsnorm", "pallas")] = ResourceHint(
        vmem_tile_bytes=2 * tile * tile * f32,
        notes="row tile in + out; weight row rides along",
    )
    out[("ssd_scan", "pallas")] = ResourceHint(
        memory_multiplier=1.25,
        vmem_tile_bytes=4 * tile * tile * f32,
        notes="chunked SSD keeps inter-chunk carry states in HBM",
    )
    out[("fft2d", "xla")] = plain
    out[("fft2d", "pallas")] = ResourceHint(
        memory_multiplier=2.0,
        vmem_tile_bytes=4 * tile * tile * f32,
        notes="matmul-DFT materialises complex as split re/im planes",
    )
    out[("lu", "xla")] = plain
    out[("lu", "pallas")] = ResourceHint(
        vmem_tile_bytes=3 * tile * tile * f32,
        notes="panel + trailing-block tiles for the Schur update",
    )
    return out


#: (block, target) -> ResourceHint for the whole shelf.
BLOCK_RESOURCES = _resource_metadata()
