"""Flash attention kernel + chunked XLA attention vs naive oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.attention import flash_attention_pallas
from repro.models.attention import attention_chunked

CASES = [
    # (B, H, KH, S, D)
    (1, 4, 4, 128, 64),  # MHA
    (2, 8, 2, 256, 64),  # GQA
    (1, 4, 1, 128, 128),  # MQA
]


@pytest.mark.parametrize("b,h,kh,s,d", CASES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel_matches_oracle(b, h, kh, s, d, causal, rng):
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, kh, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, kh, s, d)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=causal, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4
    )


def test_flash_kernel_bf16(rng):
    b, h, kh, s, d = 1, 4, 2, 128, 64
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, kh, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, kh, s, d)), jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_flash_kernel_mla_dv_differs(rng):
    # MLA: qk dim 48, v dim 32
    b, h, s = 1, 4, 128
    q = jnp.asarray(rng.standard_normal((b, h, s, 48)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, 48)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, 32)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    assert out.shape == (b, h, s, 32)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("b,h,kh,s,d", CASES[:2])
def test_chunked_attention_grads_match_oracle(b, h, kh, s, d, rng):
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, kh, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, kh, s, d)), jnp.float32)

    def loss_ref(q, k, v):
        return jnp.sum(jnp.tanh(ref.attention_ref(q, k, v, causal=True)))

    def loss_chk(q, k, v):
        return jnp.sum(
            jnp.tanh(attention_chunked(q, k, v, causal=True, q_chunk=64,
                                       kv_chunk=64))
        )

    g1 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_chk, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b_), rtol=1e-3, atol=1e-4
        )


def test_chunked_attention_kv_prefix_alignment(rng):
    # prefill semantics: q shorter than kv, ends aligned
    q = jnp.asarray(rng.standard_normal((1, 4, 64, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 4, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 4, 128, 32)), jnp.float32)
    out = attention_chunked(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4
    )
