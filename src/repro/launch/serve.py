"""Serving driver: prefill a batch of prompts, then batched greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --prompt-len 64 --gen 32

Production startup loads a previously verified offload plan (committed by an
``OffloadSession`` in a verification environment — see
``repro.offload.zoo``) and binds it with zero re-measurement:

  ... --plan-dir results/plans --plan-key zoo:llama3.2-1b:prefill

With ``--plan-dir`` alone, the stored ``zoo:<arch>:prefill`` /
``zoo:<arch>:decode`` plans (when present) bind automatically — each phase
is traced under its own verified pattern.  ``--plan-search`` searches and
commits missing zoo plans first (using ``--executor`` to parallelise the
measurement), and ``--meter`` reports the run's real power telemetry with
measured/estimated provenance.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.metering import meter_window, resolve_meter
from repro.models import lm
from repro.offload import OffloadSession
from repro.offload import load_plan_bindings  # noqa: F401 — deprecated re-export


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-dir", default=None,
                    help="PlanStore directory with verified offload plans")
    ap.add_argument("--plan-key", default=None,
                    help="plan to load and bind at startup (zero search); "
                         "defaults to the stored zoo:<arch>:prefill and "
                         "zoo:<arch>:decode plans when present")
    ap.add_argument("--plan-search", action="store_true",
                    help="search+commit missing zoo plans for this arch "
                         "before binding (verification-environment step)")
    ap.add_argument("--plan-targets", default="ref,xla",
                    help="targets --plan-search searches over "
                         "(add 'pallas' on TPU hosts)")
    ap.add_argument("--executor", default="serial",
                    help="measurement executor for --plan-search: serial | "
                         "device-parallel | batched")
    ap.add_argument("--meter", default="none",
                    help="power telemetry for the run (and --plan-search): "
                         "none | auto | time | nvml | rapl | psutil")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = lm.init_params(cfg, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.gen
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )

    if args.plan_key:
        # an explicit key binds both phases; a key without a dir flows into
        # attach, which warns that both are required — never silently drop
        # an explicitly requested plan
        keys = {"prefill": args.plan_key, "decode": args.plan_key}
    else:
        from repro.offload.zoo import launch_plan_keys

        keys = launch_plan_keys(
            args.plan_dir,
            args.arch,
            ("prefill", "decode"),
            search=args.plan_search,
            targets=tuple(args.plan_targets.split(",")),
            executor=args.executor,
            meter=args.meter,
        )
    meter = resolve_meter(args.meter)

    cache = lm.init_cache(cfg, args.batch, max_len)
    # a plan dir whose store has no plan for a phase runs that phase on
    # default bindings, silently (attach treats dir-without-key as noise);
    # a key without a dir keeps the dir=None so attach warns about it
    prefill_dir = args.plan_dir if keys["prefill"] else None
    decode_dir = args.plan_dir if keys["decode"] else None
    with OffloadSession.attach(prefill_dir, keys["prefill"]):
        prefill = jax.jit(lambda p, b, c: lm.prefill(p, b, cfg, c))
        t0 = time.time()
        with meter_window(meter) as tele_prefill:
            logits, cache = prefill(params, {"tokens": prompts}, cache)
            logits.block_until_ready()
        t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None].astype(
        jnp.int32
    )
    out_tokens = [tok]
    with OffloadSession.attach(decode_dir, keys["decode"]):
        decode = jax.jit(lambda p, t, c: lm.decode_step(p, t, cfg, c))
        t0 = time.time()
        with meter_window(meter) as tele_decode:
            for _ in range(args.gen - 1):
                logits, cache = decode(params, tok, cache)
                tok = jnp.argmax(
                    logits[:, 0, :cfg.vocab_size], axis=-1
                )[:, None].astype(jnp.int32)
                out_tokens.append(tok)
            tok.block_until_ready()
        t_dec = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {args.prompt_len} toks in {t_prefill*1e3:.1f} ms")
    print(
        f"decode: {args.gen-1} steps in {t_dec*1e3:.1f} ms "
        f"({(args.gen-1)*args.batch/max(t_dec,1e-9):.1f} tok/s)"
    )
    if meter is not None:
        print(f"power: prefill {tele_prefill.summary()}")
        print(f"power: decode {tele_decode.summary()}")
    print("sample:", np.asarray(gen[0, :16]))


if __name__ == "__main__":
    main()
