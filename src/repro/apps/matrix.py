"""Matrix-calculation application (paper §5.1.1).

Naive CPU port of the *Numerical Recipes in C* ``ludcmp`` routine: Crout LU
decomposition with implicit row scaling and partial pivoting, in pure Python
loops.  The paper's verification workload is LU decomposition of a 2048x2048
orthogonal matrix, auto-replaced by cuSOLVER; here the replacement is the
blocked MXU LU in ``repro.kernels``.

Offload paths exercised by the engine:
  * A-1/B-1: ``matrix_app_libcall`` calls ``ludcmp_nr`` by name.
  * A-2/B-2: ``matrix_app_copied`` carries a local modified clone.
  * loop-GA baseline: ``LU_STAGES`` / ``build_lu_variant``.
"""

from __future__ import annotations

import numpy as np


def ludcmp_nr(a):
    """Crout LU with implicit scaling + partial pivoting (NR ``ludcmp``).

    Returns (lu, indx, d): packed LU in one matrix, pivot rows, row-swap
    parity d = +-1.
    """
    a = np.array(a, dtype=np.float64)
    n = a.shape[0]
    indx = np.zeros(n, dtype=np.int64)
    d = 1.0
    vv = np.zeros(n, dtype=np.float64)
    for i in range(n):
        big = 0.0
        for j in range(n):
            temp = abs(a[i, j])
            if temp > big:
                big = temp
        if big == 0.0:
            raise ValueError("singular matrix in ludcmp")
        vv[i] = 1.0 / big
    for j in range(n):
        for i in range(j):
            s = a[i, j]
            for k in range(i):
                s -= a[i, k] * a[k, j]
            a[i, j] = s
        big = 0.0
        imax = j
        for i in range(j, n):
            s = a[i, j]
            for k in range(j):
                s -= a[i, k] * a[k, j]
            a[i, j] = s
            dum = vv[i] * abs(s)
            if dum >= big:
                big = dum
                imax = i
        if j != imax:
            for k in range(n):
                a[imax, k], a[j, k] = a[j, k], a[imax, k]
            d = -d
            vv[imax] = vv[j]
        indx[j] = imax
        if a[j, j] == 0.0:
            a[j, j] = 1.0e-20
        if j != n - 1:
            dum = 1.0 / a[j, j]
            for i in range(j + 1, n):
                a[i, j] *= dum
    return a, indx, d


REFERENCE_CODE = '''
def ludcmp(a):
    a = np.array(a, dtype=np.float64)
    n = a.shape[0]
    indx = np.zeros(n, dtype=np.int64)
    d = 1.0
    vv = np.zeros(n, dtype=np.float64)
    for i in range(n):
        big = 0.0
        for j in range(n):
            temp = abs(a[i, j])
            if temp > big:
                big = temp
        if big == 0.0:
            raise ValueError("singular matrix")
        vv[i] = 1.0 / big
    for j in range(n):
        for i in range(j):
            s = a[i, j]
            for k in range(i):
                s -= a[i, k] * a[k, j]
            a[i, j] = s
        big = 0.0
        imax = j
        for i in range(j, n):
            s = a[i, j]
            for k in range(j):
                s -= a[i, k] * a[k, j]
            a[i, j] = s
            dum = vv[i] * abs(s)
            if dum >= big:
                big = dum
                imax = i
        if j != imax:
            for k in range(n):
                a[imax, k], a[j, k] = a[j, k], a[imax, k]
            d = -d
            vv[imax] = vv[j]
        indx[j] = imax
        if a[j, j] == 0.0:
            a[j, j] = 1.0e-20
        if j != n - 1:
            dum = 1.0 / a[j, j]
            for i in range(j + 1, n):
                a[i, j] *= dum
    return a, indx, d
'''


def matrix_app_libcall(a):
    """The application: factorize, then determinant from the diagonal.

    The determinant is invariant to the pivoting strategy, so it is the
    app-level output verified after substitution (NR uses *scaled* partial
    pivoting; the accelerated blocked LU uses plain partial pivoting — their
    packed LU matrices legitimately differ, the determinant must not).
    """
    lu, indx, d = ludcmp_nr(a)
    det = float(d)
    for i in range(lu.shape[0]):
        det *= float(lu[i, i])
    return det


# --- copied-code flavour (A-2/B-2) -------------------------------------------


def my_ludcmp(mat):
    # borrowed textbook factorisation, adapted for our project
    mat = np.array(mat, dtype=np.float64)
    size = mat.shape[0]
    pivots = np.zeros(size, dtype=np.int64)
    parity = 1.0
    scale = np.zeros(size, dtype=np.float64)
    for r in range(size):
        largest = 0.0
        for c in range(size):
            mag = abs(mat[r, c])
            if mag > largest:
                largest = mag
        if largest == 0.0:
            raise ValueError("matrix is singular")
        scale[r] = 1.0 / largest
    for c in range(size):
        for r in range(c):
            acc = mat[r, c]
            for k in range(r):
                acc -= mat[r, k] * mat[k, c]
            mat[r, c] = acc
        largest = 0.0
        best_row = c
        for r in range(c, size):
            acc = mat[r, c]
            for k in range(c):
                acc -= mat[r, k] * mat[k, c]
            mat[r, c] = acc
            gauge = scale[r] * abs(acc)
            if gauge >= largest:
                largest = gauge
                best_row = r
        if c != best_row:
            for k in range(size):
                mat[best_row, k], mat[c, k] = mat[c, k], mat[best_row, k]
            parity = -parity
            scale[best_row] = scale[c]
        pivots[c] = best_row
        if mat[c, c] == 0.0:
            mat[c, c] = 1.0e-20
        if c != size - 1:
            inv = 1.0 / mat[c, c]
            for r in range(c + 1, size):
                mat[r, c] *= inv
    return mat, pivots, parity


def matrix_app_copied(a):
    lu, pivots, parity = my_ludcmp(a)
    det = float(parity)
    for i in range(lu.shape[0]):
        det *= float(lu[i, i])
    return det


# --- staged decomposition for the loop-offload GA baseline -------------------


def _naive_rowscale(a):
    a = np.asarray(a, dtype=np.float64)
    n = a.shape[0]
    vv = np.zeros(n, dtype=np.float64)
    for i in range(n):
        big = 0.0
        for j in range(n):
            t = abs(a[i, j])
            if t > big:
                big = t
        vv[i] = 1.0 / big
    return (a, vv)


def _dev_rowscale(a):
    import jax.numpy as jnp

    vv = 1.0 / jnp.max(jnp.abs(a), axis=1)
    return (a, vv)


def _naive_factor(state):
    a, vv = state
    a = np.array(a, dtype=np.float64)
    vv = np.array(vv, dtype=np.float64)
    n = a.shape[0]
    indx = np.zeros(n, dtype=np.int64)
    d = 1.0
    for j in range(n):
        for i in range(j):
            s = a[i, j]
            for k in range(i):
                s -= a[i, k] * a[k, j]
            a[i, j] = s
        big = 0.0
        imax = j
        for i in range(j, n):
            s = a[i, j]
            for k in range(j):
                s -= a[i, k] * a[k, j]
            a[i, j] = s
            dum = vv[i] * abs(s)
            if dum >= big:
                big = dum
                imax = i
        if j != imax:
            for k in range(n):
                a[imax, k], a[j, k] = a[j, k], a[imax, k]
            d = -d
            vv[imax] = vv[j]
        indx[j] = imax
        if a[j, j] == 0.0:
            a[j, j] = 1.0e-20
        if j != n - 1:
            dum = 1.0 / a[j, j]
            for i in range(j + 1, n):
                a[i, j] *= dum
    return (a, indx, np.float64(d))


def _dev_factor(state):
    """Unblocked right-looking LU on device (the 'offload the loop nest'
    variant): row-vectorised, scaled partial pivoting, lax.fori_loop over
    columns.  Algorithmically the paper's loop offload — same algorithm as
    the CPU code, just executed on the accelerator."""
    import jax
    import jax.numpy as jnp

    a, vv = state
    a = a.astype(jnp.float64) if a.dtype == jnp.float64 else a
    n = a.shape[0]
    ii = jnp.arange(n)

    def body(j, carry):
        a, vv, indx, d = carry
        score = jnp.where(ii >= j, vv * jnp.abs(a[:, j]), -jnp.inf)
        # NR keeps the *last* maximal row (>= comparison)
        imax = (n - 1) - jnp.argmax(score[::-1])
        rowj = a[j]
        rowi = a[imax]
        a = a.at[j].set(rowi).at[imax].set(rowj)
        vvj = vv[j]
        vvi = vv[imax]
        vv = vv.at[imax].set(vvj).at[j].set(vvi)
        d = jnp.where(imax != j, -d, d)
        indx = indx.at[j].set(imax)
        piv = a[j, j]
        piv = jnp.where(piv == 0.0, 1.0e-20, piv)
        a = a.at[j, j].set(piv)
        fac = jnp.where(ii > j, a[:, j] / piv, 0.0)
        cols = jnp.where(ii > j, a[j], 0.0)  # only trailing columns update
        a = a - jnp.outer(fac, cols)
        a = a.at[:, j].set(jnp.where(ii > j, fac, a[:, j]))
        return (a, vv, indx, d)

    indx0 = jnp.zeros(n, dtype=jnp.int64)
    a, vv, indx, d = jax.lax.fori_loop(
        0, n, body, (a, vv, indx0, jnp.asarray(1.0, a.dtype))
    )
    return (a, indx, d)


def _naive_det(state):
    lu, indx, d = state
    det = float(d)
    for i in range(lu.shape[0]):
        det *= lu[i, i]
    return np.float64(det)


def _dev_det(state):
    import jax.numpy as jnp

    lu, indx, d = state
    return jnp.prod(jnp.diagonal(lu)) * d


from repro.apps.common import Stage  # noqa: E402


LU_STAGES = (
    Stage("rowscale", _naive_rowscale, _dev_rowscale),
    Stage("factor", _naive_factor, _dev_factor),
    Stage("det", _naive_det, _dev_det),
)


def build_lu_variant(genome):
    from repro.apps.common import build_staged_variant

    return build_staged_variant(LU_STAGES, genome)


def make_input(n: int = 192, seed: int = 0):
    """Random orthogonal matrix (the paper uses a 2048^2 orthogonal input)."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    return q.astype(np.float64)
