"""Serving driver: prefill a batch of prompts, then batched greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --prompt-len 64 --gen 32

Production startup loads a previously verified offload plan (committed by an
``OffloadSession`` in a verification environment — see
``repro.offload.zoo``) and binds it with zero re-measurement:

  ... --plan-dir results/plans --plan-key zoo:llama3.2-1b:prefill
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.offload import OffloadSession
from repro.offload import load_plan_bindings  # noqa: F401 — deprecated re-export


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-dir", default=None,
                    help="PlanStore directory with verified offload plans")
    ap.add_argument("--plan-key", default=None,
                    help="plan to load and bind at startup (zero search)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = lm.init_params(cfg, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.gen
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )

    with OffloadSession.attach(args.plan_dir, args.plan_key):
        prefill = jax.jit(lambda p, b, c: lm.prefill(p, b, cfg, c))
        decode = jax.jit(lambda p, t, c: lm.decode_step(p, t, cfg, c))

        cache = lm.init_cache(cfg, args.batch, max_len)
        t0 = time.time()
        logits, cache = prefill(params, {"tokens": prompts}, cache)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None].astype(
            jnp.int32
        )
        out_tokens = [tok]
        t0 = time.time()
        for _ in range(args.gen - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(
                logits[:, 0, :cfg.vocab_size], axis=-1
            )[:, None].astype(jnp.int32)
            out_tokens.append(tok)
        tok.block_until_ready()
        t_dec = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {args.prompt_len} toks in {t_prefill*1e3:.1f} ms")
    print(
        f"decode: {args.gen-1} steps in {t_dec*1e3:.1f} ms "
        f"({(args.gen-1)*args.batch/max(t_dec,1e-9):.1f} tok/s)"
    )
    print("sample:", np.asarray(gen[0, :16]))


if __name__ == "__main__":
    main()
