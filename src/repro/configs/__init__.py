"""Architecture registry: the 10 assigned architectures + shape sets."""

from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES  # noqa: F401

_MODULES = {
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "command-r-35b": "repro.configs.command_r_35b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "arctic-480b": "repro.configs.arctic_480b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "musicgen-large": "repro.configs.musicgen_large",
    "zamba2-7b": "repro.configs.zamba2_7b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    key = name.replace("_", "-").lower()
    if key not in _MODULES:
        alt = {k.replace("-", "").replace(".", ""): k for k in _MODULES}
        key = alt.get(key.replace("-", "").replace(".", ""), key)
    if key not in _MODULES:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[key]).CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(include_long_for_quadratic: bool = False):
    """All (arch, shape) evaluation cells, honouring the long_500k skip rule
    for pure full-attention architectures."""
    out = []
    for a in ARCH_NAMES:
        cfg = get_config(a)
        for s in SHAPES.values():
            if s.name == "long_500k" and not (
                cfg.subquadratic or include_long_for_quadratic
            ):
                continue
            out.append((a, s.name))
    return out
