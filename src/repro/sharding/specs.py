"""Sharding rules: logical axis name -> mesh axes, per (arch, shape, mesh).

Parallelism map (baseline; §Perf hillclimbs adjust per cell):
  * batch          -> ("pod", "data")   DP across pods and the data axis
  * weight dim0    -> "data"            ZeRO-3/FSDP (all-gather on use)
  * heads/ffn/...  -> "model"           tensor parallelism
  * experts        -> "model"           expert parallelism (MoE)
  * act_seq        -> "data" only for batch=1 long-context (sequence
                      parallelism over the KV cache)

Rules drop a mesh axis automatically when the corresponding dimension is
not divisible (e.g. kv_heads=8 on a 16-way model axis stays replicated).
"""

from __future__ import annotations

from typing import Any

from repro.configs.base import ArchConfig, ShapeConfig

DEFAULT_RULES: dict[str, Any] = {
    # parameters
    "vocab": "model",
    "embed": "data",  # FSDP
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "expert_in": "data",   # FSDP-style: gathered on use (baseline)
    "expert_ffn": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "layers": None,
    # activations
    "act_batch": ("data",),
    "act_seq": None,
    "cache_seq": None,
    "heads_act": "model",
    "kv_heads_act": "model",
    "ffn_act": "model",
    "experts_act": "model",
    "ssm_inner_act": "model",
    "ssm_heads_act": "model",
}


def _axis_size(mesh_shape: dict[str, int], rule) -> int:
    if rule is None:
        return 1
    parts = (rule,) if isinstance(rule, str) else tuple(rule)
    n = 1
    for p in parts:
        n *= mesh_shape.get(p, 1)
    return n


def rules_for(
    cfg: ArchConfig,
    shape: ShapeConfig,
    mesh_shape: dict[str, int],
    fsdp: bool | None = None,
    ep_mode: str = "gather",
) -> dict[str, Any]:
    """Build the logical->mesh rules for one evaluation cell."""
    rules = dict(DEFAULT_RULES)
    multi_pod = "pod" in mesh_shape

    # batch: pod axis joins data-parallel batch sharding
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    if shape.global_batch % _axis_size(mesh_shape, batch_axes):
        batch_axes = ("data",) if shape.global_batch % mesh_shape.get(
            "data", 1
        ) == 0 else ()
    rules["act_batch"] = batch_axes or None

    # sequence parallelism for batch-1 long context
    if shape.global_batch == 1:
        rules["act_seq"] = ("pod", "data") if multi_pod else ("data",)

    # Megatron-style sequence parallelism for training: the residual stream
    # (and therefore the per-layer saved activation stacks, the dominant
    # memory term under remat) is sharded over "model" between blocks;
    # attention/FFN regions re-gather, GSPMD inserts the transitions.
    if shape.kind == "train" and shape.seq_len % mesh_shape.get("model", 1) == 0:
        rules["act_seq"] = "model"

    # KV caches shard their sequence axis (long decode contexts dwarf HBM
    # otherwise); conflicts with per-tensor axis reuse resolve gracefully
    if shape.kind in ("decode", "prefill"):
        rules["cache_seq"] = ("pod", "model") if multi_pod else ("model",)

    # FSDP: shard weight dim0 over data (and pod when multi-pod).  Default
    # on for training; for inference only when TP alone cannot fit params.
    if fsdp is None:
        tp = mesh_shape.get("model", 1)
        per_chip = cfg.param_count() * (2 if "16" in cfg.param_dtype else 4) / tp
        fsdp = shape.kind == "train" or per_chip > 8e9
    rules["embed"] = (("pod", "data") if multi_pod else "data") if fsdp else None

    # divisibility guards for model-axis sharding
    tp = mesh_shape.get("model", 1)
    if cfg.n_kv_heads and cfg.n_kv_heads % tp:
        rules["kv_heads_act"] = None
    if cfg.n_heads and cfg.n_heads % tp:
        rules["heads_act"] = None
    if cfg.ssm is not None:
        if cfg.ssm.n_heads(cfg.d_model) % tp:
            rules["ssm_heads_act"] = None
            rules["ssm_heads"] = None
    if cfg.moe is not None and cfg.moe.n_experts % tp:
        rules["experts_act"] = None
        rules["experts"] = None

    # expert-parallel mode: "gather" = expert weights FSDP'd over data and
    # all-gathered on use (baseline); "psum" = weights statically sharded
    # (E over model, expert-ffn over data), contractions produce partial
    # sums — activation psums replace weight gathers entirely.
    if ep_mode == "psum" and cfg.moe is not None:
        rules["expert_in"] = None
        rules["expert_ffn"] = "data"
    if not fsdp:
        rules["expert_in"] = None
    return rules
