"""Pluggable search objectives — what "best pattern" means.

The paper ranks candidate offload patterns by wall-seconds; the follow-up
power-saving work (arXiv:2110.11520) ranks by performance-per-watt.  Both
are instances of one protocol: an ``Objective`` maps a measured trial to a
scalar score where **lower is better**, and every ``SearchStrategy`` picks
winners via ``objective.score(trial)`` instead of hard-coding
``trial.seconds``.

Energy comes from a ``PowerMeter`` plugged into the ``MeasurementCache``:
a real deployment wires hardware counters into ``begin``/``end``, while
``TimeProportionalPower`` is the always-available fallback that charges a
constant device draw for the trial's runtime.  Trials measured without any
meter have ``energy_joules=None``; energy-aware objectives then fall back
to a time-proportional estimate at scoring time so they stay total orders
over any trial list.
"""

from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

#: Nominal board power charged by the time-proportional fallback.  The
#: absolute value only shifts energy scores by a constant factor — relative
#: ranking, which is all the search needs, is unaffected.
DEFAULT_DEVICE_WATTS = 170.0


# -- power metering -----------------------------------------------------------


class PowerMeter:
    """Energy measurement for one timed trial.

    ``begin()`` is called immediately before the candidate's timed window
    and ``end(measurement, space, candidate)`` immediately after; ``end``
    returns the estimated joules of **one** call (or None when the meter
    cannot produce a reading, e.g. counters unavailable).  Hardware meters
    sample RAPL / board telemetry between the two hooks; the base class is
    a null meter.

    ``provenance`` labels the readings this meter produces — ``"measured"``
    for hardware counters, ``"estimated"`` for modelled draw — and is
    stamped onto every ``Measurement`` so mixed rankings stay auditable.
    ``exclusive`` marks meters whose begin/end window reads a device-global
    counter: concurrent trials would be attributed each other's energy, so
    parallel executors serialise the metered sections of such meters.
    """

    provenance: str | None = None
    exclusive: bool = True

    def begin(self) -> None:  # pragma: no cover - trivial
        pass

    def end(
        self, measurement: Any, space: Any = None, candidate: Any = None
    ) -> float | None:
        return None


class TimeProportionalPower(PowerMeter):
    """Fallback meter: constant draw, so energy = runtime x watts.

    This is exact for a device whose power envelope does not depend on the
    pattern (then PerfPerWatt degenerates to latency) and is the documented
    stand-in until a counter-backed meter is registered.  Counter-backed
    meters (NVML / RAPL / psutil) live in ``repro.metering.meters`` behind
    ``metering.autodetect()``.
    """

    provenance = "estimated"
    # pure function of the trial's own measurement — safe under concurrency
    exclusive = False

    def __init__(self, watts: float = DEFAULT_DEVICE_WATTS) -> None:
        if watts <= 0:
            raise ValueError("watts must be positive")
        self.watts = watts

    def end(
        self, measurement: Any, space: Any = None, candidate: Any = None
    ) -> float | None:
        return measurement.seconds * self.watts


# -- objectives ---------------------------------------------------------------


@runtime_checkable
class Objective(Protocol):
    """Scores a ``PlanTrial``; lower is better.  ``name`` labels reports
    and persisted plans."""

    name: str

    def score(self, trial: Any) -> float: ...


class Latency:
    """The paper's objective: median wall-seconds per call."""

    name = "latency"

    def score(self, trial: Any) -> float:
        return trial.seconds


class PerfPerWatt:
    """Energy per unit of work (joules per call) — minimising it maximises
    performance-per-watt for a fixed workload (arXiv:2110.11520).

    Trials carrying a metered ``energy_joules`` use it directly; unmetered
    trials are charged ``seconds * fallback_watts`` (the time-proportional
    fallback), so mixed trial lists still rank consistently.
    """

    name = "perf_per_watt"

    def __init__(self, fallback_watts: float = DEFAULT_DEVICE_WATTS) -> None:
        self.fallback_watts = fallback_watts

    def score(self, trial: Any) -> float:
        energy = getattr(trial, "energy_joules", None)
        if energy is None:
            return trial.seconds * self.fallback_watts
        return energy


class WeightedCost:
    """Affine blend of latency and energy: ``wt*seconds + we*joules``.

    Covers deployment policies between the two extremes — e.g. "prefer the
    faster pattern unless it costs disproportionate power".
    """

    def __init__(
        self,
        time_weight: float = 1.0,
        energy_weight: float = 0.0,
        fallback_watts: float = DEFAULT_DEVICE_WATTS,
    ) -> None:
        self.time_weight = time_weight
        self.energy_weight = energy_weight
        self.fallback_watts = fallback_watts
        self.name = f"weighted(t={time_weight:g},e={energy_weight:g})"

    def score(self, trial: Any) -> float:
        energy = getattr(trial, "energy_joules", None)
        if energy is None:
            energy = trial.seconds * self.fallback_watts
        return self.time_weight * trial.seconds + self.energy_weight * energy


def resolve_objective(objective: "Objective | str | None") -> Objective:
    """Accept an Objective instance, a name, or None (-> Latency)."""
    if objective is None:
        return Latency()
    if isinstance(objective, str):
        named = {
            "latency": Latency,
            "seconds": Latency,
            "perf_per_watt": PerfPerWatt,
            "energy": PerfPerWatt,
        }
        if objective not in named:
            raise KeyError(
                f"unknown objective '{objective}'; known: {sorted(named)}"
            )
        return named[objective]()
    return objective
