"""Numerics of the manual-TP (shard_map) paths vs the GSPMD default.

Runs in a subprocess with 8 forced host devices so a real (data=2, model=4)
mesh exercises all_gather / psum_scatter.
"""

import pathlib
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import lm
from repro.models import params as pm
from repro.models import layers as lay
from repro.sharding.specs import rules_for
from repro.sharding.utils import use_sharding
from repro.configs.base import ShapeConfig

cfg = dataclasses.replace(
    get_config("llama3.2-1b").reduced(),
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
    vocab_size=512, compute_dtype="float32", remat="none",
)
mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = ShapeConfig("t", 16, 4, "train")
rules = rules_for(cfg, shape, {"data": 2, "model": 4})
rules["act_seq"] = "model"  # force SP so psum_scatter paths engage

params = lm.init_params(cfg, seed=0)
rng = np.random.default_rng(0)
batch = {
    "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32),
    "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32),
}

metas = lm.build_metas(cfg)
pspec = pm.spec_tree(metas, rules)
pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
params = jax.device_put(params, pshard)
bshard = {k: NamedSharding(mesh, P(("data",), None)) for k in batch}
batch = jax.device_put(batch, bshard)

def loss(p, b):
    return lm.loss_fn(p, b, cfg)[0]

outs = {}
for name, flags in (
    ("gspmd", (False, False)),
    ("manual", (True, True)),
):
    lay.BF16_TP_REDUCE, lay.MEGATRON_MLP = flags
    with use_sharding(mesh, rules):
        l = jax.jit(loss, in_shardings=(pshard, bshard))(params, batch)
        g = jax.jit(jax.grad(loss), in_shardings=(pshard, bshard))(params, batch)
    outs[name] = (float(l), jax.device_get(g))

l0, g0 = outs["gspmd"]
l1, g1 = outs["manual"]
assert abs(l0 - l1) < 1e-4, (l0, l1)
for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-3, atol=2e-3)
print("MANUAL_TP_OK", l0, l1)
"""


def test_manual_tp_matches_gspmd():
    root = pathlib.Path(__file__).resolve().parents[1]
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MANUAL_TP_OK" in out.stdout
