"""Code-Pattern DB: registration, lookup, persistence."""

import pytest

from repro.core import CodePatternDB, ReplacementEntry, default_db


def test_default_db_has_eval_targets():
    db = default_db()
    assert "fft2d" in db and "lu" in db
    # the paper's targets resolve to callables
    assert callable(db.get("fft2d").resolve())
    assert callable(db.get("lu").resolve())


def test_lookup_by_call_name_and_tail():
    db = default_db()
    assert db.lookup_by_call("fft2d_nr").name == "fft2d"
    assert db.lookup_by_call("np.fft.fft2").name == "fft2d"
    assert db.lookup_by_call("somelib.ludcmp").name == "lu"
    assert db.lookup_by_call("nonexistent_fn") is None


def test_roundtrip_json(tmp_path):
    db = default_db()
    p = tmp_path / "db.json"
    db.save(p)
    db2 = CodePatternDB.load(p)
    assert len(db2) == len(db)
    e1 = db.get("lu")
    e2 = db2.get("lu")
    assert e1.impl == e2.impl
    assert e1.interface == e2.interface
    assert e1.reference_code == e2.reference_code
    assert db2.lookup_by_call("ludcmp").name == "lu"


def test_register_custom_entry():
    db = CodePatternDB()
    db.register(
        ReplacementEntry(
            name="softmax",
            source_names=("softmax", "scipy.special.softmax"),
            impl="jax.nn:softmax",
        )
    )
    assert db.lookup_by_call("scipy.special.softmax").name == "softmax"
    fn = db.get("softmax").resolve()
    import numpy as np

    out = fn(np.zeros(4))
    assert abs(float(out.sum()) - 1.0) < 1e-6
