"""Loop-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
program built on ``lax.scan`` (layer stacking, chunked attention, grad
accumulation) under-reports FLOPs/bytes/collectives by the trip count.  This
module re-derives the three roofline inputs from the compiled HLO text:

  * flops            — dot/convolution FLOPs, x trip count inside while loops
  * hbm_bytes        — per top-level instruction: operands + result (a
                       fusion is one kernel: its internals don't touch HBM);
                       dynamic-(update-)slice counts the slice, not the
                       aliased buffer
  * collective_bytes — result bytes per collective kind, x trip count

Trip counts come from the loop condition computation (the largest integer
constant compared against the induction variable — exact for lax.scan /
fori_loop lowerings, which is everything this codebase emits).

All numbers are per device: the input is the post-SPMD partitioned module.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_ARRAY_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e\w+|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)"
    r"\[([\d,]*)\]"
)
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_INST_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:[^\s]+))\s+"
    r"([\w\-]+)\((.*)$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")


def shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = 0
    nbytes = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        b = _DTYPE_BYTES.get(dt, 2 if dt.startswith("f8") else 4)
        nbytes += n * b
    return elems, nbytes


@dataclasses.dataclass
class Inst:
    name: str
    type_str: str
    opcode: str
    rest: str  # operand list + attributes

    def operands(self) -> list[str]:
        # operand names up to the closing paren of the arg list
        depth = 1
        end = 0
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        argstr = self.rest[:end]
        return re.findall(r"%([\w.\-]+)", argstr)

    def attr(self, name: str) -> str | None:
        m = re.search(rf"{name}=([^,]+(?:\{{[^}}]*\}})?)", self.rest)
        return m.group(1) if m else None


@dataclasses.dataclass
class Computation:
    name: str
    insts: list[Inst]
    shapes: dict[str, str]  # var name -> type string


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            m = _HEADER_RE.match(line)
            if m and line.endswith("{"):
                cur = Computation(m.group(2), [], {})
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INST_RE.match(line)
        if m:
            inst = Inst(*m.groups())
            cur.insts.append(inst)
            cur.shapes[inst.name] = inst.type_str
    if cur is not None:
        comps[cur.name] = cur
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )

    def add(self, other: "Cost", scale: float = 1.0) -> None:
        self.flops += scale * other.flops
        self.hbm_bytes += scale * other.hbm_bytes
        for k, v in other.collectives.items():
            self.collectives[k] += scale * v


_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


class HloCostModel:
    def __init__(self, text: str) -> None:
        self.comps = parse_module(text)
        self._memo: dict[str, Cost] = {}
        # entry = the computation named main*, else the last one
        names = list(self.comps)
        entry_candidates = [n for n in names if n.startswith("main")]
        self.entry = entry_candidates[0] if entry_candidates else names[-1]

    # -- helpers ---------------------------------------------------------
    def _dot_flops(self, comp: Computation, inst: Inst) -> float:
        out_elems, _ = shape_elems_bytes(inst.type_str)
        ops = inst.operands()
        lhs_shape = comp.shapes.get(ops[0], "") if ops else ""
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.rest)
        k = 1
        dims_m = _ARRAY_RE.search(lhs_shape)
        if m and dims_m:
            dims = [int(d) for d in dims_m.group(2).split(",") if d]
            for ci in m.group(1).split(","):
                if ci:
                    ci = int(ci)
                    if ci < len(dims):
                        k *= dims[ci]
        return 2.0 * out_elems * k

    def _conv_flops(self, comp: Computation, inst: Inst) -> float:
        out_elems, _ = shape_elems_bytes(inst.type_str)
        ops = inst.operands()
        rhs_shape = comp.shapes.get(ops[1], "") if len(ops) > 1 else ""
        dims_m = _ARRAY_RE.search(rhs_shape)
        if not dims_m:
            return 0.0
        dims = [int(d) for d in dims_m.group(2).split(",") if d]
        if not dims:
            return 0.0
        # per-output work ~ kernel elems / output-feature dim (approx)
        kernel = 1
        for d in dims:
            kernel *= d
        return 2.0 * out_elems * max(kernel // max(dims[-1], 1), 1)

    def _trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        best = 1
        for inst in comp.insts:
            if inst.opcode == "constant":
                m = re.match(r"(\d+)", inst.rest)
                if m:
                    best = max(best, int(m.group(1)))
        return best

    def _operand_bytes(self, comp: Computation, inst: Inst) -> float:
        total = 0.0
        for op in inst.operands():
            ts = comp.shapes.get(op)
            if ts is not None:
                total += shape_elems_bytes(ts)[1]
        return total

    def _fusion_operand_bytes(
        self, comp: Computation, inst: Inst, callee: "Computation | None"
    ) -> float:
        """Call-site operand traffic for a fusion: an operand whose callee
        parameter is consumed only through (dynamic-)slice ops is read
        slice-by-slice, not in full."""
        names = inst.operands()
        if callee is None:
            return self._operand_bytes(comp, inst)
        # parameter index -> callee var name
        param_names: dict[int, str] = {}
        for ci in callee.insts:
            if ci.opcode == "parameter":
                m = re.match(r"(\d+)", ci.rest)
                if m:
                    param_names[int(m.group(1))] = ci.name
        total = 0.0
        for i, opname in enumerate(names):
            ts = comp.shapes.get(opname)
            if ts is None:
                continue
            full = shape_elems_bytes(ts)[1]
            pname = param_names.get(i)
            if pname is None:
                total += full
                continue
            consumers = [
                ci for ci in callee.insts if pname in ci.operands()
            ]
            if consumers and all(
                c.opcode in ("slice", "dynamic-slice") for c in consumers
            ):
                sliced = sum(
                    shape_elems_bytes(c.type_str)[1] for c in consumers
                )
                total += min(sliced, full)
            else:
                total += full
        return total

    def _callee_names(self, inst: Inst, attr: str) -> list[str]:
        m = re.search(rf"{attr}=%?([\w.\-]+)", inst.rest)
        return [m.group(1)] if m else []

    # -- main ---------------------------------------------------------------
    def cost_of(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # cycle guard
        comp = self.comps.get(name)
        if comp is None:
            return self._memo[name]
        total = Cost()
        for inst in comp.insts:
            op = inst.opcode
            if op in _NO_TRAFFIC:
                continue
            _, res_bytes = shape_elems_bytes(inst.type_str)
            if op == "while":
                body = self._callee_names(inst, "body")
                cond = self._callee_names(inst, "condition")
                # exact trip count from XLA's backend_config when present
                m = _TRIP_RE.search(inst.rest)
                if m:
                    trip = int(m.group(1))
                else:
                    trip = self._trip_count(cond[0]) if cond else 1
                if body:
                    total.add(self.cost_of(body[0]), scale=trip)
                continue
            if op == "fusion":
                callees = self._callee_names(inst, "calls")
                inner = self.cost_of(callees[0]) if callees else Cost()
                # a fusion is one kernel: HBM = call-site operands + result,
                # but flops/collectives of the body count fully
                total.flops += inner.flops
                for k, v in inner.collectives.items():
                    total.collectives[k] += v
                # root DUS fusions alias the big buffer: count update traffic
                root_dus = False
                if callees and self.comps.get(callees[0]):
                    root = self.comps[callees[0]].insts[-1]
                    root_dus = root.opcode == "dynamic-update-slice"
                if root_dus:
                    small = 0.0
                    for opn in inst.operands():
                        ts = comp.shapes.get(opn)
                        if ts and ts.split("{")[0] != inst.type_str.split("{")[0]:
                            small += shape_elems_bytes(ts)[1]
                    total.hbm_bytes += 2 * small
                else:
                    callee_comp = self.comps.get(callees[0]) if callees else None
                    total.hbm_bytes += res_bytes + self._fusion_operand_bytes(
                        comp, inst, callee_comp
                    )
                continue
            if op in ("call", "conditional", "async-start"):
                for cal in self._callee_names(inst, "to_apply") + self._callee_names(
                    inst, "calls"
                ):
                    total.add(self.cost_of(cal))
                total.hbm_bytes += res_bytes
                continue
            if op == "dot":
                total.flops += self._dot_flops(comp, inst)
                total.hbm_bytes += res_bytes + self._operand_bytes(comp, inst)
                continue
            if op == "convolution":
                total.flops += self._conv_flops(comp, inst)
                total.hbm_bytes += res_bytes + self._operand_bytes(comp, inst)
                continue
            if op in ("dynamic-slice", "slice"):
                # reads only the sliced region, not the whole operand
                total.hbm_bytes += 2 * res_bytes
                continue
            if op == "dynamic-update-slice":
                ops = inst.operands()
                upd = comp.shapes.get(ops[1], "") if len(ops) > 1 else ""
                total.hbm_bytes += 2 * shape_elems_bytes(upd)[1]
                continue
            matched = False
            for kind in _COLLECTIVES:
                if op == kind or op.startswith(kind + "-start"):
                    total.collectives[kind] += res_bytes
                    total.hbm_bytes += res_bytes + self._operand_bytes(comp, inst)
                    matched = True
                    break
                if op.startswith(kind + "-done"):
                    matched = True
                    break
            if matched:
                continue
            if op == "copy" or op.endswith("-done"):
                total.hbm_bytes += 2 * res_bytes
                continue
            # generic top-level op (unfused elementwise, reduce, ...)
            total.hbm_bytes += res_bytes + self._operand_bytes(comp, inst)
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        return self.cost_of(self.entry)


def analyze(text: str) -> dict:
    model = HloCostModel(text)
    c = model.entry_cost()
    return {
        "flops": c.flops,
        "hbm_bytes": c.hbm_bytes,
        "collectives": dict(c.collectives),
        "collective_bytes": sum(c.collectives.values()),
    }
