"""Pure-jnp oracles for every shelf kernel.

Each function is the semantic ground truth its Pallas kernel is tested
against (tests/test_kernels_*.py sweep shapes and dtypes with
``interpret=True`` and assert allclose against these).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.promote_types(a.dtype, b.dtype))


def schur_update_ref(c: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    return c - a @ b


def fft2d_ref(x: jax.Array) -> jax.Array:
    return jnp.fft.fft2(x).astype(jnp.complex64)


# "full": the whole norm in f32 (default).  "mixed": only the mean-square
# reduction runs in f32; the scale multiply stays in the input dtype, so no
# f32 (B,S,D) intermediate ever exists — sequence-parallel transitions and
# remat traffic then move bf16 tensors instead of f32 (a §Perf knob).
RMSNORM_PRECISION = "full"


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    if RMSNORM_PRECISION == "mixed" and x.dtype != jnp.float32:
        ms = jnp.mean(
            jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True
        )
        scale = jax.lax.rsqrt(ms + eps).astype(x.dtype)
        return x * scale * w.astype(x.dtype)
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(x.dtype)


def attention_ref(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KH, Skv, D)
    v: jax.Array,
    causal: bool = True,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kh, skv, _ = k.shape
    group = h // kh
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kq.astype(jnp.float32)
    ) / (d ** 0.5)
    if causal:
        qi = jnp.arange(sq)[:, None] + (skv - sq)  # align ends (kv prefix)
        ki = jnp.arange(skv)[None, :]
        s = jnp.where(qi >= ki, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32)).astype(q.dtype)


def lu_ref(a: jax.Array) -> tuple[jax.Array, jax.Array]:
    """LAPACK-style getrf oracle from jax.scipy."""
    import jax.scipy.linalg as jsl

    lu, piv = jsl.lu_factor(a)
    return lu, piv


def lu_reconstruct(lu: jax.Array, piv: jax.Array) -> jax.Array:
    """Rebuild P^-1 L U from a packed factorisation + NR/LAPACK pivots —
    the pivot-invariant way to verify an LU."""
    n = lu.shape[0]
    l = jnp.tril(lu, -1) + jnp.eye(n, dtype=lu.dtype)
    u = jnp.triu(lu)
    a = l @ u
    # undo row swaps in reverse order
    def body(t, m):
        j = n - 1 - t
        i = piv[j]
        rj = m[j]
        ri = m[i]
        return m.at[j].set(ri).at[i].set(rj)

    return jax.lax.fori_loop(0, n, body, a)


def ssd_ref(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    a: jax.Array,  # (H,) negative
    bmat: jax.Array,  # (B, S, N)
    cmat: jax.Array,  # (B, S, N)
    h0: jax.Array | None = None,  # (B, H, N, P)
) -> tuple[jax.Array, jax.Array]:
    """Sequential selective-scan oracle:
    h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t ;  y_t = C_t h_t.
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = bmat.astype(jnp.float32)
    cf = cmat.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)

    def step(hprev, t):
        x_t = xf[:, t]  # (B, H, P)
        dt_t = dtf[:, t]  # (B, H)
        b_t = bf[:, t]  # (B, N)
        c_t = cf[:, t]  # (B, N)
        decay = jnp.exp(af[None, :] * dt_t)  # (B, H)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt_t, b_t, x_t)
        hnew = hprev * decay[..., None, None] + upd
        y_t = jnp.einsum("bn,bhnp->bhp", c_t, hnew)
        return hnew, y_t

    hfinal, ys = jax.lax.scan(step, h0, jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1)  # (B, S, H, P)
    return y.astype(jnp.float32), hfinal
