"""Planner subsystem: spaces, strategies, shared cache, persistent plans.

Timing-sensitive tests drive sleep-based variants with >=5 ms gaps between
candidates so median-of-1 measurements rank them deterministically.
"""

import time
import warnings

import pytest

from repro.core import blocks, planner
from repro.core.blocks import FunctionBlockRegistry
from repro.core.planner import (
    BindingSpace,
    CostGuidedSearch,
    ExhaustiveSearch,
    GeneticSearch,
    MeasurementCache,
    Plan,
    Planner,
    PlanStore,
    SingleThenCombine,
    SubsetSpace,
)


def sleep_subset_space(costs, names):
    """SubsetSpace whose runtime is a deterministic function of the subset."""

    def build(subset):
        seconds = costs[frozenset(subset)]

        def fn(_x):
            time.sleep(seconds)
            return _x

        return fn

    return SubsetSpace(build, names)


COSTS3 = {
    frozenset(): 0.040,
    frozenset({"a"}): 0.025,
    frozenset({"b"}): 0.030,
    frozenset({"c"}): 0.050,
    frozenset({"a", "b"}): 0.012,
    frozenset({"a", "c"}): 0.030,
    frozenset({"b", "c"}): 0.035,
    frozenset({"a", "b", "c"}): 0.020,
}


# -- spaces -------------------------------------------------------------------


def test_subset_space_structure():
    sp = sleep_subset_space(COSTS3, ["a", "b", "c"])
    assert sp.size() == 8
    assert sp.baseline() == (0, 0, 0)
    assert sp.pattern((1, 0, 1)) == ("a", "c")
    assert sp.subset_of((0, 1, 0)) == frozenset({"b"})
    assert sp.candidate_from_subset(frozenset({"a", "c"})) == (1, 0, 1)
    # canonical keys are order-independent and distinct per pattern
    assert len({sp.canonical(c) for c in sp.enumerate()}) == 8


def test_binding_space_nary_axes_and_bind():
    reg = FunctionBlockRegistry()
    calls = []
    for target, delay in [("ref", 0.02), ("xla", 0.004), ("pallas", 0.012)]:
        def mk(t=target, d=delay):
            def impl(x):
                calls.append(t)
                time.sleep(d)
                return x

            return impl

        reg.register("norm", target, mk())

    space = BindingSpace(lambda: (lambda x: reg.call("norm", x)),
                         registry=reg)
    assert [a.name for a in space.axes] == ["norm"]
    # ref is the baseline (choice 0), generalising "not offloaded"
    assert space.axes[0].choices[0] == "ref"
    assert space.size() == 3

    cand = space.candidate_from_mapping({"norm": "pallas"})
    fn = space.build(cand)
    fn(1)
    assert calls[-1] == "pallas"
    assert space.binding_of(cand) == {"norm": "pallas"}


def test_binding_space_from_patterns_default_sentinel():
    reg = FunctionBlockRegistry()
    reg.register("m", "ref", lambda x: x)
    reg.register("m", "xla", lambda x: x)
    reg.register("n", "ref", lambda x: x)
    patterns = [{"m": "ref"}, {"m": "xla", "n": "ref"}]
    space = BindingSpace.from_patterns(
        lambda: (lambda x: x), patterns, registry=reg
    )
    # "n" is absent from the first pattern -> gets the default sentinel
    ax = {a.name: a for a in space.axes}
    assert ax["n"].choices[0] == planner.DEFAULT_TARGET
    cand = space.candidate_from_mapping(patterns[0])
    assert space.binding_of(cand) == {"m": "ref"}  # no binding for "n"


# -- strategies ---------------------------------------------------------------


def test_strategy_parity_with_brute_force():
    """On a small space, single-then-combine and the GA agree with the
    exhaustively measured optimum."""
    names = ["a", "b", "c"]
    brute = ExhaustiveSearch().search(
        sleep_subset_space(COSTS3, names), (0,),
        cache=MeasurementCache(), repeats=1,
    )
    assert brute.best.pattern == ("a", "b")

    stc = SingleThenCombine().search(
        sleep_subset_space(COSTS3, names), (0,),
        cache=MeasurementCache(), repeats=1,
    )
    assert stc.best.pattern == brute.best.pattern

    ga = GeneticSearch(population=6, generations=5, seed=0).search(
        sleep_subset_space(COSTS3, names), (0,),
        cache=MeasurementCache(), repeats=1,
    )
    assert ga.best.pattern == brute.best.pattern
    assert ga.generations is not None and len(ga.generations) == 5


def test_single_then_combine_measures_only_paper_trials():
    sp = sleep_subset_space(COSTS3, ["a", "b", "c"])
    cache = MeasurementCache()
    rep = SingleThenCombine().search(sp, (0,), cache=cache, repeats=1)
    # baseline + 3 singles + winning combination, nothing else
    assert {t.pattern for t in rep.trials} == {
        (), ("a",), ("b",), ("c",), ("a", "b")
    }
    assert rep.evaluations == 5 == cache.misses


def test_ga_nary_genome_on_binding_space():
    reg = FunctionBlockRegistry()
    for target, delay in [("ref", 0.02), ("xla", 0.004), ("pallas", 0.012)]:
        reg.register(
            "norm", target,
            (lambda d: lambda x: (time.sleep(d), x)[1])(delay),
        )
    space = BindingSpace(lambda: (lambda x: reg.call("norm", x)),
                         registry=reg)
    rep = GeneticSearch(population=3, generations=3, seed=0).search(
        space, (1,), cache=MeasurementCache(), repeats=1
    )
    assert rep.best.mapping == {"norm": "xla"}


def test_shared_cache_prevents_cross_strategy_remeasurement():
    names = ["a", "b", "c"]
    sp = sleep_subset_space(COSTS3, names)
    cache = MeasurementCache()
    SingleThenCombine().search(sp, (0,), cache=cache, repeats=1)
    assert cache.misses == 5 and cache.hits == 0

    # exhaustive sweep afterwards only measures the 3 unvisited patterns
    rep = ExhaustiveSearch().search(sp, (0,), cache=cache, repeats=1)
    assert rep.evaluations == 3
    assert cache.misses == 8
    assert cache.hits == 5  # baseline + 3 singles + combo replayed from cache
    cached_patterns = {t.pattern for t in rep.trials if t.cached}
    assert ("a", "b") in cached_patterns


def test_cost_guided_search_measures_only_top_k():
    sp = sleep_subset_space(COSTS3, ["a", "b", "c"])
    est = {c: COSTS3[frozenset(p)] for c, p in [
        ((1, 0, 0), {"a"}), ((0, 1, 0), {"b"}), ((0, 0, 1), {"c"}),
        ((1, 1, 0), {"a", "b"}), ((1, 0, 1), {"a", "c"}),
        ((0, 1, 1), {"b", "c"}), ((1, 1, 1), {"a", "b", "c"}),
    ]}
    cache = MeasurementCache()
    rep = CostGuidedSearch(
        top_k=2, cost_fn=lambda space, cand, args: est[cand]
    ).search(sp, (0,), cache=cache, repeats=1)
    # baseline + the 2 cheapest-by-model candidates, nothing else
    assert cache.misses == 3
    assert rep.best.pattern == ("a", "b")


def test_cost_guided_search_falls_back_when_model_fails():
    sp = sleep_subset_space(
        {frozenset(): 0.02, frozenset({"a"}): 0.005}, ["a"]
    )

    def broken(space, cand, args):
        raise RuntimeError("untraceable")

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        rep = CostGuidedSearch(top_k=1, cost_fn=broken).search(
            sp, (0,), cache=MeasurementCache(), repeats=1
        )
    assert any("falling back" in str(x.message) for x in w)
    assert rep.best.pattern == ("a",)


def test_roofline_cost_ranks_jax_variants():
    jnp = pytest.importorskip("jax.numpy")
    small = jnp.ones((8, 8), jnp.float32)
    t_small = planner.roofline_seconds(lambda x: x @ x, (small,))
    big = jnp.ones((64, 64), jnp.float32)
    t_big = planner.roofline_seconds(lambda x: x @ x, (big,))
    assert 0 < t_small < t_big


# -- persistent plans ---------------------------------------------------------


def _binding_space_with_counter(counter):
    reg = FunctionBlockRegistry()
    for target, delay in [("ref", 0.015), ("xla", 0.003)]:
        def mk(d=delay):
            def impl(x):
                counter["calls"] += 1
                time.sleep(d)
                return x

            return impl

        reg.register("norm", target, mk())
    return BindingSpace(
        lambda: (lambda x: reg.call("norm", x)), registry=reg
    )


def test_plan_store_roundtrip_and_zero_measurement_reload(tmp_path):
    counter = {"calls": 0}
    store = PlanStore(tmp_path)

    space = _binding_space_with_counter(counter)
    p1 = Planner(space, ExhaustiveSearch(), store=store)
    plan, report = p1.plan((1,), key="serve:test", repeats=1)
    assert report is not None  # a real search happened
    assert p1.cache.misses > 0
    assert plan.mapping == {"norm": "xla"}
    assert store.path_for("serve:test").exists()

    # second process: fresh planner + cache, same store -> zero measurement
    counter2 = {"calls": 0}
    p2 = Planner(
        _binding_space_with_counter(counter2), ExhaustiveSearch(), store=store
    )
    plan2, report2 = p2.plan((1,), key="serve:test", repeats=1)
    assert report2 is None  # served from the store
    assert p2.cache.misses == 0
    assert counter2["calls"] == 0  # no variant was ever built or run
    assert plan2.mapping == plan.mapping
    assert plan2.speedup == pytest.approx(plan.speedup)


def test_plan_store_fingerprint_mismatch_forces_research(tmp_path):
    store = PlanStore(tmp_path)
    counter = {"calls": 0}
    space = _binding_space_with_counter(counter)
    plan, _ = Planner(space, ExhaustiveSearch(), store=store).plan(
        (1,), key="k", repeats=1
    )
    # corrupt the fingerprint: pretend it was verified on other hardware
    stale = Plan.from_json(plan.to_json())
    stale.fingerprint = dict(plan.fingerprint, device="fpga-board-42")
    store.save(stale)

    assert store.load("k") is None  # invisible under this environment
    p2 = Planner(_binding_space_with_counter(counter), ExhaustiveSearch(),
                 store=store)
    _, report2 = p2.plan((1,), key="k", repeats=1)
    assert report2 is not None  # re-searched, not silently reused


def test_serve_loads_and_binds_plan_without_measurement(tmp_path):
    """The production path: a plan saved by one process is loaded via
    repro.offload.stored_binding and bound via blocks.bind, zero search."""
    from repro.offload import stored_binding

    counter = {"calls": 0}
    space = _binding_space_with_counter(counter)
    Planner(space, ExhaustiveSearch(), store=PlanStore(tmp_path)).plan(
        (1,), key="serve:prod", repeats=1
    )
    calls_after_search = counter["calls"]
    assert calls_after_search > 0

    # the global registry must know the plan's block for it to be loadable
    blocks.registry.register("norm", "xla", lambda x: x)
    mapping = stored_binding(str(tmp_path), "serve:prod")
    assert mapping == {"norm": "xla"}
    # loading measured nothing and never invoked a block implementation
    assert counter["calls"] == calls_after_search

    seen = []
    blocks.registry.register(
        "planner_test_block", "xla", lambda x: seen.append(x) or x
    )
    with blocks.bind({"planner_test_block": mapping["norm"]}):
        blocks.call("planner_test_block", 7)
    assert seen == [7]


def test_stored_binding_rejects_stale_registry_mapping(tmp_path):
    """A plan naming a block/target that no longer exists must not bind."""
    from repro.offload import stored_binding

    plan = Plan(
        key="stale", space="sig", mapping={"ghost_block": "pallas"},
        pattern=("ghost_block",), baseline_seconds=1.0, best_seconds=0.5,
        speedup=2.0, strategy="exhaustive", evaluations=2,
        search_seconds=0.1,
        fingerprint=planner.environment_fingerprint(), created_unix=0.0,
    )
    PlanStore(tmp_path).save(plan)
    assert stored_binding(str(tmp_path), "stale") is None


def test_cache_distinguishes_workloads_with_same_axes():
    """Two apps discovering identically-named blocks must not share
    measurements: the cache key carries the builder tag and arg shapes."""
    import numpy as np

    def build_a(subset):
        return lambda x: (time.sleep(0.02 if subset else 0.001), x)[1]

    def build_b(subset):
        return lambda x: (time.sleep(0.001 if subset else 0.02), x)[1]

    cache = MeasurementCache()
    sp_a = SubsetSpace(build_a, ["blk"], tag="app_a")
    sp_b = SubsetSpace(build_b, ["blk"], tag="app_b")
    rep_a = ExhaustiveSearch().search(sp_a, (0,), cache=cache, repeats=1)
    rep_b = ExhaustiveSearch().search(sp_b, (0,), cache=cache, repeats=1)
    assert cache.misses == 4  # nothing replayed across the two apps
    assert rep_a.best.pattern == ()  # offloading hurts app A
    assert rep_b.best.pattern == ("blk",)  # and helps app B

    # same app, different input shape -> measured separately too
    sp_a2 = SubsetSpace(build_a, ["blk"], tag="app_a")
    ExhaustiveSearch().search(
        sp_a2, (np.ones((8, 8)),), cache=cache, repeats=1
    )
    assert cache.misses == 6


def test_plan_json_roundtrip_fields(tmp_path):
    plan = Plan(
        key="k", space="sig", mapping={"m": "xla"}, pattern=("m",),
        baseline_seconds=1.0, best_seconds=0.5, speedup=2.0,
        strategy="exhaustive", evaluations=3, search_seconds=0.1,
        fingerprint={"device": "cpu"}, created_unix=123.0,
    )
    store = PlanStore(tmp_path)
    store.save(plan)
    loaded = store.load("k", fingerprint={"device": "cpu"})
    assert loaded == plan
    assert store.keys() == ["k"]


# -- engine integration -------------------------------------------------------


def test_measure_block_pattern_routes_through_cache():
    from repro.core.engine import OffloadEngine

    reg_calls = {"calls": 0}
    blocks.registry.register(
        "planner_probe", "slow",
        lambda x: (reg_calls.__setitem__("calls", reg_calls["calls"] + 1),
                   time.sleep(0.01), x)[-1],
    )
    blocks.registry.register(
        "planner_probe", "fast",
        lambda x: (reg_calls.__setitem__("calls", reg_calls["calls"] + 1),
                   x)[-1],
    )

    def builder():
        return lambda x: blocks.call("planner_probe", x)

    eng = OffloadEngine()
    cache = MeasurementCache()
    patterns = [{"planner_probe": "slow"}, {"planner_probe": "fast"}]
    best, results = eng.measure_block_pattern(
        builder, patterns, (1,), repeats=1, cache=cache
    )
    assert best == {"planner_probe": "fast"}
    assert [p for p, _ in results] == patterns
    assert cache.misses == 2

    # same cache, second sweep: everything replays, nothing is re-measured
    calls_before = reg_calls["calls"]
    best2, _ = eng.measure_block_pattern(
        builder, patterns, (1,), repeats=1, cache=cache
    )
    assert best2 == best
    assert cache.misses == 2
    assert reg_calls["calls"] == calls_before
