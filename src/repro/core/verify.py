"""Verification environment (paper Step 3 / §4.2 pattern search).

"Being registered as fast" does not guarantee speed in situ, so the paper
measures.  Its procedure with k replaceable blocks:

1. measure the unmodified application (baseline);
2. measure each block offloaded *alone*;
3. take the set of blocks that individually beat the baseline, measure the
   combined pattern, and keep the combination only if it beats the best
   single pattern;
4. the fastest measured pattern is the solution.

That procedure is implemented verbatim in ``search_offload_pattern``.  The
FPGA-motivated pre-filter ("compilation takes hours, narrow candidates by
arithmetic intensity first") maps to an optional cost-hint pre-filter.

Measurements block on device results (``block_until_ready``) and use
median-of-repeats, warming up once to exclude JIT compile time — compile time
is reported separately because the paper reports search time (minutes vs
hours for the GA) as a headline result.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Mapping, Sequence


def _block(x: Any) -> None:
    if hasattr(x, "block_until_ready"):
        x.block_until_ready()
    elif isinstance(x, (tuple, list)):
        for e in x:
            _block(e)


@dataclasses.dataclass
class Measurement:
    seconds: float  # median runtime
    compile_seconds: float  # first (warm-up) call minus median
    repeats: int


def measure(
    fn: Callable[..., Any],
    args: Sequence[Any],
    repeats: int = 3,
    warmup: int = 1,
    min_seconds: float = 0.0,
) -> Measurement:
    t0 = time.perf_counter()
    for _ in range(max(warmup, 0)):
        _block(fn(*args))
    warm = time.perf_counter() - t0
    times = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        _block(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    med = times[len(times) // 2]
    return Measurement(
        seconds=max(med, 1e-9),
        compile_seconds=max(warm - med, 0.0),
        repeats=len(times),
    )


@dataclasses.dataclass
class Trial:
    pattern: tuple[str, ...]  # names of blocks offloaded in this variant
    seconds: float
    speedup: float  # vs baseline


@dataclasses.dataclass
class VerificationReport:
    baseline_seconds: float
    trials: list[Trial]
    best: Trial
    search_seconds: float  # total wall time of the search (paper headline)

    def trial(self, pattern: Iterable[str]) -> Trial | None:
        key = tuple(sorted(pattern))
        for t in self.trials:
            if tuple(sorted(t.pattern)) == key:
                return t
        return None


def search_offload_pattern(
    build_variant: Callable[[frozenset[str]], Callable[..., Any]],
    candidates: Sequence[str],
    args: Sequence[Any],
    repeats: int = 3,
    prefilter: Callable[[str], bool] | None = None,
) -> VerificationReport:
    """Run the paper's single-then-combine measured search.

    ``build_variant(subset)`` must return a callable implementing the
    application with exactly ``subset`` blocks offloaded (empty set =
    unmodified baseline).
    """

    t_search0 = time.perf_counter()
    candidates = [c for c in candidates if prefilter is None or prefilter(c)]

    baseline_fn = build_variant(frozenset())
    base = measure(baseline_fn, args, repeats=repeats)
    trials: list[Trial] = [Trial((), base.seconds, 1.0)]

    singles: list[Trial] = []
    for name in candidates:
        fn = build_variant(frozenset({name}))
        m = measure(fn, args, repeats=repeats)
        t = Trial((name,), m.seconds, base.seconds / m.seconds)
        trials.append(t)
        singles.append(t)

    winners = [t for t in singles if t.speedup > 1.0]
    best = min(trials, key=lambda t: t.seconds)
    if len(winners) >= 2:
        combo = frozenset(n for t in winners for n in t.pattern)
        fn = build_variant(combo)
        m = measure(fn, args, repeats=repeats)
        t = Trial(tuple(sorted(combo)), m.seconds, base.seconds / m.seconds)
        trials.append(t)
        # paper: adopt the combination only if faster than the best single
        if t.seconds < best.seconds:
            best = t

    return VerificationReport(
        baseline_seconds=base.seconds,
        trials=trials,
        best=best,
        search_seconds=time.perf_counter() - t_search0,
    )


def verify_numerics(
    original: Callable[..., Any],
    substituted: Callable[..., Any],
    args: Sequence[Any],
    rtol: float = 1e-3,
    atol: float = 1e-3,
) -> bool:
    """Functional check that a substitution preserves results (the paper's
    動作検証 step before deployment)."""
    import numpy as np

    a = original(*args)
    b = substituted(*args)

    def _cmp(x, y) -> bool:
        x = np.asarray(x)
        y = np.asarray(y)
        if x.shape != y.shape:
            return False
        return bool(np.allclose(x, y, rtol=rtol, atol=atol))

    if isinstance(a, tuple) and isinstance(b, tuple):
        return len(a) == len(b) and all(_cmp(x, y) for x, y in zip(a, b))
    return _cmp(a, b)
