import os
import sys

# Tests run on the single host device (the dry-run sets its own flags in a
# separate process).  Keep CPU feature parity deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

# ``hypothesis`` is an optional dev dependency (see requirements-dev.txt).
# When absent, install a stub so the test modules that import it still
# collect: property tests decorated with @given are skipped at run time,
# everything else in those modules runs normally.
try:  # pragma: no cover - trivial import probe
    import hypothesis  # noqa: F401
except ImportError:
    import types

    class _Strategy:
        """Placeholder accepted anywhere a SearchStrategy object is used at
        collection time (module-level st.* calls, .map/.filter chains)."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.__getattr__ = lambda name: _Strategy()  # type: ignore[attr-defined]

    def _given(*_args, **_kwargs):
        def deco(fn):
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed")

            skipper.__name__ = getattr(fn, "__name__", "hypothesis_test")
            skipper.__doc__ = fn.__doc__
            # deliberately no __wrapped__: pytest must see a zero-arg
            # signature so it doesn't look for fixtures named after the
            # hypothesis-provided parameters
            for mark_attr in ("pytestmark",):
                if hasattr(fn, mark_attr):
                    setattr(skipper, mark_attr, getattr(fn, mark_attr))
            return skipper

        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    _hypothesis = types.ModuleType("hypothesis")
    _hypothesis.given = _given
    _hypothesis.settings = _settings
    _hypothesis.strategies = _strategies
    _hypothesis.HealthCheck = _Strategy()
    _hypothesis.assume = lambda *a, **k: True
    _hypothesis.note = lambda *a, **k: None
    sys.modules["hypothesis"] = _hypothesis
    sys.modules["hypothesis.strategies"] = _strategies


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: builds real model steps; seconds per test"
    )


@pytest.fixture
def rng():
    return np.random.default_rng(0)
