"""Project-wide static-analysis sweep: ``python -m repro.analysis.lint``.

Runs every ``repro.analysis`` pass over the two program populations the
repo actually ships:

* **configs-zoo cells** — each (arch, phase) step the offload planner
  searches gets the *legality* pass (every (block, target) binding of its
  :class:`~repro.core.planner.space.BindingSpace` classified against the
  kernel shelf's metadata and probe-traced) plus the static hot-path lints
  (callback primitives, constant-capture bloat).  Zoo cells return full
  logits by design, so the loop-program host-sync contract is *not*
  applied to them — that contract belongs to the engine programs below.
* **serve engines** — a tiny :class:`~repro.serve.ServeEngine` per
  representative arch (attention-family paged + SSM contiguous) serves a
  short mixed-length trace, then ``engine.lint()`` checks the hot-path
  contracts over the programs as actually called (decode host transfer is
  token ids only, recomposition never retraces) and the page-aliasing
  sanitizer over the final page-table operand.

With ``--resources``, the memory-envelope pass also runs: every zoo
cell's candidate bindings are fitted against ``--envelope`` (default
``cpu-host-16g``, a *static* envelope so verdicts are host-independent)
and every serve engine gets a static capacity plan, whose cannot-fit
verdicts are ratcheted warnings.  The kernel-shelf coverage lint
(every implementation must declare ``BLOCK_LEGALITY`` *and*
``BLOCK_RESOURCES``) always runs.

Diagnostics diff against a checked-in baseline (``analysis_baseline.json``)
so ``--fail-on-new`` fails CI only on *new* warning/error findings — the
ratchet discipline of a type-checker baseline.  ``info`` diagnostics
(host-platform-dependent legality verdicts, per-binding resource fits)
never enter the ratchet, and diagnostic fingerprints exclude the platform
they were found on.

  PYTHONPATH=src python -m repro.analysis.lint --fail-on-new
  PYTHONPATH=src python -m repro.analysis.lint --update-baseline
  PYTHONPATH=src python -m repro.analysis.lint --arch llama3.2-1b --json
  PYTHONPATH=src python -m repro.analysis.lint --resources --json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import warnings
from typing import Sequence

from repro.analysis.diagnostics import AnalysisReport, Baseline, Diagnostic

DEFAULT_BASELINE = "analysis_baseline.json"

#: Zoo phases linted by default — the serving phases whose plans the
#: engine binds.  ``train`` cells work too (``--kinds train,...``) but
#: triple the sweep for programs the serve path never runs.
DEFAULT_ZOO_KINDS = ("prefill", "decode")

#: One attention-family arch (paged KV) + one SSM arch (contiguous
#: state) cover both engine code paths.
DEFAULT_SERVE_ARCHS = ("llama3.2-1b", "mamba2-2.7b")


def lint_zoo_cell(
    arch: str,
    kind: str,
    *,
    reduced: bool = True,
    layers: int = 1,
    batch: int = 1,
    seq: int = 8,
    seed: int = 0,
    targets: Sequence[str] | None = None,
    probe_trace: bool = True,
    envelope: object = None,
    resources_out: dict | None = None,
) -> list[Diagnostic]:
    """Legality + static hot-path lints for one configs-zoo cell.

    With ``envelope`` the memory-envelope pass runs too; its per-binding
    fit report lands in ``resources_out`` (keyed by program) when given.
    """
    from repro.analysis.hotpath import lint_traced_program
    from repro.analysis.legality import check_binding_space
    from repro.core import blocks as blocks_mod
    from repro.core.planner.space import BindingSpace
    from repro.offload.zoo import _cell_blocks, _cell_target

    program = f"zoo:{arch}:{kind}"
    builder, args, cfg = _cell_target(
        arch, kind, reduced=reduced, layers=layers, batch=batch, seq=seq,
        seed=seed,
    )
    registry = blocks_mod.registry
    diags: list[Diagnostic] = []
    block_map = _cell_blocks(cfg, registry, targets, kind)
    if block_map:
        space = BindingSpace(
            builder, blocks=block_map, registry=registry, tag=program
        )
        rep = check_binding_space(
            space, args, probe_trace=probe_trace, program=program,
            envelope=envelope,
        )
        diags.extend(rep.diagnostics())
        if rep.resources is not None and resources_out is not None:
            resources_out[program] = rep.resources.to_dict()
    diags.extend(lint_traced_program(program, builder(), args))
    return diags


def lint_serve_engine(
    arch: str,
    *,
    page_size: int | None = None,
    n_slots: int = 2,
    max_len: int = 32,
    requests: int = 3,
    prompt_len: int = 6,
    gen: int = 4,
    max_steps: int = 256,
    seed: int = 0,
    envelope: object = None,
    resources_out: dict | None = None,
) -> list[Diagnostic]:
    """Serve a short trace on a tiny reduced engine, then run its hot-path
    and page-table lints.  Program names are rewritten to
    ``serve:<arch>:<program>`` so fingerprints stay unique across archs.

    With ``envelope`` the engine's static capacity plan joins the
    diagnostics (``capacity-oom`` is a ratcheted warning) and its full
    figures land in ``resources_out`` when given.
    """
    import numpy as np

    from repro.configs import get_config
    from repro.serve import Request, ServeEngine

    cfg = get_config(arch).reduced()
    engine = ServeEngine(
        cfg, n_slots=n_slots, max_len=max_len, page_size=page_size,
        seed=seed, quiet=True,
    )
    rng = np.random.default_rng(seed)
    for i in range(requests):
        prompt = rng.integers(0, cfg.vocab_size, prompt_len + i).tolist()
        engine.submit(Request(prompt, max_new_tokens=gen))
    engine.run_until_idle(max_steps=max_steps)

    raw = list(engine.lint())
    if envelope is not None:
        plan = engine.plan_capacity(envelope)
        raw.extend(plan.diagnostics(program=f"{cfg.name}:capacity"))
        if resources_out is not None:
            resources_out[f"serve:{arch}:capacity"] = plan.to_dict()

    diags = []
    for d in raw:
        prog = d.program
        if prog.startswith(cfg.name + ":"):
            prog = prog[len(cfg.name) + 1:]
        diags.append(dataclasses.replace(d, program=f"serve:{arch}:{prog}"))
    return diags


def run_lint(
    archs: Sequence[str] | None = None,
    kinds: Sequence[str] = DEFAULT_ZOO_KINDS,
    serve_archs: Sequence[str] | None = DEFAULT_SERVE_ARCHS,
    *,
    probe_trace: bool = True,
    seed: int = 0,
    verbose: bool = False,
    envelope: object = None,
    resources_out: dict | None = None,
) -> AnalysisReport:
    """The full sweep the CLI and the fast-tier test share.

    Cells that cannot be built on this host are skipped with a
    ``UserWarning`` (matching ``plan_zoo``'s sweep discipline) rather than
    aborting the whole lint.  ``envelope`` turns the memory-envelope pass
    on for zoo cells and serve engines; the shelf-coverage lint always
    runs (missing metadata must ratchet regardless of envelope choice).
    """
    from repro.analysis.resources import lint_shelf_coverage
    from repro.configs import ARCH_NAMES

    report = AnalysisReport()
    try:
        report.extend(lint_shelf_coverage())
    except Exception as e:  # noqa: BLE001 — keep sweeping
        warnings.warn(
            f"lint: shelf coverage failed: {type(e).__name__}: {e}",
            stacklevel=2,
        )
    for arch in archs if archs is not None else ARCH_NAMES:
        for kind in kinds:
            try:
                diags = lint_zoo_cell(
                    arch, kind, seed=seed, probe_trace=probe_trace,
                    envelope=envelope, resources_out=resources_out,
                )
            except Exception as e:  # noqa: BLE001 — keep sweeping
                warnings.warn(
                    f"lint: zoo cell {arch}:{kind} failed: "
                    f"{type(e).__name__}: {e}",
                    stacklevel=2,
                )
                continue
            if verbose:
                print(f"zoo:{arch}:{kind}: {len(diags)} diagnostics")
            report.extend(diags)
    for arch in serve_archs or ():
        try:
            # paged KV only exists for attention-family caches; SSM archs
            # exercise the contiguous path
            paged = "m" not in _pattern_of(arch)
            diags = lint_serve_engine(
                arch, page_size=8 if paged else None, seed=seed,
                envelope=envelope, resources_out=resources_out,
            )
        except Exception as e:  # noqa: BLE001 — keep sweeping
            warnings.warn(
                f"lint: serve engine {arch} failed: {type(e).__name__}: {e}",
                stacklevel=2,
            )
            continue
        if verbose:
            print(f"serve:{arch}: {len(diags)} diagnostics")
        report.extend(diags)
    return report


def _pattern_of(arch: str) -> str:
    from repro.configs import get_config

    return get_config(arch).pattern()


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description=__doc__.split("\n")[0],
    )
    ap.add_argument("--arch", default="all",
                    help="comma-separated zoo archs to lint (default: all)")
    ap.add_argument("--kinds", default=",".join(DEFAULT_ZOO_KINDS),
                    help="comma-separated zoo phases (prefill,decode[,train])")
    ap.add_argument("--serve-arch", default=",".join(DEFAULT_SERVE_ARCHS),
                    help="comma-separated archs to serve-lint with a tiny "
                         "engine ('' disables the engine sweep)")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the per-binding probe trace (metadata-only "
                         "legality verdicts)")
    ap.add_argument("--resources", action="store_true",
                    help="run the memory-envelope pass: per-binding fit "
                         "verdicts for zoo cells and a static capacity "
                         "plan per serve engine")
    ap.add_argument("--envelope", default="cpu-host-16g",
                    help="device envelope --resources checks against: a "
                         "static name (default cpu-host-16g so verdicts "
                         "ratchet identically on every host) or 'host'")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="accepted-diagnostics file for the ratchet")
    ap.add_argument("--fail-on-new", action="store_true",
                    help="exit 1 if any warning/error diagnostic is not in "
                         "the baseline (the CI mode)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from this run's diagnostics")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import ARCH_NAMES

    archs = list(ARCH_NAMES) if args.arch == "all" else args.arch.split(",")
    kinds = tuple(k for k in args.kinds.split(",") if k)
    serve_archs = tuple(a for a in args.serve_arch.split(",") if a)

    resources_out: dict | None = {} if args.resources else None
    report = run_lint(
        archs, kinds, serve_archs,
        probe_trace=not args.no_probe, seed=args.seed,
        verbose=not args.json,
        envelope=args.envelope if args.resources else None,
        resources_out=resources_out,
    )
    baseline = Baseline.load(args.baseline)
    new = report.new_versus(baseline)

    if args.update_baseline:
        baseline.save(args.baseline, report)

    if args.json:
        payload = report.to_dict()
        payload["new"] = [d.to_dict() for d in new]
        payload["baseline"] = args.baseline
        if resources_out is not None:
            payload["resources"] = {
                "envelope": args.envelope,
                "reports": resources_out,
            }
        print(json.dumps(payload, indent=2))
    else:
        counts = report.counts()
        print(
            f"repro.analysis: {len(report.diagnostics)} diagnostics "
            f"({counts['error']} error, {counts['warning']} warning, "
            f"{counts['info']} info); {len(new)} new vs baseline "
            f"'{args.baseline}'"
        )
        if resources_out is not None:
            plans = [r for r in resources_out.values() if "fits" in r]
            fits = sum(1 for r in plans if r["fits"])
            print(
                f"resources: {len(resources_out)} envelope reports against "
                f"'{args.envelope}' ({fits}/{len(plans)} capacity plans fit)"
            )
        for d in sorted(report.diagnostics, key=lambda d: d.fingerprint):
            marker = " [NEW]" if d in new else ""
            print(f"  {d}{marker}")
        if args.update_baseline:
            print(f"baseline updated: {args.baseline}")

    if args.fail_on_new and new:
        if not args.json:
            print(
                f"FAIL: {len(new)} new diagnostic(s) above baseline — fix "
                "them or re-accept with --update-baseline", file=sys.stderr,
            )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
