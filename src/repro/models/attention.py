"""Attention mixers: GQA (llama-style) and MLA (DeepSeek-V2), with KV caches.

Three execution paths per mixer:
  * train/prefill: full-sequence causal attention through the FunctionBlock
    registry ("attention" block: ref = naive softmax einsum, xla = chunked
    online-softmax (memory-safe at 32k+), pallas = flash kernel);
  * decode: single-token attention over the cache — einsum-based, never
    materialises repeated KV heads; MLA decodes in the *absorbed* form
    (scores and values computed directly against the compressed latent
    cache, the MLA serving trick).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import blocks
from repro.models.layers import rmsnorm, rope, tp_out_einsum
from repro.models.params import ParamMeta
from repro.sharding.utils import constrain

_NEG = -1e30


# -- parameter metas -----------------------------------------------------------


def attn_metas(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    dt = cfg.param_dtype
    if cfg.mla:
        m = cfg.mla
        h = cfg.n_heads
        return {
            "wq": ParamMeta(
                (d, h * (m.qk_nope_head_dim + m.qk_rope_head_dim)),
                ("embed", "heads"), dt,
            ),
            "w_dkv": ParamMeta((d, m.kv_lora_rank), ("embed", None), dt),
            "kv_norm": ParamMeta((m.kv_lora_rank,), (None,), dt, init="ones"),
            "w_uk": ParamMeta(
                (m.kv_lora_rank, h * m.qk_nope_head_dim), (None, "heads"), dt
            ),
            "w_uv": ParamMeta(
                (m.kv_lora_rank, h * m.v_head_dim), (None, "heads"), dt
            ),
            "w_kr": ParamMeta((d, m.qk_rope_head_dim), ("embed", None), dt),
            "wo": ParamMeta((h * m.v_head_dim, d), ("heads", "embed"), dt),
        }
    return {
        "wq": ParamMeta((d, cfg.n_heads * cfg.d_head), ("embed", "heads"), dt),
        "wk": ParamMeta((d, cfg.n_kv_heads * cfg.d_head), ("embed", "kv_heads"), dt),
        "wv": ParamMeta((d, cfg.n_kv_heads * cfg.d_head), ("embed", "kv_heads"), dt),
        "wo": ParamMeta((cfg.n_heads * cfg.d_head, d), ("heads", "embed"), dt),
    }


def cache_metas(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """Per-layer KV cache metas (leading layer axis added by the LM)."""
    ct = cfg.compute_dtype
    if cfg.mla:
        m = cfg.mla
        return {
            "c": ParamMeta(
                (batch, max_len, m.kv_lora_rank),
                ("act_batch", "cache_seq", None), ct, init="zeros",
            ),
            "kr": ParamMeta(
                (batch, max_len, m.qk_rope_head_dim),
                ("act_batch", "cache_seq", None), ct, init="zeros",
            ),
        }
    return {
        "k": ParamMeta(
            (batch, cfg.n_kv_heads, max_len, cfg.d_head),
            ("act_batch", "kv_heads_act", "cache_seq", None), ct, init="zeros",
        ),
        "v": ParamMeta(
            (batch, cfg.n_kv_heads, max_len, cfg.d_head),
            ("act_batch", "kv_heads_act", "cache_seq", None), ct, init="zeros",
        ),
    }


def cache_metas_paged(
    cfg: ArchConfig, n_pages_total: int, page_size: int
) -> dict:
    """Block-paged pool layout: the contiguous layout with the batch axis
    reinterpreted as a *shared page pool* (``n_pages_total`` includes the
    null page) and the sequence axis shrunk to one page.  Slot identity
    moves out of the storage entirely — it lives in the page table the
    decode program gathers through — so pool axes carry no batch/sequence
    sharding names (multi-device serving shards slots, not pages)."""
    out = {}
    for key, m in cache_metas(cfg, n_pages_total, page_size).items():
        axes = tuple(
            None if a in ("act_batch", "cache_seq") else a for a in m.axes
        )
        out[key] = ParamMeta(m.shape, axes, m.dtype, m.init, m.scale)
    return out


def cache_seq_axes(cfg: ArchConfig) -> dict:
    """Leaf name -> sequence-axis position in the per-layer contiguous
    cache leaf (batch leading).  The same position holds the within-page
    axis in the paged pool layout — the engine's page-insert uses this to
    split a prefilled slot cache into whole pages."""
    return {
        key: m.axes.index("cache_seq")
        for key, m in cache_metas(cfg, 1, 1).items()
    }


# -- chunked full-sequence attention (the memory-safe XLA formulation) ---------
#
# Flash-attention forward AND backward in jnp, with *static* chunk loops:
#   * naive autodiff through attention stacks the full S^2 probability
#     matrix per layer — the custom_vjp recomputes probability blocks in the
#     backward from the saved (q, k, v, out, lse) instead;
#   * chunk iteration is a Python loop over statically-sliced blocks, NOT a
#     lax.scan over dynamic slices: GSPMD cannot partition a dynamic slice
#     whose sliced axis is sharded and falls back to fully replicating the
#     operand (hundreds of GB at 128 heads x 4k seq).  Static slices keep
#     every block sharded.
# Chunk size adapts so there are at most 8 chunks per axis (<=64 blocks).


import functools


def _chunks(s: int, target: int = 1024, max_chunks: int = 8) -> int:
    c = max(target, -(-s // max_chunks))
    c = min(c, s)
    while s % c:
        c += 1
    return c


# precision of the attention score blocks: "f32" (default) or "bf16"
# (halves the dominant HBM traffic of the XLA attention path; stats and
# accumulation stay f32) — a dry-run hillclimb knob.
CHUNKED_SCORES_DTYPE = "float32"


def _p_block(qc_scaled, lsec, kcf, qpos, kpos, causal):
    if CHUNKED_SCORES_DTYPE == "bfloat16":
        s = jnp.einsum(
            "bkgqd,bksd->bkgqs",
            qc_scaled.astype(jnp.bfloat16),
            kcf.astype(jnp.bfloat16),
            preferred_element_type=jnp.float32,
        )
    else:
        s = jnp.einsum("bkgqd,bksd->bkgqs", qc_scaled, kcf)
    if causal:
        mask = (qpos[:, None] >= kpos[None, :])[None, None, None]
        s = jnp.where(mask, s, _NEG)
    return s, jnp.exp(s - lsec[..., None])


def _chunked_fwd_core(q, k, v, causal: bool, q_chunk: int, kv_chunk: int):
    """Returns (out (B,KH,G,Sq,Dv) f32, lse (B,KH,G,Sq))."""
    b, h, sq, dk = q.shape
    _, kh, skv, dv = v.shape
    g = h // kh
    nq = sq // q_chunk
    nk = skv // kv_chunk
    scale = 1.0 / (dk ** 0.5)
    qg = q.reshape(b, kh, g, sq, dk)
    off = skv - sq  # align sequence ends (cached prefix)

    outs = []
    lses = []
    for qi in range(nq):
        qc = qg[:, :, :, qi * q_chunk : (qi + 1) * q_chunk, :]
        qc = qc.astype(jnp.float32) * scale
        qpos = off + qi * q_chunk + jnp.arange(q_chunk)
        m_acc = jnp.full((b, kh, g, q_chunk), _NEG, jnp.float32)
        l_acc = jnp.zeros((b, kh, g, q_chunk), jnp.float32)
        o_acc = jnp.zeros((b, kh, g, q_chunk, dv), jnp.float32)
        for ki in range(nk):
            if causal and ki * kv_chunk > off + (qi + 1) * q_chunk - 1:
                continue  # block fully above the diagonal
            kc = k[:, :, ki * kv_chunk : (ki + 1) * kv_chunk, :]
            vc = v[:, :, ki * kv_chunk : (ki + 1) * kv_chunk, :]
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            s, _ = _p_block(qc, jnp.zeros_like(m_acc), kc.astype(jnp.float32),
                            qpos, kpos, causal)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_acc, m_cur)
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_acc - m_new)
            l_acc = l_acc * alpha + jnp.sum(p, axis=-1)
            o_acc = o_acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p, vc.astype(jnp.float32)
            )
            m_acc = m_new
        l_safe = jnp.where(l_acc == 0.0, 1.0, l_acc)
        outs.append(o_acc / l_safe[..., None])
        lses.append(m_acc + jnp.log(l_safe))
    out = jnp.concatenate(outs, axis=3) if nq > 1 else outs[0]
    lse = jnp.concatenate(lses, axis=3) if nq > 1 else lses[0]
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _attention_chunked_core(q, k, v, causal, q_chunk, kv_chunk):
    out, _ = _chunked_fwd_core(q, k, v, causal, q_chunk, kv_chunk)
    b, h, sq, _ = q.shape
    return out.reshape(b, h, sq, -1).astype(q.dtype)


def _core_fwd(q, k, v, causal, q_chunk, kv_chunk):
    out, lse = _chunked_fwd_core(q, k, v, causal, q_chunk, kv_chunk)
    b, h, sq, _ = q.shape
    res = (q, k, v, out, lse)
    return out.reshape(b, h, sq, -1).astype(q.dtype), res


def _core_bwd(causal, q_chunk, kv_chunk, res, do):
    q, k, v, out, lse = res  # out/lse grouped (B,KH,G,Sq,*)
    b, h, sq, dk = q.shape
    _, kh, skv, dv = v.shape
    g = h // kh
    nq = sq // q_chunk
    nk = skv // kv_chunk
    scale = 1.0 / (dk ** 0.5)
    qg = q.reshape(b, kh, g, sq, dk).astype(jnp.float32)
    dog = do.reshape(b, kh, g, sq, dv).astype(jnp.float32)
    off = skv - sq
    dsum = jnp.sum(dog * out, axis=-1)  # (B,KH,G,Sq)

    dq_parts = []
    dk_parts = [jnp.zeros((b, kh, kv_chunk, dk), jnp.float32) for _ in range(nk)]
    dv_parts = [jnp.zeros((b, kh, kv_chunk, dv), jnp.float32) for _ in range(nk)]
    for qi in range(nq):
        sl = slice(qi * q_chunk, (qi + 1) * q_chunk)
        qc = qg[:, :, :, sl, :] * scale
        doc = dog[:, :, :, sl, :]
        lsec = lse[:, :, :, sl]
        dsc = dsum[:, :, :, sl]
        qpos = off + qi * q_chunk + jnp.arange(q_chunk)
        dq_acc = jnp.zeros((b, kh, g, q_chunk, dk), jnp.float32)
        for ki in range(nk):
            if causal and ki * kv_chunk > off + (qi + 1) * q_chunk - 1:
                continue
            ksl = slice(ki * kv_chunk, (ki + 1) * kv_chunk)
            kcf = k[:, :, ksl, :].astype(jnp.float32)
            vcf = v[:, :, ksl, :].astype(jnp.float32)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            _, p = _p_block(qc, lsec, kcf, qpos, kpos, causal)
            dp = jnp.einsum("bkgqd,bksd->bkgqs", doc, vcf)
            ds = p * (dp - dsc[..., None])
            dq_acc = dq_acc + jnp.einsum("bkgqs,bksd->bkgqd", ds, kcf) * scale
            dk_parts[ki] = dk_parts[ki] + jnp.einsum(
                "bkgqs,bkgqd->bksd", ds, qc
            )  # qc already carries the 1/sqrt(d) factor
            dv_parts[ki] = dv_parts[ki] + jnp.einsum("bkgqs,bkgqd->bksd", p, doc)
        dq_parts.append(dq_acc)

    dq = (jnp.concatenate(dq_parts, axis=3) if nq > 1 else dq_parts[0])
    dk_full = jnp.concatenate(dk_parts, axis=2) if nk > 1 else dk_parts[0]
    dv_full = jnp.concatenate(dv_parts, axis=2) if nk > 1 else dv_parts[0]
    return (
        dq.reshape(b, h, sq, dk).astype(q.dtype),
        dk_full.astype(k.dtype),
        dv_full.astype(v.dtype),
    )


_attention_chunked_core.defvjp(_core_fwd, _core_bwd)


def attention_chunked(
    q: jax.Array,  # (B, H, Sq, Dk)
    k: jax.Array,  # (B, KH, Skv, Dk)
    v: jax.Array,  # (B, KH, Skv, Dv)
    causal: bool = True,
    q_chunk: int | None = None,
    kv_chunk: int | None = None,
) -> jax.Array:
    sq = q.shape[2]
    skv = k.shape[2]
    q_chunk = q_chunk or _chunks(sq)
    kv_chunk = kv_chunk or _chunks(skv)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    if sq % q_chunk or skv % kv_chunk:
        raise ValueError("sequence lengths must tile by attention chunks")
    return _attention_chunked_core(q, k, v, causal, q_chunk, kv_chunk)


def _register_chunked() -> None:
    from repro.core.blocks import registry

    registry.register(
        "attention", "xla", attention_chunked,
        "chunked online-softmax attention (memory-safe at long context)",
    )


_register_chunked()


# -- decode attention over a cache ----------------------------------------------
#
# ``index`` is per-slot: shape (B,), the write position of the *first* new
# token in each batch row's cache.  Continuous-batching serving
# (``repro.serve``) staggers requests across slots, so every row decodes at
# its own position; the single-sequence case is just the vector with equal
# entries.  Decode is the S=1 case of the general cached-extension step
# (S > 1 is chunked prefill: a budget-sized prompt chunk appended against
# the cache, causal within the chunk).


def _update_slot_rows(cache: jax.Array, update: jax.Array, index: jax.Array,
                      axis: int) -> jax.Array:
    """Per-batch-row ``dynamic_update_slice`` at each row's own position.

    ``cache``/``update`` share a leading batch axis; ``axis`` is the sequence
    axis *including* the batch axis.  ``index`` is (B,) int32.
    """
    return jax.vmap(
        lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
            c, u, i, axis=axis - 1
        )
    )(cache, update, index)


# -- page-table indirection (the paged KV pool) ---------------------------------
#
# Pool leaves share the contiguous leaf's rank: batch axis -> page axis
# (``n_pages + 1``; the last page is the null page freed/prefilling slots
# scatter into), sequence axis -> one page of ``page_size`` rows.
# ``pages`` is the (B, max_pages) int32 page table; a slot's logical
# position ``t`` lives in page ``pages[b, t // page_size]`` at row
# ``t % page_size``.  Entries past a slot's allocation point at the null
# page, so the gathered view is garbage there — always masked, because the
# valid mask admits only ``t <= index``.


def gather_kv_pages(
    pool: jax.Array, pages: jax.Array, seq_axis: int
) -> jax.Array:
    """Gather a per-slot contiguous K/V view from the page pool.

    ``pool`` (P_total, ..., page_size @ seq_axis, ...), ``pages``
    (B, max_pages) -> (B, ..., max_pages * page_size @ seq_axis, ...).
    """
    g = pool[pages]  # (B, max_pages) + pool.shape[1:]
    g = jnp.moveaxis(g, 1, seq_axis)  # page axis lands beside the page rows
    shp = g.shape
    return g.reshape(
        shp[:seq_axis]
        + (shp[seq_axis] * shp[seq_axis + 1],)
        + shp[seq_axis + 2 :]
    )


def scatter_token_pages(
    pool: jax.Array,
    val: jax.Array,
    pages: jax.Array,
    index: jax.Array,
    seq_axis: int,
) -> jax.Array:
    """Scatter each row's new token into its current page.

    ``val`` is the token slice with the sequence axis squeezed out (GQA
    (B, KH, D), MLA (B, r)); ``index`` (B,) is the logical write position.
    Rows whose table entry is the null page (freed slots, slots still
    prefilling) write into the sacrificial page.
    """
    ps = pool.shape[seq_axis]
    pid = jnp.take_along_axis(
        pages, (index[:, None] // ps).astype(jnp.int32), axis=1, mode="clip"
    )[:, 0]
    off = index % ps
    idx = (pid,) + (slice(None),) * (seq_axis - 1) + (off,)
    return pool.at[idx].set(val.astype(pool.dtype))


def insert_pages(
    pool: jax.Array, b1: jax.Array, page_ids: jax.Array, seq_axis: int
) -> jax.Array:
    """Scatter a prefilled batch-1 slot cache into the pool as whole pages.

    ``pool`` (L, P_total, ..., page_size, ...), ``b1`` (L, 1, ..., S, ...)
    with ``S == max_pages * page_size``; ``page_ids`` (max_pages,) is the
    slot's page list, null-page entries absorbing the unallocated tail.
    ``seq_axis`` positions are per-layer (batch leading), as from
    :func:`cache_seq_axes`.
    """
    ps = pool.shape[seq_axis + 1]
    x = jnp.squeeze(b1, axis=1)  # (L, ..., S, ...): seq back at seq_axis
    shp = x.shape
    n = shp[seq_axis] // ps
    x = x.reshape(shp[:seq_axis] + (n, ps) + shp[seq_axis + 1 :])
    x = jnp.moveaxis(x, seq_axis, 1)  # (L, max_pages, ..., ps, ...)
    return pool.at[:, page_ids].set(x.astype(pool.dtype))


def decode_attention_gqa(
    q: jax.Array,  # (B, H, S, D) — S=1 decode, S>1 chunked-prefill extend
    k_cache: jax.Array,  # (B, KH, Smax, D)
    v_cache: jax.Array,
    index: jax.Array,  # (B,): each row's first new-token position
) -> jax.Array:
    b, h, s, d = q.shape
    _, kh, smax, _ = k_cache.shape
    g = h // kh
    qg = q.reshape(b, kh, g, s, d).astype(jnp.float32) / (d ** 0.5)
    sc = jnp.einsum("bkgqd,bktd->bkgqt", qg, k_cache.astype(jnp.float32))
    qpos = index[:, None] + jnp.arange(s)  # (B, S)
    valid = (
        jnp.arange(smax)[None, None, None, None, :]
        <= qpos[:, None, None, :, None]
    )
    sc = jnp.where(valid, sc, _NEG)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, s, d).astype(q.dtype)


# -- the GQA mixer ----------------------------------------------------------------


def gqa_forward(
    p: dict,
    x: jax.Array,  # (B, S, D)
    cfg: ArchConfig,
    positions: jax.Array,  # (B, S)
    cache: dict | None = None,
    index: jax.Array | None = None,
    mode: str = "train",
    pages: jax.Array | None = None,
):
    b, s, d = x.shape
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,dq->bsq", xc, p["wq"].astype(cd)).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,dq->bsq", xc, p["wk"].astype(cd)).reshape(b, s, kh, dh)
    v = jnp.einsum("bsd,dq->bsq", xc, p["wv"].astype(cd)).reshape(b, s, kh, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "act_batch", None, "heads_act", None)
    k = constrain(k, "act_batch", None, "kv_heads_act", None)

    qt = jnp.swapaxes(q, 1, 2)  # (B,H,S,dh)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)

    if mode in ("decode", "extend"):
        assert cache is not None and index is not None
        if pages is not None:
            if s != 1:
                raise ValueError(
                    "paged attention writes one token per step; chunked "
                    "prefill extends the contiguous slot cache, not the pool"
                )
            k_cache = scatter_token_pages(
                cache["k"], kt[:, :, 0, :], pages, index, seq_axis=2
            )
            v_cache = scatter_token_pages(
                cache["v"], vt[:, :, 0, :], pages, index, seq_axis=2
            )
            k_view = gather_kv_pages(k_cache, pages, seq_axis=2)
            v_view = gather_kv_pages(v_cache, pages, seq_axis=2)
        else:
            k_cache = _update_slot_rows(
                cache["k"], kt.astype(cache["k"].dtype), index, axis=2
            )
            v_cache = _update_slot_rows(
                cache["v"], vt.astype(cache["v"].dtype), index, axis=2
            )
            k_view, v_view = k_cache, v_cache
        o = decode_attention_gqa(qt, k_view, v_view, index)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        o = blocks.call("attention", qt, kt, vt, causal=True)
        new_cache = None
        if cache is not None:  # prefill: persist kv
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], kt.astype(cache["k"].dtype), 0, axis=2
                ),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], vt.astype(cache["v"].dtype), 0, axis=2
                ),
            }
    o = jnp.swapaxes(o, 1, 2).reshape(b, s, h * dh)
    o = constrain(o, "act_batch", None, "heads_act")
    out = tp_out_einsum("bsq,qd->bsd", o.astype(cd), p["wo"].astype(cd), cd)
    return out, new_cache


# -- the MLA mixer -----------------------------------------------------------------


def mla_forward(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array,
    cache: dict | None = None,
    index: jax.Array | None = None,
    mode: str = "train",
    pages: jax.Array | None = None,
):
    m = cfg.mla
    b, s, d = x.shape
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = jnp.einsum("bsd,dq->bsq", xc, p["wq"].astype(cd))
    q = q.reshape(b, s, h, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = rope(qr, positions, cfg.rope_theta)

    c = jnp.einsum("bsd,dr->bsr", xc, p["w_dkv"].astype(cd))
    c = rmsnorm(p["kv_norm"], c, cfg.norm_eps).astype(cd)
    kr = jnp.einsum("bsd,dr->bsr", xc, p["w_kr"].astype(cd))
    kr = rope(kr[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,dr)

    if mode in ("decode", "extend"):
        assert cache is not None and index is not None
        if pages is not None:
            if s != 1:
                raise ValueError(
                    "paged attention writes one token per step; chunked "
                    "prefill extends the contiguous slot cache, not the pool"
                )
            c_cache = scatter_token_pages(
                cache["c"], c[:, 0, :], pages, index, seq_axis=1
            )
            kr_cache = scatter_token_pages(
                cache["kr"], kr[:, 0, 0, :], pages, index, seq_axis=1
            )
            c_view = gather_kv_pages(c_cache, pages, seq_axis=1)
            kr_view = gather_kv_pages(kr_cache, pages, seq_axis=1)
        else:
            c_cache = _update_slot_rows(
                cache["c"], c.astype(cache["c"].dtype), index, axis=1
            )
            kr_cache = _update_slot_rows(
                cache["kr"], kr[:, :, 0, :].astype(cache["kr"].dtype), index,
                axis=1,
            )
            c_view, kr_view = c_cache, kr_cache
        # absorbed decode: score = q_abs . c  +  qr . kr
        w_uk = p["w_uk"].astype(cd).reshape(m.kv_lora_rank, h, dn)
        q_abs = jnp.einsum("bshn,rhn->bshr", qn, w_uk)  # (B,S,H,r)
        scale = 1.0 / ((dn + dr) ** 0.5)
        s_nope = jnp.einsum(
            "bshr,btr->bhst", q_abs.astype(jnp.float32),
            c_view.astype(jnp.float32),
        )
        s_rope = jnp.einsum(
            "bshr,btr->bhst", qr.astype(jnp.float32),
            kr_view.astype(jnp.float32),
        )
        sc = (s_nope + s_rope) * scale  # (B,H,S,T)
        smax = c_view.shape[1]
        qpos = index[:, None] + jnp.arange(s)  # (B, S)
        valid = (
            jnp.arange(smax)[None, None, None, :]
            <= qpos[:, None, :, None]
        )
        sc = jnp.where(valid, sc, _NEG)
        pattn = jax.nn.softmax(sc, axis=-1)
        ctx = jnp.einsum(
            "bhst,btr->bshr", pattn, c_view.astype(jnp.float32)
        )  # weighted latent
        w_uv = p["w_uv"].astype(cd).reshape(m.kv_lora_rank, h, dv)
        o = jnp.einsum("bshr,rhv->bshv", ctx.astype(cd), w_uv)
        new_cache = {"c": c_cache, "kr": kr_cache}
    else:
        kn = jnp.einsum("bsr,rq->bsq", c, p["w_uk"].astype(cd))
        kn = kn.reshape(b, s, h, dn)
        v = jnp.einsum("bsr,rq->bsq", c, p["w_uv"].astype(cd))
        v = v.reshape(b, s, h, dv)
        k = jnp.concatenate([kn, jnp.broadcast_to(kr, (b, s, h, dr))], axis=-1)
        qf = jnp.concatenate([qn, qr], axis=-1)
        # pin head sharding: the broadcast of the shared rope key otherwise
        # propagates "replicated heads" into the whole attention region and
        # GSPMD all-gathers every (B,H,S,D) block — TBs/step at 128 heads
        qf = constrain(qf, "act_batch", None, "heads_act", None)
        k = constrain(k, "act_batch", None, "heads_act", None)
        v = constrain(v, "act_batch", None, "heads_act", None)
        o = blocks.call(
            "attention",
            jnp.swapaxes(qf, 1, 2),
            jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2),
            causal=True,
        )
        o = jnp.swapaxes(o, 1, 2)  # (B,S,H,dv)
        new_cache = None
        if cache is not None:
            new_cache = {
                "c": jax.lax.dynamic_update_slice_in_dim(
                    cache["c"], c.astype(cache["c"].dtype), 0, axis=1
                ),
                "kr": jax.lax.dynamic_update_slice_in_dim(
                    cache["kr"], kr[:, :, 0, :].astype(cache["kr"].dtype), 0,
                    axis=1,
                ),
            }
    o = o.reshape(b, s, h * dv)
    out = tp_out_einsum("bsq,qd->bsd", o.astype(cd), p["wo"].astype(cd), cd)
    return out, new_cache


def attention_forward(
    p, x, cfg, positions, cache=None, index=None, mode="train", pages=None
):
    if cfg.mla is not None:
        return mla_forward(p, x, cfg, positions, cache, index, mode, pages)
    return gqa_forward(p, x, cfg, positions, cache, index, mode, pages)
