"""Logical-axis sharding context.

Models annotate parameters and activations with *logical* axis names
("vocab", "embed", "heads", "experts", "act_batch", ...).  A sharding
context maps logical names to mesh axes; ``constrain`` applies
``with_sharding_constraint`` when a context is active and is a no-op
otherwise — so the same model code runs single-device (smoke tests),
under the 256-chip pod mesh, and under the 512-chip multi-pod mesh.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Iterator, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


def current_rules() -> dict[str, Any]:
    return getattr(_state, "rules", {})


@contextlib.contextmanager
def use_sharding(mesh: Mesh | None, rules: Mapping[str, Any]) -> Iterator[None]:
    prev = (current_mesh(), current_rules())
    _state.mesh = mesh
    _state.rules = dict(rules)
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def resolve_spec(
    axes: Sequence[str | None], rules: Mapping[str, Any] | None = None
) -> P:
    """Map logical axis names to a PartitionSpec via the active rules."""
    rules = current_rules() if rules is None else rules
    mesh_axes = []
    used: set[str] = set()
    for ax in axes:
        r = rules.get(ax) if ax is not None else None
        if r is None:
            mesh_axes.append(None)
            continue
        parts = (r,) if isinstance(r, str) else tuple(r)
        parts = tuple(p for p in parts if p not in used)
        used.update(parts)
        if not parts:
            mesh_axes.append(None)
        elif len(parts) == 1:
            mesh_axes.append(parts[0])
        else:
            mesh_axes.append(parts)
    return P(*mesh_axes)


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """Activation sharding constraint by logical axes; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = resolve_spec(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
