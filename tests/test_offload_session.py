"""repro.offload facade: session lifecycle, objectives, shims, plan_zoo.

Timing-sensitive tests drive sleep-based variants with >=5 ms gaps between
candidates so median-of-1 measurements rank them deterministically.
"""

import time

import pytest

from repro.core import blocks, planner
from repro.core.blocks import FunctionBlockRegistry
from repro.core.planner import (
    CostGuidedSearch,
    ExhaustiveSearch,
    GeneticSearch,
    Latency,
    MeasurementCache,
    PerfPerWatt,
    PlanStore,
    PowerMeter,
    SingleThenCombine,
    SubsetSpace,
    TimeProportionalPower,
    WeightedCost,
)
from repro.core.planner.strategies import PlanTrial
from repro.offload import OffloadSession, StageError, stored_binding


def _trial(pattern, seconds, energy):
    return PlanTrial(
        candidate=(), pattern=pattern, mapping={}, seconds=seconds,
        compile_seconds=0.0, speedup=1.0, cached=False,
        energy_joules=energy,
    )


# -- objectives ---------------------------------------------------------------


def test_objectives_disagree_on_synthetic_trials():
    """The fast pattern burns disproportionate power: Latency picks it,
    PerfPerWatt picks the economical one, from identical trials."""
    fast_hot = _trial(("a",), seconds=0.010, energy=5.0)
    slow_cool = _trial(("b",), seconds=0.012, energy=2.0)
    trials = [fast_hot, slow_cool]
    assert min(trials, key=Latency().score).pattern == ("a",)
    assert min(trials, key=PerfPerWatt().score).pattern == ("b",)
    # WeightedCost spans the two extremes
    assert min(trials, key=WeightedCost(1.0, 0.0).score).pattern == ("a",)
    assert min(trials, key=WeightedCost(0.0, 1.0).score).pattern == ("b",)


def test_perf_per_watt_falls_back_time_proportional():
    """Unmetered trials are charged seconds * fallback_watts, so a trial
    list without any energy readings ranks exactly like latency."""
    t1 = _trial(("a",), 0.010, None)
    t2 = _trial(("b",), 0.020, None)
    obj = PerfPerWatt(fallback_watts=100.0)
    assert obj.score(t1) == pytest.approx(1.0)
    assert min([t1, t2], key=obj.score) is t1


class _PatternPower(PowerMeter):
    """Test meter: per-candidate draw looked up by offload pattern."""

    def __init__(self, watts_by_pattern, default=1.0):
        self.watts_by_pattern = watts_by_pattern
        self.default = default

    def end(self, measurement, space=None, candidate=None):
        watts = self.watts_by_pattern.get(
            space.pattern(candidate), self.default
        )
        return measurement.seconds * watts


def _sleep_space(costs, names):
    def build(subset):
        seconds = costs[frozenset(subset)]

        def fn(_x):
            time.sleep(seconds)
            return _x

        return fn

    return SubsetSpace(build, names)


# offloading "blk" is 3x faster but drawn at 1000x the power
POWER_COSTS = {frozenset(): 0.018, frozenset({"blk"}): 0.006}
POWER_WATTS = {(): 1.0, ("blk",): 1000.0}


@pytest.mark.parametrize(
    "strategy_factory",
    [
        lambda: SingleThenCombine(),
        lambda: ExhaustiveSearch(),
        lambda: GeneticSearch(population=2, generations=2, seed=0),
        lambda: CostGuidedSearch(
            top_k=1, cost_fn=lambda space, cand, args: 0.0
        ),
    ],
    ids=["single_then_combine", "exhaustive", "genetic", "cost_guided"],
)
def test_every_strategy_selects_by_injected_objective(strategy_factory):
    """All four strategies pick the offload under Latency and the baseline
    under PerfPerWatt — same space, same measurements, different winner."""
    meter = _PatternPower(POWER_WATTS)
    cache = MeasurementCache(meter=meter)
    space = _sleep_space(POWER_COSTS, ["blk"])

    lat = strategy_factory().search(
        space, (0,), cache=cache, repeats=1, objective=Latency()
    )
    assert lat.best.pattern == ("blk",)
    assert lat.objective == "latency"

    # identical trials (replayed from the shared cache, energy included)
    ppw = strategy_factory().search(
        space, (0,), cache=cache, repeats=1, objective=PerfPerWatt()
    )
    assert ppw.evaluations == 0  # nothing re-measured
    assert ppw.best.pattern == ()
    assert ppw.objective == "perf_per_watt"
    assert ppw.best.energy_joules is not None


def test_time_proportional_meter_populates_energy():
    cache = MeasurementCache(meter=TimeProportionalPower(watts=50.0))
    space = _sleep_space(POWER_COSTS, ["blk"])
    rep = ExhaustiveSearch().search(space, (0,), cache=cache, repeats=1)
    for t in rep.trials:
        assert t.energy_joules == pytest.approx(t.seconds * 50.0)


# -- session lifecycle --------------------------------------------------------


def _toy_registry(delays=(("ref", 0.015), ("xla", 0.003))):
    reg = FunctionBlockRegistry()
    for target, delay in delays:
        reg.register(
            "norm", target,
            (lambda d: lambda x: (time.sleep(d), x)[1])(delay),
        )
    return reg


def _toy_binding_space(reg):
    return planner.BindingSpace(
        lambda: (lambda x: reg.call("norm", x)), registry=reg
    )


def test_session_stage_ordering_enforced():
    space = _toy_binding_space(_toy_registry())
    s = OffloadSession(space, args=(1,), repeats=1)
    with pytest.raises(StageError):
        s.discover()
    with pytest.raises(StageError):
        s.plan()
    with pytest.raises(StageError):
        s.verify()
    with pytest.raises(StageError):
        s.commit()
    s.analyze()
    with pytest.raises(StageError):
        s.plan()  # discover still missing
    s.discover()
    with pytest.raises(StageError):
        s.verify()  # plan still missing
    s.plan()
    s.verify()
    res = s.commit()
    assert res.mapping == {"norm": "xla"}
    assert res.numerics_ok is True


def test_session_binding_mode_from_blocks():
    """Binding mode: a step builder plus a block->targets map builds the
    BindingSpace inside the session."""
    reg = _toy_registry()
    s = OffloadSession(
        lambda: (lambda x: reg.call("norm", x)),
        args=(2,),
        blocks={"norm": ("ref", "xla")},
        registry=reg,
        repeats=1,
    )
    assert s.analyze() == {"norm": ("ref", "xla")}
    assert s.discover() == ["norm"]
    plan = s.plan()
    assert plan.mapping == {"norm": "xla"}
    res = s.commit()  # verify stage is optional
    assert res.numerics_ok is None
    assert res.fn(7) == 7


def test_session_store_roundtrip_zero_measurement(tmp_path):
    reg = _toy_registry()
    s1 = OffloadSession(
        _toy_binding_space(reg), args=(1,), repeats=1,
        store=str(tmp_path), key="sess:roundtrip",
    )
    r1 = s1.run(verify=False)
    assert not r1.from_store and r1.report is not None

    s2 = OffloadSession(
        _toy_binding_space(_toy_registry()), args=(1,), repeats=1,
        store=str(tmp_path), key="sess:roundtrip",
    )
    r2 = s2.run(verify=False)
    assert r2.from_store and r2.report is None
    assert s2.cache.misses == 0  # nothing measured
    assert r2.mapping == r1.mapping
    # attach: the production zero-search path binds the stored mapping
    blocks.registry.register("norm", "xla", lambda x: x)
    with OffloadSession.attach(str(tmp_path), "sess:roundtrip", quiet=True):
        assert blocks.registry.current_pattern()["norm"] == "xla"


def test_session_objective_threads_to_plan(tmp_path):
    meter = _PatternPower({(): 1.0, ("norm",): 1000.0})
    reg = _toy_registry()
    res = OffloadSession(
        _toy_binding_space(reg), args=(1,), repeats=1,
        objective=PerfPerWatt(), meter=meter,
        store=str(tmp_path), key="sess:ppw",
    ).run(verify=False)
    # offloading is faster but power-expensive: perf-per-watt keeps the
    # baseline target — pinned explicitly, so deployment can't silently
    # substitute the registry's default preference
    assert res.mapping == {"norm": "ref"}
    assert res.pattern == ()
    assert res.objective == "perf_per_watt"
    assert res.plan.objective == "perf_per_watt"
    stored = PlanStore(tmp_path).load("sess:ppw")
    assert stored is not None and stored.objective == "perf_per_watt"


def test_store_hit_requires_matching_objective(tmp_path):
    """A latency-selected stored plan must not satisfy a PerfPerWatt
    session — the store short-circuit re-searches instead."""
    reg = _toy_registry()
    r1 = OffloadSession(
        _toy_binding_space(reg), args=(1,), repeats=1,
        store=str(tmp_path), key="sess:objmatch",
    ).run(verify=False)
    assert r1.objective == "latency" and r1.mapping == {"norm": "xla"}

    meter = _PatternPower({(): 1.0, ("norm",): 1000.0})
    r2 = OffloadSession(
        _toy_binding_space(_toy_registry()), args=(1,), repeats=1,
        objective=PerfPerWatt(), meter=meter,
        store=str(tmp_path), key="sess:objmatch",
    ).run(verify=False)
    assert not r2.from_store  # re-searched under the new objective
    assert r2.mapping == {"norm": "ref"}

    # the same policy lives in core Planner.plan (the session delegates):
    # the store now holds r2's perf_per_watt plan, which must not satisfy
    # a latency planner
    from repro.core.planner import Planner

    p = Planner(
        _toy_binding_space(_toy_registry()),
        planner.ExhaustiveSearch(),
        store=PlanStore(tmp_path),
    )
    plan3, report3 = p.plan((1,), key="sess:objmatch", repeats=1)
    assert report3 is not None  # perf-per-watt store entry not reused
    assert plan3.objective == "latency"


def test_commit_never_persists_numerics_failed_plan(tmp_path):
    """A winner that fails the verify stage must not reach the store —
    attach would bind a numerically-wrong pattern in production."""
    reg = FunctionBlockRegistry()
    reg.register("norm", "ref", lambda x: (time.sleep(0.012), x)[1])
    reg.register("norm", "xla", lambda x: x + 1000)  # fast but WRONG
    s = OffloadSession(
        _toy_binding_space(reg), args=(1,), repeats=1,
        store=str(tmp_path), key="sess:badnum",
    )
    res = s.run()
    assert res.mapping == {"norm": "xla"}  # fastest by measurement
    assert res.numerics_ok is False
    assert PlanStore(tmp_path).load("sess:badnum") is None  # not persisted


def test_plan_store_rejects_slug_collision(tmp_path):
    """Distinct keys that slug to the same filename must not answer for
    each other."""
    store = PlanStore(tmp_path)
    plan = planner.Plan(
        key="zoo:x:train", space="sig", mapping={}, pattern=(),
        baseline_seconds=1.0, best_seconds=1.0, speedup=1.0,
        strategy="exhaustive", evaluations=1, search_seconds=0.0,
        fingerprint={},
    )
    store.save(plan)
    assert store.path_for("zoo:x:train") == store.path_for("zoo:x_train")
    assert store.load("zoo:x:train", match_fingerprint=False) is not None
    assert store.load("zoo:x_train", match_fingerprint=False) is None


def test_session_rejects_conflicting_meter():
    cache = MeasurementCache(meter=TimeProportionalPower(watts=10.0))
    with pytest.raises(ValueError, match="different PowerMeter"):
        OffloadSession(
            _toy_binding_space(_toy_registry()), args=(1,),
            cache=cache, meter=TimeProportionalPower(watts=99.0),
        )


# -- deprecation shims --------------------------------------------------------


def test_engine_adapt_delegates_to_session():
    from repro.apps import fourier
    from repro.core import OffloadEngine

    x = fourier.make_input(64)
    res = OffloadEngine().adapt(fourier.fourier_app_libcall, (x,), repeats=1)
    assert res.offload_pattern == ("fft2d",)
    assert res.numerics_ok
    assert res.verification.best.speedup > 1.0
    assert [d.entry.name for d in res.discoveries] == ["fft2d"]


def test_measure_block_pattern_shim_matches_session():
    from repro.core.engine import OffloadEngine

    reg_calls = {"n": 0}
    blocks.registry.register(
        "shim_probe", "slow",
        lambda x: (reg_calls.__setitem__("n", reg_calls["n"] + 1),
                   time.sleep(0.012), x)[-1],
    )
    blocks.registry.register(
        "shim_probe", "fast",
        lambda x: (reg_calls.__setitem__("n", reg_calls["n"] + 1), x)[-1],
    )

    def builder():
        return lambda x: blocks.call("shim_probe", x)

    patterns = [{"shim_probe": "slow"}, {"shim_probe": "fast"}]
    best, results = OffloadEngine().measure_block_pattern(
        builder, patterns, (1,), repeats=1
    )
    assert best == {"shim_probe": "fast"}
    assert [p for p, _ in results] == patterns


def test_attach_is_the_only_production_bind_path(tmp_path):
    """The historical launch.plans shims are gone: stored_binding +
    OffloadSession.attach are the one production loading surface."""
    reg = _toy_registry()
    OffloadSession(
        _toy_binding_space(reg), args=(1,), repeats=1,
        store=str(tmp_path), key="shim:plans",
    ).run(verify=False)
    blocks.registry.register("norm", "xla", lambda x: x)
    assert stored_binding(str(tmp_path), "shim:plans") == {"norm": "xla"}
    with OffloadSession.attach(str(tmp_path), "shim:plans", quiet=True):
        assert blocks.registry.current_pattern()["norm"] == "xla"
    with pytest.raises(ModuleNotFoundError):
        import repro.launch.plans  # noqa: F401 — deleted shim stays deleted


# -- kernel-shelf fingerprint -------------------------------------------------


def test_shelf_fingerprint_changes_with_source():
    reg1 = FunctionBlockRegistry()
    reg1.register("b", "xla", _toy_registry)  # any fn with source
    reg2 = FunctionBlockRegistry()
    reg2.register("b", "xla", _toy_binding_space)  # different source
    assert reg1.shelf_fingerprint() != reg2.shelf_fingerprint()
    # restricting to an unrelated block set ignores the difference
    assert reg1.shelf_fingerprint(blocks=[]) == reg2.shelf_fingerprint(
        blocks=[]
    )


def test_kernel_rewrite_invalidates_stored_plan(tmp_path):
    """A plan whose fingerprint carries a different kernel-shelf hash must
    not load (the kernels were rewritten since it was verified)."""
    fp = planner.environment_fingerprint()
    assert "kernel_shelf" in fp  # repro.kernels is imported in this suite
    store = PlanStore(tmp_path)
    plan = planner.Plan(
        key="shelf", space="sig", mapping={}, pattern=(),
        baseline_seconds=1.0, best_seconds=1.0, speedup=1.0,
        strategy="exhaustive", evaluations=1, search_seconds=0.0,
        fingerprint=fp,
    )
    store.save(plan)
    assert store.load("shelf") is not None
    stale = planner.Plan.from_json(plan.to_json())
    stale.fingerprint = dict(fp, kernel_shelf="0" * 16)
    store.save(stale)
    assert store.load("shelf") is None


# -- GA cost seeding ----------------------------------------------------------


def test_ga_seeds_population_from_cost_model():
    """With seed_from_cost, generation zero contains the cost model's top
    pick instead of random genomes."""
    costs = {
        frozenset(): 0.030,
        frozenset({"a"}): 0.024,
        frozenset({"b"}): 0.012,
        frozenset({"a", "b"}): 0.018,
    }
    est = {(0, 0): 9.0, (1, 0): 3.0, (0, 1): 1.0, (1, 1): 2.0}
    asked = []

    def cost_fn(space, cand, args):
        asked.append(cand)
        return est[cand]

    ga = GeneticSearch(
        population=2, generations=1, seed=0,
        seed_from_cost=True, cost_fn=cost_fn,
    )
    rep = ga.search(_sleep_space(costs, ["a", "b"]), (0,),
                    cache=MeasurementCache(), repeats=1)
    assert asked  # the static model was consulted
    # population = [baseline, cost-model best] -> both were measured
    measured = {t.candidate for t in rep.trials}
    assert (0, 1) in measured
    assert rep.best.pattern == ("b",)


def test_ga_cost_seeding_falls_back_on_failure():
    def broken(space, cand, args):
        raise RuntimeError("untraceable")

    ga = GeneticSearch(
        population=2, generations=1, seed=0,
        seed_from_cost=True, cost_fn=broken,
    )
    with pytest.warns(UserWarning, match="seeding randomly"):
        rep = ga.search(
            _sleep_space(POWER_COSTS, ["blk"]), (0,),
            cache=MeasurementCache(), repeats=1,
        )
    assert rep.best.pattern == ("blk",)


# -- plan_zoo -----------------------------------------------------------------


@pytest.mark.slow
def test_plan_zoo_roundtrip_through_store(tmp_path):
    """plan_zoo searches a real decode step per cell, persists a plan, and
    a second sweep resolves every cell from the store with zero search."""
    cells = [("llama3.2-1b", "decode")]
    res = OffloadSession.plan_zoo(
        str(tmp_path), cells, targets=("ref", "xla"),
        batch=1, seq=8, layers=2, repeats=1,
    )
    assert set(res) == {("llama3.2-1b", "decode")}
    first = res[("llama3.2-1b", "decode")]
    assert not first.from_store
    assert first.plan.key == "zoo:llama3.2-1b:decode"

    store = PlanStore(tmp_path)
    assert store.keys() == ["zoo:llama3.2-1b:decode"]
    loaded = store.load("zoo:llama3.2-1b:decode")
    assert loaded is not None
    assert loaded.mapping == first.mapping
    assert "kernel_shelf" in loaded.fingerprint

    res2 = OffloadSession.plan_zoo(
        str(tmp_path), cells, targets=("ref", "xla"),
        batch=1, seq=8, layers=2, repeats=1,
    )
    second = res2[("llama3.2-1b", "decode")]
    assert second.from_store and second.report is None
    assert second.mapping == first.mapping
