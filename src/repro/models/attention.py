"""Attention mixers: GQA (llama-style) and MLA (DeepSeek-V2), with KV caches.

Three execution paths per mixer:
  * train/prefill: full-sequence causal attention through the FunctionBlock
    registry ("attention" block: ref = naive softmax einsum, xla = chunked
    online-softmax (memory-safe at 32k+), pallas = flash kernel);
  * decode: single-token attention over the cache — einsum-based, never
    materialises repeated KV heads; MLA decodes in the *absorbed* form
    (scores and values computed directly against the compressed latent
    cache, the MLA serving trick).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import blocks

# chunked attention lives on the kernel shelf now (registered there as
# ("attention", "xla") — import-order independent); re-exported for
# backward compatibility
from repro.kernels.attention_xla import attention_chunked  # noqa: F401

# page-table plumbing shared by both paged_attention shelf targets and the
# serve engine's page insert; re-exported from the kernel layer
from repro.kernels.paged_attention import (  # noqa: F401
    gather_kv_pages,
    insert_pages,
    scatter_chunk_pages,
    scatter_token_pages,
)
from repro.models.layers import rmsnorm, rope, tp_out_einsum
from repro.models.params import ParamMeta
from repro.sharding.utils import constrain

_NEG = -1e30


# -- parameter metas -----------------------------------------------------------


def attn_metas(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    dt = cfg.param_dtype
    if cfg.mla:
        m = cfg.mla
        h = cfg.n_heads
        return {
            "wq": ParamMeta(
                (d, h * (m.qk_nope_head_dim + m.qk_rope_head_dim)),
                ("embed", "heads"), dt,
            ),
            "w_dkv": ParamMeta((d, m.kv_lora_rank), ("embed", None), dt),
            "kv_norm": ParamMeta((m.kv_lora_rank,), (None,), dt, init="ones"),
            "w_uk": ParamMeta(
                (m.kv_lora_rank, h * m.qk_nope_head_dim), (None, "heads"), dt
            ),
            "w_uv": ParamMeta(
                (m.kv_lora_rank, h * m.v_head_dim), (None, "heads"), dt
            ),
            "w_kr": ParamMeta((d, m.qk_rope_head_dim), ("embed", None), dt),
            "wo": ParamMeta((h * m.v_head_dim, d), ("heads", "embed"), dt),
        }
    return {
        "wq": ParamMeta((d, cfg.n_heads * cfg.d_head), ("embed", "heads"), dt),
        "wk": ParamMeta((d, cfg.n_kv_heads * cfg.d_head), ("embed", "kv_heads"), dt),
        "wv": ParamMeta((d, cfg.n_kv_heads * cfg.d_head), ("embed", "kv_heads"), dt),
        "wo": ParamMeta((cfg.n_heads * cfg.d_head, d), ("heads", "embed"), dt),
    }


def cache_metas(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """Per-layer KV cache metas (leading layer axis added by the LM)."""
    ct = cfg.compute_dtype
    if cfg.mla:
        m = cfg.mla
        return {
            "c": ParamMeta(
                (batch, max_len, m.kv_lora_rank),
                ("act_batch", "cache_seq", None), ct, init="zeros",
            ),
            "kr": ParamMeta(
                (batch, max_len, m.qk_rope_head_dim),
                ("act_batch", "cache_seq", None), ct, init="zeros",
            ),
        }
    return {
        "k": ParamMeta(
            (batch, cfg.n_kv_heads, max_len, cfg.d_head),
            ("act_batch", "kv_heads_act", "cache_seq", None), ct, init="zeros",
        ),
        "v": ParamMeta(
            (batch, cfg.n_kv_heads, max_len, cfg.d_head),
            ("act_batch", "kv_heads_act", "cache_seq", None), ct, init="zeros",
        ),
    }


def cache_metas_paged(
    cfg: ArchConfig, n_pages_total: int, page_size: int
) -> dict:
    """Block-paged pool layout: the contiguous layout with the batch axis
    reinterpreted as a *shared page pool* (``n_pages_total`` includes the
    null page) and the sequence axis shrunk to one page.  Slot identity
    moves out of the storage entirely — it lives in the page table the
    decode program gathers through — so pool axes carry no batch/sequence
    sharding names (multi-device serving shards slots, not pages)."""
    out = {}
    for key, m in cache_metas(cfg, n_pages_total, page_size).items():
        axes = tuple(
            None if a in ("act_batch", "cache_seq") else a for a in m.axes
        )
        out[key] = ParamMeta(m.shape, axes, m.dtype, m.init, m.scale)
    return out


def cache_seq_axes(cfg: ArchConfig) -> dict:
    """Leaf name -> sequence-axis position in the per-layer contiguous
    cache leaf (batch leading).  The same position holds the within-page
    axis in the paged pool layout — the engine's page-insert uses this to
    split a prefilled slot cache into whole pages."""
    return {
        key: m.axes.index("cache_seq")
        for key, m in cache_metas(cfg, 1, 1).items()
    }


# -- decode attention over a cache ----------------------------------------------
#
# ``index`` is per-slot: shape (B,), the write position of the *first* new
# token in each batch row's cache.  Continuous-batching serving
# (``repro.serve``) staggers requests across slots, so every row decodes at
# its own position; the single-sequence case is just the vector with equal
# entries.  Decode is the S=1 case of the general cached-extension step
# (S > 1 is chunked prefill: a budget-sized prompt chunk appended against
# the cache, causal within the chunk).


def _update_slot_rows(cache: jax.Array, update: jax.Array, index: jax.Array,
                      axis: int) -> jax.Array:
    """Per-batch-row ``dynamic_update_slice`` at each row's own position.

    ``cache``/``update`` share a leading batch axis; ``axis`` is the sequence
    axis *including* the batch axis.  ``index`` is (B,) int32.
    """
    return jax.vmap(
        lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
            c, u, i, axis=axis - 1
        )
    )(cache, update, index)


def decode_attention_gqa(
    q: jax.Array,  # (B, H, S, D) — S=1 decode, S>1 chunked-prefill extend
    k_cache: jax.Array,  # (B, KH, Smax, D)
    v_cache: jax.Array,
    index: jax.Array,  # (B,): each row's first new-token position
) -> jax.Array:
    b, h, s, d = q.shape
    _, kh, smax, _ = k_cache.shape
    g = h // kh
    qg = q.reshape(b, kh, g, s, d).astype(jnp.float32) / (d ** 0.5)
    sc = jnp.einsum("bkgqd,bktd->bkgqt", qg, k_cache.astype(jnp.float32))
    qpos = index[:, None] + jnp.arange(s)  # (B, S)
    valid = (
        jnp.arange(smax)[None, None, None, None, :]
        <= qpos[:, None, None, :, None]
    )
    sc = jnp.where(valid, sc, _NEG)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, s, d).astype(q.dtype)


# -- the GQA mixer ----------------------------------------------------------------


def gqa_forward(
    p: dict,
    x: jax.Array,  # (B, S, D)
    cfg: ArchConfig,
    positions: jax.Array,  # (B, S)
    cache: dict | None = None,
    index: jax.Array | None = None,
    mode: str = "train",
    pages: jax.Array | None = None,
):
    b, s, d = x.shape
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,dq->bsq", xc, p["wq"].astype(cd)).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,dq->bsq", xc, p["wk"].astype(cd)).reshape(b, s, kh, dh)
    v = jnp.einsum("bsd,dq->bsq", xc, p["wv"].astype(cd)).reshape(b, s, kh, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "act_batch", None, "heads_act", None)
    k = constrain(k, "act_batch", None, "kv_heads_act", None)

    qt = jnp.swapaxes(q, 1, 2)  # (B,H,S,dh)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)

    if mode in ("decode", "extend"):
        assert cache is not None and index is not None
        if pages is not None:
            if s == 1:
                k_cache = scatter_token_pages(
                    cache["k"], kt[:, :, 0, :], pages, index, seq_axis=2
                )
                v_cache = scatter_token_pages(
                    cache["v"], vt[:, :, 0, :], pages, index, seq_axis=2
                )
            else:  # extend: S-token chunk, causal within the chunk
                k_cache = scatter_chunk_pages(
                    cache["k"], kt, pages, index, seq_axis=2
                )
                v_cache = scatter_chunk_pages(
                    cache["v"], vt, pages, index, seq_axis=2
                )
            # the attention read is a planner-searchable function block:
            # xla = rolled page-walk gather + dense softmax, pallas = the
            # fused page-walk kernel (no gathered view)
            o = blocks.call(
                "paged_attention", qt, k_cache, v_cache, pages, index
            )
            new_cache = {"k": k_cache, "v": v_cache}
        else:
            k_cache = _update_slot_rows(
                cache["k"], kt.astype(cache["k"].dtype), index, axis=2
            )
            v_cache = _update_slot_rows(
                cache["v"], vt.astype(cache["v"].dtype), index, axis=2
            )
            o = decode_attention_gqa(qt, k_cache, v_cache, index)
            new_cache = {"k": k_cache, "v": v_cache}
    else:
        o = blocks.call("attention", qt, kt, vt, causal=True)
        new_cache = None
        if cache is not None:  # prefill: persist kv
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], kt.astype(cache["k"].dtype), 0, axis=2
                ),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], vt.astype(cache["v"].dtype), 0, axis=2
                ),
            }
    o = jnp.swapaxes(o, 1, 2).reshape(b, s, h * dh)
    o = constrain(o, "act_batch", None, "heads_act")
    out = tp_out_einsum("bsq,qd->bsd", o.astype(cd), p["wo"].astype(cd), cd)
    return out, new_cache


# -- the MLA mixer -----------------------------------------------------------------


def mla_forward(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array,
    cache: dict | None = None,
    index: jax.Array | None = None,
    mode: str = "train",
    pages: jax.Array | None = None,
):
    m = cfg.mla
    b, s, d = x.shape
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = jnp.einsum("bsd,dq->bsq", xc, p["wq"].astype(cd))
    q = q.reshape(b, s, h, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = rope(qr, positions, cfg.rope_theta)

    c = jnp.einsum("bsd,dr->bsr", xc, p["w_dkv"].astype(cd))
    c = rmsnorm(p["kv_norm"], c, cfg.norm_eps).astype(cd)
    kr = jnp.einsum("bsd,dr->bsr", xc, p["w_kr"].astype(cd))
    kr = rope(kr[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,dr)

    if mode in ("decode", "extend"):
        assert cache is not None and index is not None
        # absorbed decode: score = q_abs . c  +  qr . kr — structurally
        # GQA with one KV head whose keys/values are the latent cache
        w_uk = p["w_uk"].astype(cd).reshape(m.kv_lora_rank, h, dn)
        q_abs = jnp.einsum("bshn,rhn->bshr", qn, w_uk)  # (B,S,H,r)
        scale = 1.0 / ((dn + dr) ** 0.5)
        if pages is not None:
            if s == 1:
                c_cache = scatter_token_pages(
                    cache["c"], c[:, 0, :], pages, index, seq_axis=1
                )
                kr_cache = scatter_token_pages(
                    cache["kr"], kr[:, 0, 0, :], pages, index, seq_axis=1
                )
            else:  # extend chunk
                c_cache = scatter_chunk_pages(
                    cache["c"], c, pages, index, seq_axis=1
                )
                kr_cache = scatter_chunk_pages(
                    cache["kr"], kr[:, :, 0, :], pages, index, seq_axis=1
                )
            ctx = blocks.call(
                "paged_attention",
                jnp.swapaxes(q_abs, 1, 2),  # (B,H,S,r)
                c_cache[:, None],  # latent pool as 1-KV-head (P,1,ps,r)
                c_cache[:, None],  # ...and it doubles as the value pool
                pages, index,
                q_rope=jnp.swapaxes(qr, 1, 2),  # (B,H,S,dr)
                kr_pool=kr_cache[:, None],
                scale=scale,
            )
            ctx = jnp.swapaxes(ctx, 1, 2)  # (B,S,H,r)
        else:
            c_cache = _update_slot_rows(
                cache["c"], c.astype(cache["c"].dtype), index, axis=1
            )
            kr_cache = _update_slot_rows(
                cache["kr"], kr[:, :, 0, :].astype(cache["kr"].dtype), index,
                axis=1,
            )
            c_view, kr_view = c_cache, kr_cache
            s_nope = jnp.einsum(
                "bshr,btr->bhst", q_abs.astype(jnp.float32),
                c_view.astype(jnp.float32),
            )
            s_rope = jnp.einsum(
                "bshr,btr->bhst", qr.astype(jnp.float32),
                kr_view.astype(jnp.float32),
            )
            sc = (s_nope + s_rope) * scale  # (B,H,S,T)
            smax = c_view.shape[1]
            qpos = index[:, None] + jnp.arange(s)  # (B, S)
            valid = (
                jnp.arange(smax)[None, None, None, :]
                <= qpos[:, None, :, None]
            )
            sc = jnp.where(valid, sc, _NEG)
            pattn = jax.nn.softmax(sc, axis=-1)
            ctx = jnp.einsum(
                "bhst,btr->bshr", pattn, c_view.astype(jnp.float32)
            )  # weighted latent
        w_uv = p["w_uv"].astype(cd).reshape(m.kv_lora_rank, h, dv)
        o = jnp.einsum("bshr,rhv->bshv", ctx.astype(cd), w_uv)
        new_cache = {"c": c_cache, "kr": kr_cache}
    else:
        kn = jnp.einsum("bsr,rq->bsq", c, p["w_uk"].astype(cd))
        kn = kn.reshape(b, s, h, dn)
        v = jnp.einsum("bsr,rq->bsq", c, p["w_uv"].astype(cd))
        v = v.reshape(b, s, h, dv)
        k = jnp.concatenate([kn, jnp.broadcast_to(kr, (b, s, h, dr))], axis=-1)
        qf = jnp.concatenate([qn, qr], axis=-1)
        # pin head sharding: the broadcast of the shared rope key otherwise
        # propagates "replicated heads" into the whole attention region and
        # GSPMD all-gathers every (B,H,S,D) block — TBs/step at 128 heads
        qf = constrain(qf, "act_batch", None, "heads_act", None)
        k = constrain(k, "act_batch", None, "heads_act", None)
        v = constrain(v, "act_batch", None, "heads_act", None)
        o = blocks.call(
            "attention",
            jnp.swapaxes(qf, 1, 2),
            jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2),
            causal=True,
        )
        o = jnp.swapaxes(o, 1, 2)  # (B,S,H,dv)
        new_cache = None
        if cache is not None:
            new_cache = {
                "c": jax.lax.dynamic_update_slice_in_dim(
                    cache["c"], c.astype(cache["c"].dtype), 0, axis=1
                ),
                "kr": jax.lax.dynamic_update_slice_in_dim(
                    cache["kr"], kr[:, :, 0, :].astype(cache["kr"].dtype), 0,
                    axis=1,
                ),
            }
    o = o.reshape(b, s, h * dv)
    out = tp_out_einsum("bsq,qd->bsd", o.astype(cd), p["wo"].astype(cd), cd)
    return out, new_cache


def attention_forward(
    p, x, cfg, positions, cache=None, index=None, mode="train", pages=None
):
    if cfg.mla is not None:
        return mla_forward(p, x, cfg, positions, cache, index, mode, pages)
    return gqa_forward(p, x, cfg, positions, cache, index, mode, pages)
