"""repro.obs: tracer, metrics registry, exporters, engine integration.

The observability layer's contract is threefold: recording is thread-safe
and bounded (the serve loop never blocks on its own telemetry), a
*disabled* tracer costs nothing on the hot path, and every exported view
(Chrome trace, Prometheus text, the legacy telemetry aggregates) is fed by
the same observations — parity between views is asserted, not hoped for.
"""

import json
import threading
import time
import urllib.request

import pytest

from repro.core.planner import MeasurementCache
from repro.obs import (
    NULL_SPAN,
    MetricsRegistry,
    MetricsServer,
    Tracer,
    exponential_buckets,
    get_tracer,
    set_tracer,
)
from repro.obs import timeline


# -- tracer -------------------------------------------------------------------


def test_span_context_records_duration():
    tr = Tracer()
    with tr.span("work", step=3):
        time.sleep(0.002)
    (rec,) = tr.records()
    assert rec.name == "work"
    assert rec.ph == "X"
    assert rec.args == {"step": 3}
    assert rec.duration >= 0.002


def test_retroactive_span_and_instant_event():
    tr = Tracer()
    t0 = time.perf_counter()
    tr.add_span("queue", t0, t0 + 0.5, tid=7, request=1)
    tr.event("preempt", tid=7, request=1)
    spans = tr.records()
    assert [r.ph for r in spans] == ["X", "i"]
    assert spans[0].tid == 7 and spans[0].duration == pytest.approx(0.5)
    # a clock-skewed t1 < t0 clamps to zero duration instead of exporting
    # a negative dur (which trace viewers reject)
    tr.add_span("skewed", t0 + 1.0, t0 + 0.5)
    assert tr.records()[-1].duration == 0.0


def test_ring_buffer_drops_oldest_and_counts():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.event(f"e{i}")
    assert len(tr) == 4
    assert [r.name for r in tr.records()] == ["e6", "e7", "e8", "e9"]
    assert tr.dropped == 6
    assert tr.to_chrome()["otherData"]["dropped_records"] == 6
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_disabled_tracer_is_free():
    tr = Tracer(enabled=False)
    # the no-op span is one shared singleton — no allocation per call
    assert tr.span("a") is NULL_SPAN
    assert tr.span("b", tid=9, big="arg") is NULL_SPAN
    with tr.span("c"):
        pass
    tr.event("x")
    tr.add_span("y", 0.0, 1.0)
    assert len(tr) == 0


def test_default_process_tracer_disabled_and_swappable():
    assert get_tracer().enabled is False
    installed = set_tracer(Tracer())
    try:
        assert get_tracer() is installed
        with get_tracer().span("visible"):
            pass
        assert [r.name for r in installed.records()] == ["visible"]
    finally:
        set_tracer(None)
    assert get_tracer().enabled is False


def test_threaded_recording_keeps_every_span_ordered():
    """Concurrent recorders (the DeviceParallelExecutor shape): no record
    is lost, and each thread's own spans stay in its program order."""
    tr = Tracer()
    n_threads, per_thread = 8, 50
    barrier = threading.Barrier(n_threads)  # all threads alive at once,
    # so the OS can't recycle thread idents across workers

    def work(k):
        barrier.wait()
        for i in range(per_thread):
            with tr.span("job", worker=k, seq=i):
                pass

    threads = [
        threading.Thread(target=work, args=(k,)) for k in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    recs = tr.records()
    assert len(recs) == n_threads * per_thread
    by_worker = {}
    for r in sorted(recs, key=lambda r: r.t0):
        by_worker.setdefault(r.args["worker"], []).append(r.args["seq"])
    assert set(by_worker) == set(range(n_threads))
    for seqs in by_worker.values():
        assert seqs == sorted(seqs)
    # distinct threads land on distinct tracks
    assert len({r.tid for r in recs}) == n_threads


def test_chrome_export_is_viewer_valid(tmp_path):
    tr = Tracer()
    tr.name_track(0x5E54_0001, "req 1")
    t0 = time.perf_counter()
    tr.add_span("queue", t0, t0 + 0.01, tid=0x5E54_0001, request=1)
    with tr.span("decode", batch=2):
        pass
    tr.event("complete", tid=0x5E54_0001, request=1)
    doc = tr.to_chrome()
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    # metadata names the virtual request track
    meta = [e for e in events if e["ph"] == "M"]
    assert meta and meta[0]["args"]["name"] == "req 1"
    # the exported structure passes the timeline validator and is real JSON
    path = tmp_path / "trace.json"
    tr.write_chrome(str(path))
    loaded = timeline.load_events(str(path))
    assert timeline.validate(loaded) == []
    spans = [e for e in loaded if e["ph"] == "X"]
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in spans)
    # spans sorted by start time, timestamps in µs relative to the epoch
    assert [e["ts"] for e in spans] == sorted(e["ts"] for e in spans)


def test_jsonl_round_trip(tmp_path):
    tr = Tracer()
    with tr.span("a"):
        pass
    tr.event("b")
    path = tmp_path / "trace.jsonl"
    tr.write_jsonl(str(path))
    events = timeline.load_events(str(path))
    assert [e["name"] for e in events] == ["a", "b"]
    assert timeline.validate(events) == []


def test_timeline_cli_check(tmp_path, capsys):
    tr = Tracer()
    tr.name_track(5, "req 5")
    t0 = time.perf_counter()
    tr.add_span("queue", t0, t0 + 0.01, tid=5, request=5)
    tr.add_span("prefill", t0 + 0.01, t0 + 0.03, tid=5, request=5)
    good = tmp_path / "good.json"
    tr.write_chrome(str(good))
    assert timeline.main([str(good), "--check"]) == 0
    out = capsys.readouterr().out
    assert "queue" in out and "critical path" in out

    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(
        {"traceEvents": [{"ph": "X", "ts": -5, "dur": "oops"}]}
    ))
    assert timeline.main([str(bad), "--check"]) == 1


# -- metrics registry ---------------------------------------------------------


def test_counter_gauge_basics_and_kind_safety():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "requests")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)  # counters only go up
    g = reg.gauge("depth", "queue depth")
    g.set(7)
    g.dec(2)
    assert g.value == 5
    with pytest.raises(TypeError):
        c.set(3)  # set() is a gauge operation
    # idempotent re-register returns the same family; schema drift raises
    assert reg.counter("requests_total") is c
    with pytest.raises(ValueError):
        reg.gauge("requests_total")
    with pytest.raises(ValueError):
        reg.counter("requests_total", labelnames=("phase",))
    with pytest.raises(ValueError):
        reg.counter("bad name!")


def test_labeled_family_children_render():
    reg = MetricsRegistry()
    fam = reg.counter("phase_tokens_total", "tokens", labelnames=("phase",))
    fam.labels(phase="prefill").inc(10)
    fam.labels(phase="decode").inc(32)
    assert fam.labels(phase="decode") is fam.labels(phase="decode")
    with pytest.raises(KeyError):
        fam.labels(stage="decode")
    with pytest.raises(KeyError):
        fam.inc()  # labeled family has no sole child
    text = reg.render_prometheus()
    assert '# TYPE phase_tokens_total counter' in text
    assert 'phase_tokens_total{phase="decode"} 32' in text
    assert 'phase_tokens_total{phase="prefill"} 10' in text


def test_prometheus_escaping():
    reg = MetricsRegistry()
    reg.counter(
        "odd_total", 'help with \\ and\nnewline', labelnames=("k",)
    ).labels(k='va"l\\ue\n').inc()
    text = reg.render_prometheus()
    assert '# HELP odd_total help with \\\\ and\\nnewline' in text
    assert 'odd_total{k="va\\"l\\\\ue\\n"} 1' in text


def test_histogram_buckets_cumulative_and_sums():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "latency", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.05, 0.5, 5.0):
        h.observe(v)
    text = reg.render_prometheus()
    assert 'lat_seconds_bucket{le="0.01"} 1' in text
    assert 'lat_seconds_bucket{le="0.1"} 3' in text
    assert 'lat_seconds_bucket{le="1"} 4' in text
    assert 'lat_seconds_bucket{le="+Inf"} 5' in text
    assert 'lat_seconds_count 5' in text
    sum_line = [
        line for line in text.splitlines()
        if line.startswith("lat_seconds_sum")
    ][0]
    assert float(sum_line.split()[-1]) == pytest.approx(5.605)
    with pytest.raises(ValueError):
        exponential_buckets(start=0.0)
    assert len(exponential_buckets(1e-3, 2.0, 4)) == 4


def test_registry_reset_keeps_child_handles_valid():
    reg = MetricsRegistry()
    c = reg.counter("n_total", "n", labelnames=("k",)).labels(k="a")
    h = reg.histogram("h_seconds", "h", buckets=(1.0,))
    c.inc(3)
    h.observe(0.5)
    reg.reset()
    assert c.value == 0
    assert 'h_seconds_count 0' in reg.render_prometheus()
    c.inc()  # the pre-reset handle still feeds the family
    assert 'n_total{k="a"} 1' in reg.render_prometheus()


def test_metrics_server_serves_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("up_total", "liveness").inc()
    srv = MetricsServer(reg, port=0)
    try:
        with urllib.request.urlopen(srv.url, timeout=5) as resp:
            body = resp.read().decode()
            ctype = resp.headers["Content-Type"]
        assert "up_total 1" in body
        assert "text/plain" in ctype
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                srv.url.replace("/metrics", "/other"), timeout=5
            )
    finally:
        srv.close()


# -- planner / metering integration ------------------------------------------


class _FakeSpace:
    def signature(self):
        return ("obs-test",)

    def canonical(self, cand):
        return tuple(sorted(cand))

    def build(self, cand):
        return lambda x: x * 2


def test_measurement_cache_metrics_parity():
    reg = MetricsRegistry()
    cache = MeasurementCache(metrics=reg)
    space = _FakeSpace()
    cache.measure(space, ["a"], (3,), repeats=1, warmup=0)
    cache.measure(space, ["a"], (3,), repeats=1, warmup=0)
    cache.measure(space, ["b"], (3,), repeats=1, warmup=0)
    assert (cache.hits, cache.misses) == (1, 2)
    text = reg.render_prometheus()
    assert "planner_cache_hits_total 1" in text
    assert "planner_cache_misses_total 2" in text


def test_executor_trial_spans_across_worker_threads():
    from repro.metering.executors import DeviceParallelExecutor, MeasureJob

    tr = set_tracer(Tracer())
    try:
        jobs = [
            MeasureJob(
                fn=lambda _x: time.sleep(0.002),
                args=(1,),
                repeats=1,
                warmup=0,
                candidate=("blk",),
            )
            for _ in range(4)
        ]
        ex = DeviceParallelExecutor(devices=[None, None], max_workers=2)
        out = ex.run(jobs)
        assert len(out) == 4
        trials = [r for r in tr.records() if r.name == "trial"]
        assert len(trials) == 4
        assert all(r.args["candidate"] == "('blk',)" for r in trials)
        # two workers -> the spans land on (at most) two distinct tracks
        assert 1 <= len({r.tid for r in trials}) <= 2
    finally:
        set_tracer(None)


def test_session_stage_spans():
    from repro.core.planner import SubsetSpace
    from repro.offload import OffloadSession

    space = SubsetSpace(lambda subset: (lambda x: x), ["blk"])
    tr = Tracer()
    session = OffloadSession(space, args=(1,), repeats=1, tracer=tr)
    session.run(verify=True)
    stages = [r.name for r in tr.records() if r.name.startswith("stage:")]
    assert stages == [
        "stage:analyze", "stage:discover", "stage:plan",
        "stage:verify", "stage:commit",
    ]


# -- serve-engine integration -------------------------------------------------


@pytest.fixture(scope="module")
def traced_engine():
    """One small engine, 3 requests served under an enabled tracer."""
    from repro.configs import get_config
    from repro.serve import Request, ServeEngine

    cfg = get_config("llama3.2-1b").reduced()
    engine = ServeEngine(
        cfg, n_slots=2, max_len=64, seed=0, tracer=Tracer()
    )
    for i in range(3):
        engine.submit(Request([1 + i, 2, 3, 4, 5], max_new_tokens=4))
    completions = engine.run_until_idle(max_steps=500)
    return engine, completions


def test_engine_request_lifecycle_spans(traced_engine, tmp_path):
    engine, completions = traced_engine
    assert len(completions) == 3
    per_request = {}
    for rec in engine.tracer.records():
        req = (rec.args or {}).get("request")
        if req is not None:
            per_request.setdefault(req, set()).add(rec.name)
    assert set(per_request) == {0, 1, 2}
    for kinds in per_request.values():
        # the acceptance gate: every request's track carries its whole
        # lifecycle, at least queue / kv-alloc / prefill / decode
        assert {"queue", "kv-alloc", "prefill", "decode"} <= kinds
        assert "complete" in kinds
    path = tmp_path / "engine_trace.json"
    engine.tracer.write_chrome(str(path))
    assert timeline.validate(timeline.load_events(str(path))) == []


def test_engine_metrics_parity_with_telemetry(traced_engine):
    """The registry counters and the legacy PhaseTelemetry aggregates are
    two views of the same observations — they must agree exactly."""
    engine, completions = traced_engine
    reg = engine.registry
    for phase in ("prefill", "decode"):
        tele = engine.telemetry[phase]
        calls = reg.get("serve_phase_calls_total").labels(phase=phase)
        seconds = reg.get("serve_phase_seconds_total").labels(phase=phase)
        tokens = reg.get("serve_phase_tokens_total").labels(phase=phase)
        assert calls.value == tele.calls
        assert seconds.value == pytest.approx(tele.seconds)
        assert tokens.value == tele.tokens
    assert reg.get("serve_requests_submitted_total").value == 3
    assert reg.get("serve_requests_completed_total").value == 3
    assert reg.get("serve_tokens_generated_total").value == sum(
        len(c.tokens) for c in completions
    )
    # the step histogram is the monitor's own observations, written through
    assert reg.get("serve_step_seconds").value == engine.monitor.steps
    text = reg.render_prometheus()
    assert 'serve_phase_calls_total{phase="decode"}' in text
    assert 'serve_step_seconds_bucket{le="+Inf"}' in text


def test_engine_ttft_admitted_and_queue_wait(traced_engine):
    _, completions = traced_engine
    for c in completions:
        assert c.admitted_at is not None
        assert c.queue_wait >= 0.0
        assert 0.0 <= c.ttft_admitted <= c.ttft
        assert c.ttft == pytest.approx(c.queue_wait + c.ttft_admitted)


def test_engine_program_stats_and_no_span_lint(traced_engine):
    engine, _ = traced_engine
    stats = engine.programs.stats()
    assert stats["decode"]["calls"] > 0
    assert stats["decode"]["retraces"] == 0
    assert stats["decode"]["compile_seconds"] > 0
    assert stats["decode"]["span_kind"] == "decode"
    # every engine-registered program carries a span kind, so the obs info
    # lint stays quiet on the engine itself
    assert not [d for d in engine.lint() if d.code == "no-span"]
    # ...but a traced ProgramSet with an uninstrumented program is flagged
    from repro.analysis.hotpath import ProgramSet

    ps = ProgramSet()
    ps.tracer = engine.tracer
    ps.register("orphan", lambda x: x)
    ps.observe("orphan", 1)
    diags = ps.lint()
    assert [d.code for d in diags] == ["no-span"]
    assert diags[0].severity == "info"


def test_engine_reset_stats_clears_obs_state():
    from repro.configs import get_config
    from repro.serve import Request, ServeEngine

    cfg = get_config("llama3.2-1b").reduced()
    engine = ServeEngine(
        cfg, n_slots=2, max_len=64, seed=0, tracer=Tracer()
    )
    engine.submit(Request([1, 2, 3], max_new_tokens=2))
    engine.run_until_idle(max_steps=100)
    assert len(engine.tracer) > 0
    engine.reset_stats()
    assert len(engine.tracer) == 0
    assert engine.registry.get("serve_requests_completed_total").value == 0
    # post-reset traffic still feeds the same child handles
    engine.submit(Request([1, 2, 3], max_new_tokens=2))
    engine.run_until_idle(max_steps=100)
    assert engine.registry.get("serve_requests_completed_total").value == 1
    assert engine.telemetry["decode"].calls == (
        engine.registry.get("serve_phase_calls_total")
        .labels(phase="decode").value
    )


def test_engine_disabled_tracer_records_nothing():
    """The default engine inherits the disabled process tracer: the run
    must produce zero records and never flip it on (the zero-overhead
    configuration the serving benchmark ships with)."""
    from repro.configs import get_config
    from repro.serve import Request, ServeEngine

    cfg = get_config("llama3.2-1b").reduced()
    engine = ServeEngine(cfg, n_slots=2, max_len=64, seed=0)
    assert engine.tracer.enabled is False
    engine.submit(Request([1, 2, 3], max_new_tokens=2))
    completions = engine.run_until_idle(max_steps=100)
    assert len(completions) == 1
    assert len(engine.tracer) == 0
    # metrics still work — the registry is independent of tracing
    assert engine.registry.get("serve_requests_completed_total").value == 1
