"""Blocked right-looking LU with partial pivoting — the cuSOLVER-getrf
analogue for the matrix-calculation application.

Algorithm (block size nb, MXU-aligned 128):

    for each column block kb:
        1. panel factorisation  (rank-1 updates inside the panel, pivoting
           over the whole column) — latency-bound, stays in jnp;
        2. apply the panel's row swaps to the rest of the matrix;
        3. triangular solve U12 = L11^-1 A12     (small, jnp fori_loop);
        4. trailing update A22 -= L21 @ U12      (the FLOPs: >2/3 of n^3) —
           this is the MXU matmul, dispatched to the fused Pallas
           ``schur_update`` kernel on TPU.

This mirrors how cuSOLVER speeds up LU on GPUs: the algorithm is
restructured so nearly all work lands in the tuned matmul primitive — the
paper's point that *block-level replacement captures algorithm change*,
which loop-level offload cannot.

Pivot bookkeeping matches Numerical Recipes' ``indx`` convention (imax per
step, rows swapped in place) so the NR back-substitution consumes the result
unchanged; pad rows use an identity extension and can never be selected as
pivots for real columns.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp


def _panel_factor(panel: jax.Array, n_real_rows: int):
    """Unblocked LU of a (rows x nb) panel, pivoting over all rows.

    Returns (panel, piv, parity): piv[j] = row swapped with j at step j
    (panel-relative), NR semantics.
    """
    rows, nb = panel.shape
    ridx = jnp.arange(rows)

    def body(j, carry):
        panel, piv, parity = carry
        col = panel[:, j]
        # eligible pivots: at/below the diagonal, and never a pad row for a
        # real column (pad rows may only pivot for their own pad column).
        eligible = (ridx >= j) & ((ridx < n_real_rows) | (ridx == j))
        score = jnp.where(eligible, jnp.abs(col), -jnp.inf)
        imax = jnp.argmax(score)
        rj = panel[j]
        ri = panel[imax]
        panel = panel.at[j].set(ri).at[imax].set(rj)
        piv = piv.at[j].set(imax)
        parity = jnp.where(imax != j, -parity, parity)
        pivval = panel[j, j]
        pivval = jnp.where(pivval == 0.0, 1.0e-20, pivval)
        panel = panel.at[j, j].set(pivval)
        fac = jnp.where(ridx > j, panel[:, j] / pivval, 0.0)
        cidx = jnp.arange(nb)
        urow = jnp.where(cidx > j, panel[j], 0.0)
        panel = panel - jnp.outer(fac, urow)
        panel = panel.at[:, j].set(jnp.where(ridx > j, fac, panel[:, j]))
        return panel, piv, parity

    piv0 = jnp.zeros(nb, dtype=jnp.int32)
    return jax.lax.fori_loop(
        0, nb, body, (panel, piv0, jnp.asarray(1.0, panel.dtype))
    )


def _apply_swaps(mat: jax.Array, piv: jax.Array) -> jax.Array:
    """Apply the NR swap sequence piv (row j <-> piv[j]) to ``mat`` rows."""

    def body(j, m):
        i = piv[j]
        rj = m[j]
        ri = m[i]
        return m.at[j].set(ri).at[i].set(rj)

    return jax.lax.fori_loop(0, piv.shape[0], body, mat)


def _trsm_lower_unit(l11: jax.Array, b: jax.Array) -> jax.Array:
    """Solve L11 @ X = B with L11 unit lower triangular (nb x nb)."""
    nb = l11.shape[0]
    ridx = jnp.arange(nb)

    def body(r, x):
        lrow = jnp.where(ridx < r, l11[r], 0.0)  # (nb,)
        x_r = b[r] - lrow @ x
        return x.at[r].set(x_r)

    return jax.lax.fori_loop(0, nb, body, jnp.zeros_like(b))


def _schur_jnp(c: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    return c - a @ b


@functools.partial(jax.jit, static_argnames=("nb", "n_real", "use_pallas", "interpret"))
def lu_blocked(
    a: jax.Array,
    *,
    nb: int = 128,
    n_real: int | None = None,
    use_pallas: bool = False,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Blocked LU.  Returns (lu_packed, piv, parity).

    ``a`` must be square with n % nb == 0 (use ops.lu for auto-padding).
    ``n_real`` marks the boundary of identity padding.
    """
    n = a.shape[0]
    if a.shape[1] != n or n % nb:
        raise ValueError(f"need square n%nb==0 matrix, got {a.shape}, nb={nb}")
    n_real = n if n_real is None else n_real

    if use_pallas:
        from repro.kernels.matmul import schur_update_pallas

        def schur(c, x, y):
            if min(c.shape + x.shape) == 0:
                return c
            bm = 128 if c.shape[0] % 128 == 0 else nb
            return schur_update_pallas(
                c, x, y, block_m=min(bm, c.shape[0]),
                block_n=min(128, c.shape[1]), block_k=min(128, x.shape[1]),
                interpret=interpret,
            )
    else:
        schur = _schur_jnp

    a = a.astype(jnp.float32)
    piv = jnp.zeros(n, dtype=jnp.int32)
    parity = jnp.asarray(1.0, jnp.float32)

    for kb in range(0, n, nb):
        rows = n - kb
        panel = jax.lax.dynamic_slice(a, (kb, kb), (rows, nb))
        panel, ppiv, pparity = _panel_factor(panel, max(n_real - kb, 0) or nb)
        parity = parity * pparity
        a = jax.lax.dynamic_update_slice(a, panel, (kb, kb))
        piv = jax.lax.dynamic_update_slice(piv, ppiv + kb, (kb,))
        # swap rows in the columns left of and right of the panel
        if kb > 0:
            left = jax.lax.dynamic_slice(a, (kb, 0), (rows, kb))
            left = _apply_swaps(left, ppiv)
            a = jax.lax.dynamic_update_slice(a, left, (kb, 0))
        rcols = n - kb - nb
        if rcols > 0:
            right = jax.lax.dynamic_slice(a, (kb, kb + nb), (rows, rcols))
            right = _apply_swaps(right, ppiv)
            l11 = panel[:nb]
            u12 = _trsm_lower_unit(l11, right[:nb])
            right = right.at[:nb].set(u12)
            if rows > nb:
                l21 = panel[nb:]
                a22 = schur(right[nb:], l21, u12)
                right = right.at[nb:].set(a22)
            a = jax.lax.dynamic_update_slice(a, right, (kb, kb + nb))

    return a, piv, parity
