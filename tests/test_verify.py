"""Verification-environment pattern search (paper Step 3 procedure)."""

import time

import pytest

from repro.core.verify import measure, search_offload_pattern, verify_numerics


def _mk_variant_factory(costs):
    """Variants whose runtime is a deterministic function of the subset."""

    def build(subset):
        seconds = costs[frozenset(subset)]

        def fn(_x):
            time.sleep(seconds)
            return _x

        return fn

    return build


def test_single_then_combine_adopts_combination():
    costs = {
        frozenset(): 0.02,
        frozenset({"a"}): 0.012,
        frozenset({"b"}): 0.014,
        frozenset({"a", "b"}): 0.006,
    }
    rep = search_offload_pattern(
        _mk_variant_factory(costs), ["a", "b"], (0,), repeats=1
    )
    assert set(rep.best.pattern) == {"a", "b"}
    assert rep.best.speedup > 2.0


def test_combination_rejected_when_slower_than_best_single():
    costs = {
        frozenset(): 0.02,
        frozenset({"a"}): 0.008,
        frozenset({"b"}): 0.018,
        frozenset({"a", "b"}): 0.015,  # combo worse than 'a' alone
    }
    rep = search_offload_pattern(
        _mk_variant_factory(costs), ["a", "b"], (0,), repeats=1
    )
    assert rep.best.pattern == ("a",)


def test_keeps_baseline_when_nothing_helps():
    costs = {
        frozenset(): 0.005,
        frozenset({"a"}): 0.02,
    }
    rep = search_offload_pattern(
        _mk_variant_factory(costs), ["a"], (0,), repeats=1
    )
    assert rep.best.pattern == ()


def test_prefilter_limits_trials():
    costs = {
        frozenset(): 0.01,
        frozenset({"a"}): 0.005,
        frozenset({"b"}): 0.005,
    }
    rep = search_offload_pattern(
        _mk_variant_factory(costs), ["a", "b"], (0,), repeats=1,
        prefilter=lambda name: name == "a",
    )
    assert {t.pattern for t in rep.trials} == {(), ("a",)}


def test_measure_reports_compile_time_separately():
    calls = {"n": 0}

    def fn(x):
        if calls["n"] == 0:
            time.sleep(0.05)  # "compile" on first call
        calls["n"] += 1
        return x

    m = measure(fn, (0,), repeats=2, warmup=1)
    assert m.compile_seconds > 0.02
    assert m.seconds < 0.05


def test_measure_min_seconds_floor_repeats_short_kernels():
    calls = {"n": 0}

    def fast(x):
        calls["n"] += 1
        time.sleep(0.001)
        return x

    m = measure(fast, (0,), repeats=2, warmup=0, min_seconds=0.03)
    # each of the 2 timed windows must span >= 30 ms, so a ~1 ms kernel is
    # called many times per window rather than once (bound is loose: sleep
    # can take several ms on a loaded CI runner)
    assert calls["n"] >= 2 * 5
    # per-call time is reported, not the window total
    assert 0.0005 < m.seconds < 0.02
    assert m.repeats == 2


def test_measure_min_seconds_default_zero_single_call():
    calls = {"n": 0}

    def fn(x):
        calls["n"] += 1
        return x

    measure(fn, (0,), repeats=3, warmup=1)
    assert calls["n"] == 4  # warmup + one call per repeat, no floor looping


def test_verify_numerics_tuple_and_scalar():
    f = lambda x: (x * 2.0, x + 1.0)
    g = lambda x: (x * 2.0 + 1e-9, x + 1.0)
    import numpy as np

    assert verify_numerics(f, g, (np.ones(4),))
    h = lambda x: (x * 3.0, x + 1.0)
    assert not verify_numerics(f, h, (np.ones(4),))
