"""Offloading an *existing* application you didn't write for acceleration.

Demonstrates all three discovery/adaptation paths of the paper:
  A-1/B-1  a named library call (ludcmp) found by DB name matching;
  A-2/B-2  a copied-and-modified block (my_ludcmp) found by Deckard-style
           similarity;
  C-2      an interface mismatch that needs the user's confirmation before
           substitution (here: a replacement returning fewer values).

  PYTHONPATH=src python examples/offload_existing_app.py
"""

import warnings

warnings.filterwarnings("ignore")

import numpy as np

from repro.apps import matrix
from repro.core import Policy
from repro.core.interface import InterfaceSpec, Param, match_interfaces
from repro.offload import OffloadSession


def main() -> None:
    a = matrix.make_input(128)

    print("=== A-1/B-1: library call found by name ===")
    res = OffloadSession(matrix.matrix_app_libcall, args=(a,), repeats=1).run()
    d = res.discoveries[0]
    print(f"  {d.source_name} -> {d.entry.name} via {d.kind}")
    print(f"  recipe: {d.entry.usage_recipe[:70]}...")
    print(f"  speedup {res.speedup:.1f}x, "
          f"numerics ok: {res.numerics_ok}")

    print("=== A-2/B-2: copied code found by similarity ===")
    res2 = OffloadSession(matrix.matrix_app_copied, args=(a,), repeats=1).run()
    d2 = res2.discoveries[0]
    print(f"  {d2.source_name} -> {d2.entry.name} via {d2.kind} "
          f"(score {d2.score:.2f})")
    print(f"  speedup {res2.speedup:.1f}x")

    print("=== C-2: interface mismatch requires confirmation ===")
    src = InterfaceSpec(
        params=(Param("a", "float64", rank=2), Param("b", "float64", rank=1)),
        returns=("float64", "int64", "float64"),
    )
    dst = InterfaceSpec(
        params=(Param("a", "float32", rank=2),),
        returns=("float32", "int32"),
    )
    try:
        match_interfaces(src, dst)  # default policy: deny
        print("  unexpected: adaptation proceeded without the user")
    except Exception as e:
        print(f"  blocked as expected: {e}")
    asked = []
    pol = Policy(confirm=lambda msg: asked.append(msg) or True)
    adaptation = match_interfaces(src, dst, pol)
    print(f"  after user confirmation ({len(asked)} questions): "
          f"dropped={adaptation.dropped}, casts applied")


if __name__ == "__main__":
    main()
