#!/usr/bin/env sh
# Convenience wrapper around the Makefile targets for environments without
# make.  Usage: scripts/test.sh [fast|full]
set -eu
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

mode="${1:-full}"
case "$mode" in
  fast)
    exec python -m pytest -q \
      tests/test_planner.py tests/test_offload_session.py \
      tests/test_metering.py tests/test_serve.py tests/test_serve_kv.py \
      tests/test_verify.py tests/test_ga.py \
      tests/test_engine.py tests/test_blocks.py tests/test_core_ast.py \
      tests/test_pattern_db.py tests/test_similarity.py \
      tests/test_interface.py tests/test_hlo_cost.py \
      tests/test_analysis.py tests/test_jaxpr_analysis.py \
      tests/test_resources.py tests/test_obs.py \
      tests/test_kernels_paged_attention.py
    ;;
  full)
    exec python -m pytest -x -q
    ;;
  *)
    echo "usage: scripts/test.sh [fast|full]" >&2
    exit 2
    ;;
esac
