"""repro.analysis: legality, hot-path and paging passes, planner pruning.

Three populations are covered: seeded-regression fixtures each pass must
flag (a host-syncing decode loop, a shape-drifting program, a double page
write), the full configs zoo linted against the checked-in baseline, and
the legality pre-filter driven through a real OffloadSession search with a
deterministic fake executor (pruned and unpruned searches must commit the
same winner).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    AnalysisReport,
    Baseline,
    Diagnostic,
    PageAliasError,
    ProgramSet,
    assert_page_table,
    check_binding_space,
    check_page_table,
    lint_traced_program,
    trace_features,
)
from repro.core.blocks import FunctionBlockRegistry
from repro.core.planner import BindingSpace, SingleThenCombine
from repro.offload.session import OffloadSession

BASELINE_PATH = os.path.join(
    os.path.dirname(__file__), "..", "analysis_baseline.json"
)


# -- diagnostics plumbing -----------------------------------------------------


def test_fingerprint_excludes_message_and_ratchet_skips_info():
    a = Diagnostic("hotpath", "host-sync", "warning", "p", "output[0]", "v1")
    b = Diagnostic("hotpath", "host-sync", "warning", "p", "output[0]", "v2")
    assert a.fingerprint == b.fingerprint

    report = AnalysisReport([
        a,
        Diagnostic("legality", "illegal-binding", "info", "p", "x->pallas",
                   "platform"),
    ])
    # info diagnostics never enter the ratchet; the warning is new
    new = report.new_versus(Baseline())
    assert [d.code for d in new] == ["host-sync"]
    assert report.new_versus(Baseline({a.fingerprint})) == []


def test_unknown_severity_rejected():
    with pytest.raises(ValueError):
        Diagnostic("p", "c", "fatal", "prog", "s", "m")


# -- feature extraction -------------------------------------------------------


def test_trace_features_collects_nested_jit_consts():
    big = np.ones((512, 1024), np.float32)  # 2 MiB

    @jax.jit
    def f(x):
        return x @ big

    feats = trace_features(f, jax.ShapeDtypeStruct((4, 512), jnp.float32))
    # jit buries captured constants on the inner pjit jaxpr; the walker
    # must find them there, not on the (empty) outer ClosedJaxpr
    assert feats.largest_const_bytes >= big.nbytes
    assert "float32" in feats.dtypes
    assert feats.flops > 0


# -- hot-path pass: seeded regressions ---------------------------------------


def _cache_like():
    return jax.ShapeDtypeStruct((2, 4, 16, 8), jnp.float32)


def test_host_sync_flagged_for_logit_returning_decode_loop():
    """The classic bug: the decode loop returns full logits and the driver
    argmaxes them on host every step."""

    def decode(tok, cache):
        logits = jnp.zeros((4, 50_000), jnp.float32) + tok[:, None]
        return cache, logits  # cache is the carry; logits go to host

    ps = ProgramSet()
    ps.register("decode", decode, loop=True, carry_outputs=(0,),
                expected_signatures=1)
    ps.observe("decode", jax.ShapeDtypeStruct((4,), jnp.int32), _cache_like())
    codes = [d.code for d in ps.lint()]
    assert "host-sync" in codes


def test_fused_sampling_decode_contract_is_clean():
    def decode(tok, cache):
        return jnp.argmax(tok)[None].astype(jnp.int32), cache

    ps = ProgramSet()
    ps.register("decode", decode, loop=True, carry_outputs=(1,),
                expected_signatures=1)
    ps.observe("decode", jax.ShapeDtypeStruct((4,), jnp.int32), _cache_like())
    assert ps.lint() == []


def test_shape_drift_flagged_as_retrace_risk():
    def prog(x):
        return x * 2

    ps = ProgramSet()
    ps.register("insert", prog, expected_signatures=1)
    ps.observe("insert", jax.ShapeDtypeStruct((4, 8), jnp.float32))
    assert ps.lint() == []
    ps.observe("insert", jax.ShapeDtypeStruct((4, 9), jnp.float32))
    diags = ps.lint()
    assert [d.code for d in diags] == ["retrace-risk"]
    assert diags[0].severity == "warning"


def test_python_scalar_in_loop_program_flagged():
    ps = ProgramSet()
    ps.register("decode", lambda x, t: x * t, loop=True)
    ps.observe("decode", jax.ShapeDtypeStruct((4,), jnp.float32), 0.8)
    assert "weak-type" in [d.code for d in ps.lint()]


def test_const_capture_flagged():
    table = np.ones((600, 600), np.float32)  # ~1.4 MB > 1 MiB budget

    @jax.jit
    def f(x):
        return x @ table

    diags = lint_traced_program(
        "prog", f, [jax.ShapeDtypeStruct((2, 600), jnp.float32)]
    )
    assert "const-capture" in [d.code for d in diags]


def test_observed_wrapper_records_without_changing_results():
    ps = ProgramSet()
    wrapped = ps.register("f", lambda x: x + 1)
    assert int(wrapped(jnp.zeros((), jnp.int32))) == 1
    assert wrapped.record.calls == 1


# -- paging pass: seeded regressions -----------------------------------------


def test_double_page_write_is_an_error():
    # slots 0 and 1 both name page 1 — decode scatter-writes would collide
    table = np.array([[0, 1], [1, 4]], np.int32)
    diags = check_page_table(table, null_page=4, page_size=8)
    assert any(d.code == "page-alias" and d.severity == "error"
               for d in diags)
    with pytest.raises(PageAliasError):
        assert_page_table(table, null_page=4, page_size=8)


def test_freed_slot_writes_and_range_errors_flagged():
    table = np.array([[0, 9], [2, 4]], np.int32)  # 9 out of range
    diags = check_page_table(
        table, null_page=4, page_size=8, live_slots={0}
    )
    codes = {d.code for d in diags}
    assert "page-range" in codes
    assert "freed-slot-write" in codes  # slot 1 is dead but names page 2


def test_page_hole_is_a_warning():
    table = np.array([[4, 2]], np.int32)  # null before a real page
    diags = check_page_table(table, null_page=4, page_size=8)
    assert any(d.code == "page-hole" and d.severity == "warning"
               for d in diags)


def test_clean_table_passes():
    table = np.array([[0, 1], [2, 4]], np.int32)
    assert check_page_table(table, null_page=4, page_size=8) == []


def test_page_table_runtime_validation_catches_induced_alias():
    from repro.serve.kv.pool import PagePool, PageTable

    table = PageTable(2, 4, PagePool(6, 8), validate=True)
    table.alloc_slot(0, 10)
    table.alloc_slot(1, 10)
    table.check_invariants()  # healthy

    # induce the double-write bug the sanitizer exists for: slot 1's
    # second page silently aliased onto slot 0's first page
    table._pages[1][1] = table._pages[0][0]
    with pytest.raises(PageAliasError):
        table.ensure(1, 11)  # any mutation re-validates


# -- legality pass ------------------------------------------------------------


def _toy_registry():
    reg = FunctionBlockRegistry()
    reg.register("norm", "ref", lambda x: x * 1.0)
    reg.register("norm", "xla", lambda x: x + 0.0)

    def pallas_like(x):
        raise NotImplementedError("pallas lowering requires a TPU backend")

    reg.register("norm", "pallas", pallas_like)
    return reg


def _toy_space(reg):
    return BindingSpace(
        lambda: (lambda x: reg.call("norm", x)), registry=reg, tag="toy"
    )


def test_probe_trace_rejects_untraceable_binding():
    space = _toy_space(_toy_registry())
    report = check_binding_space(
        space, (jnp.ones((4, 4)),), constraints={}, program="toy"
    )
    verdicts = {(v.block, v.target): v.status for v in report.verdicts}
    assert verdicts[("norm", "xla")] == "legal"
    assert verdicts[("norm", "pallas")] == "illegal"
    (reason,) = [v.reason for v in report.verdicts if v.target == "pallas"]
    assert "probe trace failed" in reason


def test_platform_metadata_rejects_without_probe():
    from repro.analysis.legality import TargetConstraints

    space = _toy_space(_toy_registry())
    constraints = {
        ("norm", "pallas"): TargetConstraints(requires_platform=("tpu",)),
        ("norm", "xla"): TargetConstraints(),
    }
    report = check_binding_space(
        space, (jnp.ones((4, 4)),), constraints=constraints, platform="cpu",
        probe_trace=False, program="toy",
    )
    illegal = report.illegal
    assert ("norm", "pallas") in illegal
    assert "requires platform tpu" in illegal[("norm", "pallas")]
    # platform-dependent verdicts are info: exempt from the ratchet
    diags = report.diagnostics()
    assert all(d.severity == "info" for d in diags
               if d.subject == "norm->pallas")


def test_kernel_shelf_declares_legality_metadata():
    from repro.analysis.legality import shelf_constraints

    meta = shelf_constraints()
    assert ("matmul", "pallas") in meta
    assert "tpu" in meta[("matmul", "pallas")].requires_platform
    # the baseline formulations run anywhere
    assert meta[("matmul", "ref")].requires_platform == ()


def test_mark_illegal_prunes_candidates_but_never_baseline():
    space = _toy_space(_toy_registry())
    space.mark_illegal({("norm", "pallas"): "no TPU"})
    bad = space.candidate_from_mapping({"norm": "pallas"})
    good = space.candidate_from_mapping({"norm": "xla"})
    assert "no TPU" in space.pruned(bad)
    assert space.pruned(good) is None
    assert space.pruned(space.baseline()) is None
    from repro.core.planner.space import DEFAULT_TARGET

    with pytest.raises(ValueError):
        space.mark_illegal({("norm", DEFAULT_TARGET): "nope"})


# -- legality pre-filter through a real search --------------------------------


class FakeExecutor:
    """Deterministic 'measurements' keyed on the candidate's binding; never
    calls the built fn, so statically-illegal variants don't crash the
    unpruned control search."""

    name = "fake"

    def __init__(self, times):
        self.times = times
        self.measured: list[dict] = []

    def run(self, jobs, meter=None):
        from repro.core.verify import Measurement

        out = []
        for job in jobs:
            binding = job.space.binding_of(job.candidate)
            target = binding.get("norm", "ref")
            self.measured.append(binding)
            out.append(Measurement(
                seconds=self.times[target], compile_seconds=0.0, repeats=1
            ))
        return out


TIMES = {"ref": 0.02, "xla": 0.001, "pallas": 5.0}


def _searched_session(legality):
    reg = _toy_registry()
    session = OffloadSession(
        _toy_space(reg),
        args=(jnp.ones((4, 4)),),
        strategy=SingleThenCombine(),
        executor=FakeExecutor(TIMES),
        repeats=1,
        legality=legality,
    )
    session.analyze()
    session.discover()
    plan = session.plan()
    return session, plan


def test_pruned_search_commits_same_winner_as_unpruned():
    pruned_session, pruned_plan = _searched_session(legality=True)
    control_session, control_plan = _searched_session(legality=False)

    # the pre-filter found the untraceable pallas binding and skipped it
    report = pruned_session._report
    assert report.pruned > 0
    assert any("pallas" in k for k in report.pruned_reasons)
    fake = pruned_session.cache.executor
    assert all(b.get("norm") != "pallas" for b in fake.measured)

    # the control search measured (and rejected on merit) the 5 s pallas
    control_fake = control_session.cache.executor
    assert any(b.get("norm") == "pallas" for b in control_fake.measured)
    assert getattr(control_session._report, "pruned", 0) == 0

    # identical committed winner: pruning changed cost, not the outcome
    assert pruned_plan.mapping == control_plan.mapping == {"norm": "xla"}
    assert pruned_session.legality_report is not None
    assert control_session.legality_report is None


# -- full-zoo lint vs the checked-in baseline ---------------------------------


def _zoo_cells():
    from repro.configs import ARCH_NAMES

    return [(a, k) for a in ARCH_NAMES for k in ("prefill", "decode")]


@pytest.mark.parametrize("arch,kind", _zoo_cells())
def test_zoo_cell_lints_clean_against_baseline(arch, kind):
    """Every configs-zoo (arch, phase) program runs the legality pass over
    its full BindingSpace plus the static hot-path lints, and must produce
    nothing above the committed baseline (info verdicts are host-dependent
    and exempt)."""
    from repro.analysis.lint import lint_zoo_cell

    report = AnalysisReport(lint_zoo_cell(arch, kind))
    baseline = Baseline.load(BASELINE_PATH)
    new = report.new_versus(baseline)
    assert new == [], "\n".join(str(d) for d in new)


def test_serve_engine_lints_clean_and_validated():
    """A tiny paged engine serves a short trace under runtime page-table
    validation, then its hot-path + page-table lints must be clean — the
    PR-4/5 contracts (decode transfers token ids only, recomposition never
    retraces, no page aliasing) hold for real served traffic."""
    from repro.configs import get_config
    from repro.serve import Request, ServeEngine

    cfg = get_config("llama3.2-1b").reduced()
    engine = ServeEngine(
        cfg, n_slots=2, max_len=32, page_size=8, kv_validate=True, seed=0
    )
    rng = np.random.default_rng(0)
    for i in range(3):
        prompt = rng.integers(0, cfg.vocab_size, 5 + i).tolist()
        engine.submit(Request(prompt, max_new_tokens=4))
    completions = engine.run_until_idle(max_steps=64)
    assert len(completions) == 3
    assert engine.lint() == []
    assert engine.programs.records["decode"].calls > 0
