"""Perf hillclimbing harness (EXPERIMENTS.md §Perf).

Runs one evaluation cell under a sequence of named override variants,
recording the three roofline terms per variant into
results/perf_iterations.json.  Each entry is one hypothesis->change->
measure iteration; the narrative lives in EXPERIMENTS.md.

  PYTHONPATH=src python -m benchmarks.perf_iterate --arch command-r-35b \
      --shape train_4k --variant baseline --variant bf16_params ...
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

# variant name -> overrides dict
VARIANTS = {
    "baseline": {},
    "bf16_params": {"param_dtype": "bfloat16"},
    "bf16_params_mb1": {"param_dtype": "bfloat16", "microbatch": 1},
    "bf16_params_mb1_bf16scores": {
        "param_dtype": "bfloat16", "microbatch": 1,
        "scores_dtype": "bfloat16",
    },
    "bf16_mb1": {"microbatch": 1, "param_dtype": "bfloat16",
                 "opt_dtype": "bfloat16"},
    "ep_psum": {"ep_mode": "psum"},
    "ep_psum_mb1": {"ep_mode": "psum", "microbatch": 1},
    "ep_psum_mb1_bf16scores": {
        "ep_mode": "psum", "microbatch": 1, "scores_dtype": "bfloat16",
    },
    "mb1": {"microbatch": 1},
    "mb4": {"microbatch": 4},
    "bf16scores": {"scores_dtype": "bfloat16"},
    "mixednorm": {"norm_precision": "mixed"},
    "mixednorm_bf16scores": {"norm_precision": "mixed",
                             "scores_dtype": "bfloat16"},
    "ep_psum_mixednorm": {"ep_mode": "psum", "norm_precision": "mixed"},
    "bf16reduce": {"bf16_tp_reduce": True},
    "bf16reduce_mixednorm": {"bf16_tp_reduce": True,
                             "norm_precision": "mixed"},
    "ep_psum_bf16reduce_mixednorm": {
        "ep_mode": "psum", "bf16_tp_reduce": True, "norm_precision": "mixed",
    },
    "megatron": {"bf16_tp_reduce": True, "megatron_mlp": True},
    "megatron_mixednorm": {"bf16_tp_reduce": True, "megatron_mlp": True,
                           "norm_precision": "mixed"},
    "ep_psum_megatron": {"ep_mode": "psum", "bf16_tp_reduce": True,
                         "megatron_mlp": True},
    "save_moe": {"remat_policy": "save_moe"},
    "save_moe_megatron": {"remat_policy": "save_moe", "bf16_tp_reduce": True,
                          "megatron_mlp": True},
    # arctic: 56 q-heads don't divide the 16-way model axis; pad to 64
    # zero-initialised heads (mathematically inert) so attention shards
    "pad_heads64": {"n_heads": 64},
    "pad_heads64_megatron": {"n_heads": 64, "bf16_tp_reduce": True,
                             "megatron_mlp": True},
    "pad_heads64_megatron_savemoe": {
        "n_heads": 64, "bf16_tp_reduce": True, "megatron_mlp": True,
        "remat_policy": "save_moe",
    },
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", action="append", required=True)
    ap.add_argument("--out", default="results/perf_iterations.json")
    args = ap.parse_args()

    from benchmarks.roofline import analyze_record
    from repro.launch.dryrun import run_cell

    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = []
    if out_path.exists():
        results = json.loads(out_path.read_text())

    for vname in args.variant:
        ov = VARIANTS[vname]
        rec = run_cell(args.arch, args.shape, args.multi_pod, overrides=ov)
        rec["variant"] = vname
        a = analyze_record(rec) or {}
        rec.update({f"term_{k}": v for k, v in a.items()
                    if k.endswith("_s") or k in ("dominant", "roofline_fraction",
                                                 "useful_ratio")})
        results.append(rec)
        out_path.write_text(json.dumps(results, indent=1))
        if rec["status"] == "ok":
            print(
                f"{args.arch} x {args.shape} [{vname}]: "
                f"comp={a['compute_s']:.2f}s mem={a['memory_s']:.2f}s "
                f"coll={a['collective_s']:.2f}s dom={a['dominant']} "
                f"frac={a['roofline_fraction']:.3f} "
                f"peak={rec['peak_bytes_per_device']/1e9:.1f}GB",
                flush=True,
            )
        else:
            print(f"{args.arch} x {args.shape} [{vname}]: {rec['status']} "
                  f"{rec.get('error','')[:200]}", flush=True)


if __name__ == "__main__":
    main()
