"""Continuous-batching admission control: slots, queueing, budget, pages.

The engine's KV cache is a fixed array of ``n_slots`` batch rows.  The
scheduler owns which request occupies which slot: submitted requests wait
in FIFO order, each engine step admits waiting requests into free slots
(a prefill each), and finished requests release their slot immediately —
the next waiting request reuses it on the following step, while the other
slots keep decoding.  This is continuous batching: the batch recomposes
every step instead of draining entirely before refilling.

With a paged KV cache (``kv`` is a :class:`repro.serve.kv.PageTable`)
admission additionally gates on **free pages**: a slot is only a batch
row, the tokens live in the shared pool, so what bounds concurrency is
pages — not ``n_slots x max_len``.  Admission allocates the request's
initial pages (the prompt, or just its first chunk under chunked
prefill), ``release`` and ``preempt`` return every page to the pool.

The *token budget* (``max_tokens_per_step``) bounds how much work one
engine step may inject, in tokens: a decode step costs one token per
decoding slot, an admission costs the tokens its first prefill program
call actually runs (bucket-padded, or one chunk) plus the admitted
request's own decode token this step.  A small budget keeps per-step
latency flat under bursty arrivals; a large budget maximises admission
throughput.  When no other work is running this step, one admission is
always allowed regardless of budget, so a prompt longer than the budget
cannot deadlock the queue.
"""

from __future__ import annotations

import time
from collections import deque

from repro.obs import get_tracer
from repro.serve.request import RequestState

#: Virtual trace-track ids for per-request lifecycle spans — offset far
#: above any real thread ident's low bits so request tracks sort together
#: in the exported timeline.
REQUEST_TRACK_BASE = 0x5E54_0000


def request_track(request_id: int) -> int:
    """The tracer track (Chrome `tid`) carrying one request's lifecycle."""
    return REQUEST_TRACK_BASE + request_id


class Scheduler:
    def __init__(
        self,
        n_slots: int,
        max_tokens_per_step: int | None = None,
        prompt_cost=None,
        kv=None,
        admit_tokens=None,
        tracer=None,
        metrics=None,
    ) -> None:
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.max_tokens_per_step = max_tokens_per_step
        #: maps a waiting RequestState to the budget tokens its admission
        #: runs this step — the engine passes bucket-padded context length,
        #: or one chunk under chunked prefill
        self.prompt_cost = prompt_cost or (
            lambda state: len(state.request.prompt) + len(state.tokens)
        )
        #: maps a waiting RequestState to the tokens its admission must
        #: hold *pages* for right now (full context, or the first chunk)
        self.admit_tokens = admit_tokens or (
            lambda state: len(state.request.prompt) + len(state.tokens)
        )
        #: page table (paged KV mode) — admission allocates, release frees
        self.kv = kv
        # pop() takes from the end: keep slot 0 first for readable traces
        self._free: list[int] = list(range(n_slots - 1, -1, -1))
        self.waiting: deque[RequestState] = deque()
        self.active: dict[int, RequestState] = {}
        #: admissions per slot over the scheduler's lifetime — any count > 1
        #: is an observed slot reuse (the continuous-batching signature)
        self.admitted_per_slot: dict[int, int] = {}
        #: preempted-and-requeued requests (paged mode under page pressure)
        self.preemptions = 0
        self._admit_seq = 0
        #: request-lifecycle tracing (queue spans, kv-alloc/free, preempt)
        self.tracer = tracer if tracer is not None else get_tracer()
        self._admissions_c = self._preemptions_c = None
        if metrics is not None:
            self._admissions_c = metrics.counter(
                "serve_admissions_total",
                "requests admitted into a KV slot (re-admissions included)",
            )
            self._preemptions_c = metrics.counter(
                "serve_preemptions_total",
                "running requests evicted under page pressure and requeued",
            )

    # -- queue side -----------------------------------------------------------
    def enqueue(self, state: RequestState) -> None:
        if not state.queued_at:
            state.queued_at = state.submitted_at or time.perf_counter()
        self.waiting.append(state)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    # -- per-step admission ----------------------------------------------------
    def admissions(self, spent: int | None = None) -> list[RequestState]:
        """Admit waiting requests into free slots for this engine step.

        FIFO, budget-capped and page-gated.  ``spent`` is the budget this
        step has already committed (decode tokens + planned prefill
        chunks); defaults to one decode token per active slot.  Guaranteed
        to make progress when the engine is otherwise idle.
        """
        admitted: list[RequestState] = []
        budget = self.max_tokens_per_step
        if spent is None:
            spent = len(self.active)  # this step's decode tokens
        progressing = spent > 0
        while self.waiting and self._free:
            nxt = self.waiting[0]
            # +1: the admitted request decodes in this same step too
            cost = self.prompt_cost(nxt) + 1
            if budget is not None and spent + cost > budget:
                if progressing or self.active or admitted:
                    break  # decode / chunks / earlier admissions run first
                # idle engine: admit anyway — a prompt longer than the
                # budget must not wedge the queue
            if self.kv is not None and not self.kv.can_admit(
                self.admit_tokens(nxt)
            ):
                # no pages: in-flight requests return theirs on release /
                # preemption; an idle pool always fits one request because
                # submit() rejects anything larger than the whole pool
                break
            self.waiting.popleft()
            slot = self._free.pop()
            nxt.slot = slot
            nxt.admit_seq = self._admit_seq
            self._admit_seq += 1
            now = time.perf_counter()
            if nxt.admitted_at is None:
                # first admission only: ttft_admitted compares the first
                # token against the first time the model saw the request
                nxt.admitted_at = now
            nxt.last_admitted_at = now
            tr = self.tracer
            track = request_track(nxt.request_id)
            tokens = self.admit_tokens(nxt)
            if tr.enabled:
                tr.name_track(track, f"req {nxt.request_id}")
                tr.add_span(
                    "queue", nxt.queued_at or nxt.submitted_at, now,
                    tid=track, request=nxt.request_id, slot=slot,
                )
            t0 = time.perf_counter()
            pages = (
                self.kv.alloc_slot(slot, tokens)
                if self.kv is not None
                else None
            )
            if tr.enabled:
                # contiguous mode "allocates" by reserving the slot row;
                # the span still marks where this request's KV came from
                tr.add_span(
                    "kv-alloc", t0, time.perf_counter(), tid=track,
                    request=nxt.request_id, slot=slot, tokens=tokens,
                    pages=len(pages) if pages is not None else 0,
                )
            if self._admissions_c is not None:
                self._admissions_c.inc()
            self.active[slot] = nxt
            self.admitted_per_slot[slot] = (
                self.admitted_per_slot.get(slot, 0) + 1
            )
            admitted.append(nxt)
            spent += cost
        return admitted

    def release(self, slot: int) -> RequestState:
        """Evict a finished request: free its slot for reuse and return
        its pages to the pool."""
        state = self.active.pop(slot)
        self._free.append(slot)
        freed = self.kv.free_slot(slot) if self.kv is not None else 0
        if self.tracer.enabled:
            self.tracer.event(
                "kv-free", tid=request_track(state.request_id),
                request=state.request_id, slot=slot, pages=freed,
            )
        return state

    def preempt(self, slot: int) -> RequestState:
        """Evict a *running* request under page pressure: pages return to
        the pool and the request requeues at the FRONT of the waiting
        queue with its generated tokens intact — re-admission re-prefills
        ``prompt + tokens`` and continues exactly where it stopped
        ((seed, token-index)-keyed sampling is batch-independent, so the
        continuation is token-identical)."""
        state = self.active.pop(slot)
        self._free.append(slot)
        freed = self.kv.free_slot(slot) if self.kv is not None else 0
        state.slot = -1
        state.queued_at = time.perf_counter()
        if self.tracer.enabled:
            self.tracer.event(
                "preempt", tid=request_track(state.request_id),
                request=state.request_id, slot=slot, pages=freed,
                generated=len(state.tokens),
            )
        self.waiting.appendleft(state)
        self.preemptions += 1
        if self._preemptions_c is not None:
            self._preemptions_c.inc()
        return state

    # -- reporting -------------------------------------------------------------
    @property
    def slot_reuses(self) -> int:
        """Admissions beyond each slot's first — > 0 proves continuous
        batching actually recomposed the batch."""
        return sum(max(0, n - 1) for n in self.admitted_per_slot.values())
