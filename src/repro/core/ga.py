"""Prior-work loop-offload GA (paper §3.2, refs [32][33]) — deprecated shim.

The GA itself now lives in ``repro.core.planner.GeneticSearch``, which runs
the same elitist generational algorithm (tournament selection, single-point
crossover, per-gene mutation) over *any* ``SearchSpace`` — binary genomes on
a ``SubsetSpace`` (this module's historical behaviour: one bit per
parallelisable loop, 1 = offload) and n-ary genomes on a ``BindingSpace``
(per-block choice among {ref, xla, pallas} targets, the paper's
GPU-vs-FPGA destination choice generalised).  Measurement memoisation moved
from the private fitness dict into the shared ``planner.MeasurementCache``,
so a GA and a single-then-combine search over the same space never
re-measure each other's visited patterns.

``run_ga`` is kept as a thin wrapper producing the historical ``GAReport``
(per-generation best speedup = the paper's Fig. 4 curve); new code should
drive the planner directly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

Genome = tuple[int, ...]


@dataclasses.dataclass
class GAReport:
    best_genome: Genome
    best_seconds: float
    baseline_seconds: float
    generations: list[float]  # best speedup per generation (paper Fig. 4)
    evaluations: int  # number of *measured* trials
    search_seconds: float

    @property
    def best_speedup(self) -> float:
        return self.baseline_seconds / self.best_seconds


def run_ga(
    build_variant: Callable[[Genome], Callable[..., Any]],
    n_genes: int,
    args: Sequence[Any],
    population: int = 8,
    generations: int = 8,
    mutation_rate: float = 0.1,
    elite: int = 2,
    tournament: int = 3,
    repeats: int = 2,
    seed: int = 0,
) -> GAReport:
    """Deprecated shim over ``planner.GeneticSearch`` on a binary space."""
    from repro.core import planner

    space = planner.SubsetSpace.from_genome_builder(build_variant, n_genes)
    strategy = planner.GeneticSearch(
        population=population,
        generations=generations,
        mutation_rate=mutation_rate,
        elite=elite,
        tournament=tournament,
        seed=seed,
    )
    t0 = time.perf_counter()
    report = strategy.search(
        space, args, cache=planner.MeasurementCache(), repeats=repeats
    )
    return GAReport(
        best_genome=tuple(report.best.candidate),
        best_seconds=report.best.seconds,
        baseline_seconds=report.baseline_seconds,
        generations=list(report.generations or []),
        evaluations=report.evaluations,
        search_seconds=time.perf_counter() - t0,
    )
