"""Deep structural features of a traced program (``ClosedJaxpr``).

``repro.core.jaxpr_analysis`` stays the histogram/FLOPs walker (the Deckard
characteristic-vector analogue); this module layers the facts the analysis
passes decide on: the full primitive set including sub-jaxprs, the dtype
universe, control-flow and callback presence, baked-in constant sizes and
dynamic-shape detection.  Everything here is pure trace inspection — no
compilation, no execution.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.extend.core as jex_core

from repro.core import jaxpr_analysis

#: Primitives that re-enter Python from inside a trace.  Any of these in a
#: jitted hot-path program forces a host round-trip per call.
CALLBACK_PRIMITIVES = frozenset(
    {"pure_callback", "io_callback", "debug_callback", "callback"}
)

#: Control-flow primitives (the paper's "loop statements" at trace level).
CONTROL_FLOW_PRIMITIVES = frozenset({"scan", "while", "cond"})


@dataclasses.dataclass
class ProgramFeatures:
    """Facts about one traced program, for legality and hot-path passes."""

    primitives: frozenset[str]  # deep: includes all sub-jaxpr eqns
    dtypes: frozenset[str]  # every aval dtype seen (inputs + intermediates)
    n_eqns: int
    has_scan: bool
    has_while: bool
    has_cond: bool
    callbacks: tuple[str, ...]  # callback primitives present, sorted
    const_bytes: int  # total bytes of captured (baked-in) constants
    largest_const_bytes: int
    n_consts: int
    dynamic_shapes: bool  # any aval dimension not a static int
    flops: float  # dot+conv+fft estimate, scan-scaled
    dot_flops: float
    out_avals: tuple[Any, ...]  # abstract outputs (for host-sync sizing)
    report: jaxpr_analysis.JaxprReport  # the underlying histogram report


def _walk_avals(jaxpr, seen_dtypes: set, dyn: list) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        n += 1
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is None:
                continue
            dt = getattr(aval, "dtype", None)
            if dt is not None:
                seen_dtypes.add(str(dt))
            for d in getattr(aval, "shape", ()) or ():
                if not isinstance(d, int):
                    dyn.append(d)
        for sub in jaxpr_analysis._sub_jaxprs(eqn):
            n += _walk_avals(sub, seen_dtypes, dyn)
    return n


def _collect_consts(node: Any, out: list) -> None:
    """Constants captured anywhere in the program, including inside nested
    ``pjit``/``scan``/``cond`` ClosedJaxprs — ``jax.jit`` hoists a closed-
    over array onto the *inner* pjit jaxpr's consts, not the outer one."""
    if isinstance(node, jex_core.ClosedJaxpr):
        out.extend(getattr(node, "consts", []) or [])
        node = node.jaxpr
    if not isinstance(node, jex_core.Jaxpr):
        return
    for eqn in node.eqns:
        for v in eqn.params.values():
            if isinstance(v, (jex_core.ClosedJaxpr, jex_core.Jaxpr)):
                _collect_consts(v, out)
            elif isinstance(v, (tuple, list)):
                for e in v:
                    if isinstance(e, (jex_core.ClosedJaxpr, jex_core.Jaxpr)):
                        _collect_consts(e, out)


def _nbytes(c: Any) -> int:
    nb = getattr(c, "nbytes", None)
    if nb is not None:
        return int(nb)
    size = getattr(c, "size", None)
    itemsize = getattr(getattr(c, "dtype", None), "itemsize", None)
    if size is not None and itemsize is not None:
        return int(size) * int(itemsize)
    return 0


def extract_features(closed: Any) -> ProgramFeatures:
    """Features of a ``ClosedJaxpr`` (or bare ``Jaxpr``)."""
    report = jaxpr_analysis.analyze_jaxpr(closed)
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed

    dtypes: set[str] = set()
    dyn: list[Any] = []
    for v in list(jaxpr.invars) + list(jaxpr.outvars):
        aval = getattr(v, "aval", None)
        if aval is None:
            continue
        dt = getattr(aval, "dtype", None)
        if dt is not None:
            dtypes.add(str(dt))
        for d in getattr(aval, "shape", ()) or ():
            if not isinstance(d, int):
                dyn.append(d)
    n_eqns = _walk_avals(jaxpr, dtypes, dyn)

    consts: list[Any] = []
    _collect_consts(closed, consts)
    const_sizes = [_nbytes(c) for c in consts]

    prims = frozenset(report.histogram)
    callbacks = tuple(sorted(prims & CALLBACK_PRIMITIVES))
    out_avals = tuple(
        getattr(v, "aval", None) for v in jaxpr.outvars
    )
    return ProgramFeatures(
        primitives=prims,
        dtypes=frozenset(dtypes),
        n_eqns=n_eqns,
        has_scan=report.has_scan,
        has_while=report.has_while,
        has_cond="cond" in prims,
        callbacks=callbacks,
        const_bytes=sum(const_sizes),
        largest_const_bytes=max(const_sizes, default=0),
        n_consts=len(consts),
        dynamic_shapes=bool(dyn),
        flops=report.flops,
        dot_flops=report.dot_flops,
        out_avals=out_avals,
        report=report,
    )


def trace_features(
    fn: Callable[..., Any], *example_args: Any, **example_kwargs: Any
) -> ProgramFeatures:
    """Trace ``fn`` abstractly (no execution) and extract its features.

    Works through ``jax.jit`` wrappers — ``make_jaxpr`` inlines the pjit
    call into a sub-jaxpr the walkers descend into.
    """
    closed = jax.make_jaxpr(fn)(*example_args, **example_kwargs)
    return extract_features(closed)


def jaxpr_of(fn: Callable[..., Any], *example_args: Any) -> jex_core.ClosedJaxpr:
    return jax.make_jaxpr(fn)(*example_args)
