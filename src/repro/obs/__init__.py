"""``repro.obs`` — tracing, metrics and profiling across the stack.

The paper's environment-adaptive loop re-decides *where* to offload from
measurements of the running system.  This package is the measurement
substrate those decisions (and their operators) consume:

  trace     :class:`Tracer` — typed spans/events on a thread-safe ring
            buffer; Chrome/Perfetto ``trace_event`` JSON and JSONL
            exporters.  The serve engine, the offload session stages and
            the metering executors all record against the process-default
            tracer (:func:`get_tracer`), disabled — and near-free — until
            enabled.
  metrics   :class:`MetricsRegistry` — counter/gauge/exponential-bucket
            histogram families with a Prometheus text renderer and an
            optional stdlib HTTP ``/metrics`` endpoint
            (:class:`MetricsServer`; ``ServeEngine.serve_metrics(port)``).
  profile   :func:`profile_window` — opt-in ``jax.profiler`` capture
            around N serve steps or one planner round, degrading to a
            no-op where the profiler is unavailable.
  timeline  ``python -m repro.obs.timeline trace.json`` — terminal span
            summary (p50/p99 per span kind) plus the critical path of the
            worst request.
"""

from repro.obs.metrics import (  # noqa: F401
    MetricsRegistry,
    MetricsServer,
    exponential_buckets,
)
from repro.obs.profile import profile_window, profiler_available  # noqa: F401
from repro.obs.trace import (  # noqa: F401
    NULL_SPAN,
    SpanRecord,
    Tracer,
    get_tracer,
    set_tracer,
)
