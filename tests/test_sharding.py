"""Sharding rules: logical-axis resolution, dedupe, divisibility guards."""

import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_shape
from repro.sharding.specs import rules_for
from repro.sharding.utils import resolve_spec

MESH_1POD = {"data": 16, "model": 16}
MESH_2POD = {"pod": 2, "data": 16, "model": 16}


def test_resolve_dedupes_reused_axes():
    rules = {"a": "model", "b": "model", "c": ("data",)}
    spec = resolve_spec(("a", "b", "c"), rules)
    # "model" used once; second use dropped
    assert spec == P("model", None, "data")


def test_resolve_multi_axis():
    rules = {"batch": ("pod", "data")}
    assert resolve_spec(("batch", None), rules) == P(("pod", "data"), None)


def test_train_rules_enable_fsdp_and_sp():
    cfg = get_config("command-r-35b")
    rules = rules_for(cfg, get_shape("train_4k"), MESH_1POD)
    assert rules["embed"] == "data"  # FSDP
    assert rules["act_seq"] == "model"  # sequence parallel
    assert rules["act_batch"] == ("data",)


def test_multipod_batch_uses_pod_axis():
    cfg = get_config("llama3.2-1b")
    rules = rules_for(cfg, get_shape("train_4k"), MESH_2POD)
    assert rules["act_batch"] == ("pod", "data")
    assert rules["embed"] == ("pod", "data")


def test_kv_head_divisibility_guard():
    cfg = get_config("granite-3-8b")  # kv=8 < 16-way model axis
    rules = rules_for(cfg, get_shape("decode_32k"), MESH_1POD)
    assert rules["kv_heads_act"] is None
    assert rules["cache_seq"] == ("model",)


def test_long_context_sequence_parallel():
    cfg = get_config("mamba2-2.7b")
    rules = rules_for(cfg, get_shape("long_500k"), MESH_1POD)
    assert rules["act_batch"] is None  # batch 1 cannot shard
    assert rules["act_seq"] == ("data",)


def test_arctic_head_guard():
    cfg = get_config("arctic-480b")  # 56 heads % 16 != 0
    rules = rules_for(cfg, get_shape("train_4k"), MESH_1POD)
    assert rules["heads_act"] is None
    assert rules["experts_act"] == "model"  # 128 % 16 == 0


def test_inference_fsdp_only_when_needed():
    small = get_config("llama3.2-1b")
    rules = rules_for(small, get_shape("decode_32k"), MESH_1POD)
    assert rules["embed"] is None  # 1.2B fits TP-only
    big = get_config("arctic-480b")
    rules_big = rules_for(big, get_shape("decode_32k"), MESH_1POD)
    assert rules_big["embed"] == "data"  # 480B needs ZeRO even to serve
