"""Zoo-wide offload planning: one verified plan per (arch, shape) cell.

``launch/serve.py`` / ``launch/train.py`` only *load* plans; this module is
the verification-environment side that produces them for the whole model
zoo.  For every requested (arch, kind) cell it builds the *real* step —
train / prefill / decode, the same builders production jits — wraps it in a
``BindingSpace`` over the function blocks that step exercises, runs a full
``OffloadSession`` lifecycle, and commits the winning plan to the store
under ``zoo:<arch>:<kind>``.  This is the BindingSpace analogue of what
``launch/dryrun.py`` does for compile stats.

  PYTHONPATH=src python -m repro.offload.zoo --plan-dir results/plans \\
      --arch llama3.2-1b --kind train --reduced

On a CPU container the Pallas shelf is typically not usable; the CLI
defaults to ``--targets ref,xla`` (include ``pallas`` on TPU hosts).
"""

from __future__ import annotations

import argparse
import dataclasses
import warnings
from typing import Any, Mapping, Sequence

from repro.core.planner import (
    BindingSpace,
    Objective,
    PlanStore,
    SearchStrategy,
)
from repro.offload.session import OffloadResult, OffloadSession

#: Shelf blocks each layer kind routes compute through (see repro.models).
_BLOCKS_BY_LAYER_KIND = {
    "a": ("rmsnorm", "attention"),
    "d": ("rmsnorm", "attention"),
    "s": ("rmsnorm", "attention"),
    "m": ("rmsnorm", "ssd_scan"),
}

#: Extra blocks the *decode* cell exercises per layer kind: decode cells
#: trace through the paged KV pool (the serving layout), so the hot-loop
#: attention read is the planner-searchable paged_attention block.
_DECODE_BLOCKS_BY_LAYER_KIND = {
    "a": ("paged_attention",),
    "d": ("paged_attention",),
    "s": ("paged_attention",),
}

ZOO_KINDS = ("train", "prefill", "decode")


def canonical_arch(arch: str) -> str:
    """Registry spelling of an arch name (``llama3.2_1b`` ->
    ``llama3.2-1b``); unknown names pass through unchanged so non-zoo
    callers (e.g. the report selftest) can use arbitrary labels."""
    try:
        from repro.configs import get_config

        return get_config(arch).name
    except Exception:  # noqa: BLE001 — unknown arch: keep caller's label
        return arch


def zoo_key(arch: str, kind: str) -> str:
    # canonicalised so every spelling a driver accepts (get_config is
    # permissive) addresses the same stored plan
    return f"zoo:{canonical_arch(arch)}:{kind}"


def default_plan_key(
    plan_dir: str | None,
    arch: str,
    kind: str,
    match_fingerprint: bool = False,
) -> str | None:
    """``zoo:<arch>:<kind>`` when the store actually holds that plan, else
    None — lets launch drivers default ``--plan-key`` without emitting
    "plan not found" noise on hosts that never ran the zoo sweep.

    By default presence only (fingerprint/registry compatibility is still
    enforced at bind time by ``OffloadSession.attach``).  Pass
    ``match_fingerprint=True`` when deciding whether a *search* is needed:
    a plan verified under a different environment would be rejected at
    bind time, so for search purposes it counts as missing.
    """
    if not plan_dir:
        return None
    key = zoo_key(arch, kind)
    plan = PlanStore(plan_dir).load(key, match_fingerprint=match_fingerprint)
    return None if plan is None else key


def launch_plan_keys(
    plan_dir: str | None,
    arch: str,
    kinds: Sequence[str],
    *,
    search: bool = False,
    targets: Sequence[str] | None = None,
    executor: Any = None,
    meter: Any = None,
) -> dict[str, str | None]:
    """The launch drivers' zoo-default flow, in one place: optionally
    search+commit any cell whose stored plan is absent **or verified under
    a different environment** (it would be rejected at bind time, so for
    search purposes it counts as missing), then return each kind's
    bindable default key (presence-checked; attach still enforces
    compatibility)."""
    if not plan_dir:
        return {kind: None for kind in kinds}
    if search:
        missing = [
            kind
            for kind in kinds
            if default_plan_key(plan_dir, arch, kind, match_fingerprint=True)
            is None
        ]
        if missing:
            print(f"searching offload plans for {arch}: {missing}")
            plan_zoo(
                plan_dir,
                [(arch, kind) for kind in missing],
                targets=targets,
                executor=executor,
                meter=meter,
                quiet=False,
            )
    return {
        kind: default_plan_key(plan_dir, arch, kind) for kind in kinds
    }


def _cell_blocks(
    cfg: Any,
    registry: Any,
    targets: Sequence[str] | None,
    kind: str = "train",
) -> dict[str, list[str]]:
    """Axes for one cell: the blocks this arch's step actually exercises,
    restricted to the requested (and registered) targets."""
    wanted: list[str] = []
    per_kind = dict(_BLOCKS_BY_LAYER_KIND)
    if kind == "decode":
        per_kind = {
            k: v + _DECODE_BLOCKS_BY_LAYER_KIND.get(k, ())
            for k, v in per_kind.items()
        }
    for kind_char in dict.fromkeys(cfg.pattern()):
        for b in per_kind.get(kind_char, ()):
            if b not in wanted:
                wanted.append(b)
    out: dict[str, list[str]] = {}
    for b in wanted:
        avail = registry.targets(b)
        chosen = [t for t in (targets or avail) if t in avail]
        if len(chosen) > 1:
            out[b] = chosen
    return out


def _materialize(spec: Mapping[str, Any], cfg: Any, rng: Any):
    """Concrete jnp inputs for a tree of ShapeDtypeStructs."""
    import jax.numpy as jnp

    out = {}
    for k, s in spec.items():
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[k] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, s.shape), s.dtype
            )
        else:
            out[k] = jnp.asarray(rng.standard_normal(s.shape), s.dtype)
    return out


def _cell_target(
    arch: str,
    kind: str,
    *,
    reduced: bool,
    layers: int,
    batch: int,
    seq: int,
    seed: int,
):
    """(step_builder, args, cfg) for one zoo cell, using the production
    step builders from ``launch/steps``."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import TrainHyper, input_specs, make_train_step
    from repro.models import lm
    from repro.optim.adamw import AdamW

    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if layers:
        cfg = dataclasses.replace(
            cfg,
            n_layers=layers,
            block_pattern=None if cfg.block_pattern is None
            else cfg.pattern()[:layers],
        )
    shape = ShapeConfig(f"zoo_{kind}", seq, batch, kind)  # type: ignore[arg-type]
    rng = np.random.default_rng(seed)
    params = lm.init_params(cfg, seed=seed)
    batch_tree = _materialize(input_specs(cfg, shape), cfg, rng)

    if kind == "train":
        opt = AdamW(moment_dtype=cfg.opt_dtype)
        step = make_train_step(
            cfg, opt, TrainHyper(warmup_steps=2, total_steps=16)
        )

        def builder():
            return jax.jit(step)

        args = (params, opt.init(params), batch_tree)
    elif kind == "prefill":
        def builder():
            return jax.jit(lambda p, b, c: lm.prefill(p, b, cfg, c))

        args = (params, batch_tree, lm.init_cache(cfg, batch, seq))
    elif kind == "decode":
        def builder():
            return jax.jit(lambda p, t, c: lm.decode_step(p, t, cfg, c))

        # attention-family decode traces through the block-paged KV pool
        # (the serving layout), so the cell's binding space includes the
        # paged_attention hot-loop block; pure-SSM archs have no sequence
        # axis to page and keep the contiguous state
        if any(ch in "ads" for ch in cfg.pattern()):
            import jax.numpy as jnp

            page_size = max(1, min(8, seq))
            max_pages = -(-seq // page_size)
            cache = lm.init_cache(
                cfg, batch, seq,
                page_size=page_size, n_pages=batch * max_pages,
            )
            # identity table: slot b owns pages [b*mp, (b+1)*mp); ragged
            # per-slot positions so the cell measures the staggered
            # continuous-batching case, not the aligned one
            cache = dict(
                cache,
                pages=jnp.arange(
                    batch * max_pages, dtype=jnp.int32
                ).reshape(batch, max_pages),
                index=jnp.arange(batch, dtype=jnp.int32) % jnp.int32(seq),
            )
        else:
            cache = lm.init_cache(cfg, batch, seq)
        args = (params, batch_tree["tokens"], cache)
    else:
        raise ValueError(f"unknown cell kind '{kind}'; known: {ZOO_KINDS}")
    return builder, args, cfg


def plan_zoo(
    store: PlanStore | str,
    cells: Sequence[tuple[str, str]] | None = None,
    *,
    reduced: bool = True,
    layers: int = 2,
    batch: int = 2,
    seq: int = 16,
    targets: Sequence[str] | None = None,
    objective: Objective | str | None = None,
    strategy: SearchStrategy | None = None,
    executor: Any = None,
    meter: Any = None,
    repeats: int = 1,
    min_seconds: float = 0.0,
    registry: Any = None,
    seed: int = 0,
    verify: bool = False,
    force_search: bool = False,
    legality: bool = False,
    resources: Any = False,
    quiet: bool = True,
) -> dict[tuple[str, str], OffloadResult]:
    """Search and persist an offload plan for every (arch, kind) cell.

    ``cells`` defaults to every registered architecture x every step kind.
    Already-stored compatible plans short-cut to zero measurements (pass
    ``force_search=True`` to re-measure).  ``executor`` / ``meter`` select
    the ``repro.metering`` measurement executor (e.g. ``device_parallel``
    on multi-device hosts) and power meter (``"auto"`` autodetects, with
    provenance recorded on every trial).  ``legality=True`` runs the
    ``repro.analysis`` static legality pass per cell so strategies prune
    statically-illegal bindings instead of measuring them (required when
    ``targets`` includes 'pallas' on a non-TPU host).  ``resources``
    (True / "host" / an envelope name / a ``DeviceEnvelope``) additionally
    runs the memory-envelope pass so statically-OOM bindings are pruned
    before measurement — the paper's FPGA resource-fit check.  Returns
    ``{(arch, kind): OffloadResult}``; cells whose step cannot be built or
    measured on this host are skipped with a ``UserWarning`` (regardless
    of ``quiet``, which only silences progress lines) rather than
    aborting the sweep.
    """
    from repro.configs import ARCH_NAMES
    from repro.core import blocks as blocks_mod
    from repro.metering import resolve_meter

    registry = registry or blocks_mod.registry
    store = PlanStore(store) if isinstance(store, str) else store
    meter = resolve_meter(meter)
    if cells is None:
        cells = [(a, k) for a in ARCH_NAMES for k in ZOO_KINDS]

    results: dict[tuple[str, str], OffloadResult] = {}
    for arch, kind in cells:
        try:
            builder, args, cfg = _cell_target(
                arch, kind, reduced=reduced, layers=layers, batch=batch,
                seq=seq, seed=seed,
            )
            block_map = _cell_blocks(cfg, registry, targets, kind)
            if not block_map:
                if not quiet:
                    print(f"zoo cell {arch}:{kind}: no searchable blocks "
                          f"for targets={targets}; skipped")
                continue
            space = BindingSpace(
                builder,
                blocks=block_map,
                registry=registry,
                tag=f"zoo:{arch}:{kind}:b{batch}xs{seq}",
            )
            session = OffloadSession(
                space,
                args=args,
                objective=objective,
                strategy=strategy,
                store=store,
                key=zoo_key(arch, kind),
                meter=meter,
                executor=executor,
                repeats=repeats,
                min_seconds=min_seconds,
                registry=registry,
                force_search=force_search,
                legality=legality,
                resources=resources,
            )
            result = session.run(verify=verify)
        except Exception as e:  # noqa: BLE001 — keep sweeping other cells
            warnings.warn(
                f"zoo cell {arch}:{kind} failed: {type(e).__name__}: {e}",
                stacklevel=2,
            )
            continue
        results[(arch, kind)] = result
        if not quiet:
            src = "store" if result.from_store else result.plan.strategy
            pruned = getattr(result.report, "pruned", 0) if result.report else 0
            pruned_note = f" pruned={pruned}" if pruned else ""
            print(
                f"zoo cell {arch}:{kind}: {result.mapping or '(baseline)'} "
                f"speedup={result.speedup:.2f}x via {src} "
                f"[{result.objective}]{pruned_note}"
            )
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--plan-dir", required=True,
                    help="PlanStore directory to commit plans into")
    ap.add_argument("--arch", default="all",
                    help="comma-separated arch names, or 'all'")
    ap.add_argument("--kind", default="all",
                    help="comma-separated step kinds (train,prefill,decode)")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="search reduced configs (--no-reduced for full "
                         "production configs on real hardware)")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--targets", default="ref,xla",
                    help="comma-separated targets to search over "
                         "(add 'pallas' on TPU hosts)")
    ap.add_argument("--legality", action="store_true",
                    help="run the repro.analysis static legality pass per "
                         "cell; statically-illegal bindings are pruned "
                         "from the search instead of measured")
    ap.add_argument("--resources", action="store_true",
                    help="run the repro.analysis memory-envelope pass per "
                         "cell; statically-OOM bindings are pruned from "
                         "the search instead of measured")
    ap.add_argument("--envelope", default=None,
                    help="device envelope for --resources: a static name "
                         "(e.g. a100-40g, cpu-host-16g, tiny-32m) or "
                         "'host' to probe the live device (default)")
    ap.add_argument("--objective", default="latency",
                    help="latency | perf_per_watt")
    ap.add_argument("--executor", default="serial",
                    help="measurement executor: serial | device-parallel "
                         "| batched (repro.metering)")
    ap.add_argument("--meter", default="none",
                    help="power meter: none | auto | time | nvml | rapl | "
                         "psutil (provenance recorded per trial)")
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--force", action="store_true",
                    help="re-search even when a stored plan exists")
    ap.add_argument("--verify", action="store_true",
                    help="run the numerics stage per cell")
    args = ap.parse_args()

    from repro.configs import ARCH_NAMES

    archs = ARCH_NAMES if args.arch == "all" else args.arch.split(",")
    kinds = ZOO_KINDS if args.kind == "all" else tuple(args.kind.split(","))
    cells = [(a, k) for a in archs for k in kinds]
    results = plan_zoo(
        args.plan_dir,
        cells,
        reduced=args.reduced,
        layers=args.layers,
        batch=args.batch,
        seq=args.seq,
        targets=tuple(args.targets.split(",")),
        objective=args.objective,
        executor=args.executor,
        meter=args.meter,
        repeats=args.repeats,
        verify=args.verify,
        force_search=args.force,
        legality=args.legality,
        resources=(args.envelope or True) if args.resources else False,
        quiet=False,
    )
    print(f"planned {len(results)}/{len(cells)} cells -> {args.plan_dir}")


if __name__ == "__main__":
    main()
