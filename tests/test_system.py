"""End-to-end behaviour tests for the paper's system.

The headline claims, verified in miniature on this container:
  1. function-block offloading finds and substitutes accelerated blocks for
     both discovery paths (name match + similarity) and the result is both
     *correct* and *faster*;
  2. function-block offload beats loop-level offload on the same app
     (the paper's central comparison, Fig. 5);
  3. the search completes without a GA (paper: minutes vs hours);
  4. a training job with the full substrate stack (data, optimizer,
     checkpointing, fault injection) survives failures and learns.
"""

import numpy as np
import pytest

from repro.apps import fourier
from repro.core import OffloadEngine, run_ga


def test_block_offload_beats_loop_offload_fft():
    """Paper Fig. 5, in kind: block-level >> loop-level on the same app."""
    x = fourier.make_input(128)
    eng = OffloadEngine()

    res = eng.adapt(fourier.fourier_app_libcall, (x,), repeats=1)
    assert res.numerics_ok
    block_speedup = res.verification.best.speedup

    ga = run_ga(
        fourier.build_fft_variant,
        n_genes=len(fourier.FFT_STAGES),
        args=(x,),
        population=6,
        generations=3,
        repeats=1,
        seed=0,
    )
    loop_speedup = ga.best_speedup

    assert block_speedup > loop_speedup
    # and the search itself is faster than the GA (paper: minutes vs hours)
    assert res.verification.search_seconds < ga.search_seconds * 2


def test_end_to_end_training_with_failures(tmp_path):
    """~1M-param model, 30 steps, one injected node failure: loss drops and
    recovery works."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLMData
    from repro.checkpoint.manager import CheckpointManager
    from repro.launch.steps import TrainHyper, make_train_step
    from repro.models import lm
    from repro.optim.adamw import AdamW
    from repro.runtime.fault import FaultTolerantLoop, InjectedFailure

    cfg = get_config("llama3.2-1b").reduced()
    data = SyntheticLMData(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, structure=1.0
    )
    opt = AdamW(weight_decay=0.0)
    step_jit = jax.jit(
        make_train_step(cfg, opt, TrainHyper(base_lr=5e-3, warmup_steps=5,
                                             total_steps=80))
    )
    params = lm.init_params(cfg, seed=0)
    state = {"params": params, "opt": opt.init(params)}

    losses = []

    def step_fn(state, batch, step):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, metrics = step_jit(state["params"], state["opt"], b)
        losses.append(float(metrics["loss"]))
        return {"params": p, "opt": o}

    failed = {"done": False}

    def failure_hook(step):
        if step == 27 and not failed["done"]:
            failed["done"] = True
            raise InjectedFailure("simulated preemption")

    loop = FaultTolerantLoop(
        step_fn=step_fn,
        batch_fn=data.batch_at,
        ckpt=CheckpointManager(tmp_path),
        ckpt_every=10,
        failure_hook=failure_hook,
    )
    res = loop.run(state, 60)
    assert res.restarts == 1
    assert res.completed_steps == 60
    # learning happened despite the failure
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.25
