"""HLO roofline cost model as a search pre-filter.

``launch/hlo_cost.py`` re-derives loop-aware FLOPs / HBM bytes from compiled
HLO text; here those feed a roofline estimate (seconds lower-bounded by
compute and by memory traffic) that ``CostGuidedSearch`` uses to rank
candidates before any measurement — the paper's FPGA narrowing step, where
estimating is cheap (one compile) and measuring is expensive.

The peak numbers default to the TPU v5e hardware model used by the
roofline benchmarks (``launch/mesh.HW``); only the *relative* ranking
matters for candidate narrowing, so they need not match the machine the
verification environment runs on.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

from repro.core.planner.space import Candidate, SearchSpace

# TPU v5e, kept in sync with repro.launch.mesh.HW (not imported to keep the
# planner importable without the launch stack).
PEAK_FLOPS = 197e12  # per chip, bf16
PEAK_HBM_BW = 819e9  # bytes/s per chip


def roofline_seconds(
    fn: Callable[..., Any],
    args: Sequence[Any],
    peak_flops: float = PEAK_FLOPS,
    peak_hbm_bw: float = PEAK_HBM_BW,
) -> float:
    """Lower-bound runtime of a jax-traceable callable from its compiled HLO.

    Raises whatever jax raises when ``fn`` cannot be traced/compiled —
    CostGuidedSearch treats that as an unrankable candidate.
    """
    import jax

    from repro.launch import hlo_cost

    compiled = jax.jit(fn).lower(*args).compile()
    c = hlo_cost.analyze(compiled.as_text())
    t_compute = c["flops"] / peak_flops
    t_memory = c["hbm_bytes"] / peak_hbm_bw
    return max(t_compute, t_memory, 1e-12)


def make_roofline_cost_fn(
    peak_flops: float = PEAK_FLOPS,
    peak_hbm_bw: float = PEAK_HBM_BW,
) -> Callable[[SearchSpace, Candidate, Sequence[Any]], float]:
    """Cost function for CostGuidedSearch: build the candidate variant and
    score it with the roofline model."""

    def cost_fn(
        space: SearchSpace, cand: Candidate, args: Sequence[Any]
    ) -> float:
        fn = space.build(cand)
        return roofline_seconds(
            fn, args, peak_flops=peak_flops, peak_hbm_bw=peak_hbm_bw
        )

    return cost_fn
