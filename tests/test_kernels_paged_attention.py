"""Fused Pallas paged-attention block: parity, shelf metadata, planner
search, and serve-level token identity.

The parity tests run the fused kernel in interpret mode (the CPU-CI
path) against an independent float64 dense oracle AND against the XLA
gather-then-attend implementation, across decode (S=1) and extend (S>1)
chunks, GQA and MLA layouts, ragged per-slot lengths, page boundaries,
final partial pages and null-page table entries.  The integration tests
pin the acceptance criteria: both shelf targets carry legality/resource
metadata regardless of import order, the zoo decode search prunes the
TPU-only kernel statically on CPU while still committing a plan that
binds the block, the fused program's peak live bytes sit strictly below
the gather path's at serving-scale shapes, and a served greedy trace is
token-for-token identical under ``decode_impl="pallas"``.
"""

import dataclasses
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.paged_attention import (
    gather_kv_pages,
    paged_attention_pallas,
    paged_attention_xla,
    scatter_chunk_pages,
    scatter_token_pages,
)
from repro.serve import Request, ServeEngine

CFG = get_config("llama3.2-1b").reduced()
# token-identity comparisons across different decode programs: f32 keeps
# greedy argmax ties deterministic (same convention as test_serve_kv)
F32 = dataclasses.replace(CFG, compute_dtype="float32", remat="none")


# -- paged operand builder + dense float64 oracle ------------------------------


def _paged_case(rng, *, b, h, kh, s, dk, dv, ps, mp, lengths, dr=0):
    """Identity-table paged operands with per-slot logical lengths.

    ``lengths[i]`` is slot ``i``'s history length (== the first new-token
    position); table entries past the pages needed to hold
    ``lengths[i] + s`` tokens point at the null page, whose contents are
    poisoned to catch any unmasked read.
    """
    n_pages = b * mp
    null = n_pages
    k_pool = rng.standard_normal((n_pages + 1, kh, ps, dk)).astype(np.float32)
    v_pool = rng.standard_normal((n_pages + 1, kh, ps, dv)).astype(np.float32)
    k_pool[null] = 1e6  # poison: masked rows must never contribute
    v_pool[null] = 1e6
    q = rng.standard_normal((b, h, s, dk)).astype(np.float32)
    pages = np.arange(n_pages, dtype=np.int32).reshape(b, mp)
    for i, ln in enumerate(lengths):
        used = -(-(ln + s) // ps)
        pages[i, used:] = null
    index = np.asarray(lengths, np.int32)
    case = {
        "q": jnp.asarray(q),
        "k_pool": jnp.asarray(k_pool),
        "v_pool": jnp.asarray(v_pool),
        "pages": jnp.asarray(pages),
        "index": jnp.asarray(index),
    }
    if dr:
        kr_pool = rng.standard_normal((n_pages + 1, 1, ps, dr))
        kr_pool = kr_pool.astype(np.float32)
        kr_pool[null] = 1e6
        case["q_rope"] = jnp.asarray(
            rng.standard_normal((b, h, s, dr)).astype(np.float32)
        )
        case["kr_pool"] = jnp.asarray(kr_pool)
        case["scale"] = 1.0 / float(np.sqrt(dk + dr))
    return case


def _oracle(case):
    """Dense float64 reference: gather every page, mask by position."""
    q = np.asarray(case["q"], np.float64)
    b, h, s, dk = q.shape
    k_pool = np.asarray(case["k_pool"], np.float64)
    v_pool = np.asarray(case["v_pool"], np.float64)
    pages = np.asarray(case["pages"])
    index = np.asarray(case["index"])
    kh, ps = k_pool.shape[1], k_pool.shape[2]
    g = h // kh

    def view(pool):  # (b, mp, kh, ps, d) -> (b, kh, mp*ps, d)
        v = pool[pages]
        return np.moveaxis(v, 2, 1).reshape(b, kh, -1, pool.shape[-1])

    kv, vv = view(k_pool), view(v_pool)
    qg = q.reshape(b, kh, g, s, dk)
    sc = np.einsum("bkgqd,bktd->bkgqt", qg, kv)
    if "q_rope" in case:
        qr = np.asarray(case["q_rope"], np.float64)
        qr = qr.reshape(b, kh, g, s, -1)
        sc = (sc + np.einsum(
            "bkgqd,bktd->bkgqt", qr, view(np.asarray(case["kr_pool"],
                                                     np.float64))
        )) * case["scale"]
    else:
        sc = sc / np.sqrt(dk)
    pos = np.arange(kv.shape[2])
    qpos = index[:, None] + np.arange(s)
    mask = pos[None, None, None, None, :] <= qpos[:, None, None, :, None]
    sc = np.where(mask, sc, -np.inf)
    p = np.exp(sc - sc.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = np.einsum("bkgqt,bktd->bkgqd", p, vv)
    return o.reshape(b, h, s, v_pool.shape[-1])


# lengths exercise: index 0 (empty history), a write landing exactly on a
# page boundary, a final partial page, and a fully ragged mix
GQA_CASES = [
    # (s, lengths) with ps=8, mp=4
    (1, (15, 8)),   # decode: last slot of page 2 / first slot of page 2
    (1, (0, 31)),   # decode: empty history / final table slot
    (4, (12, 0)),   # extend: mid-page / from scratch
    (4, (6, 20)),   # extend: chunk crosses a page boundary
]


@pytest.mark.parametrize("s,lengths", GQA_CASES)
def test_paged_parity_gqa(s, lengths, rng):
    case = _paged_case(
        rng, b=2, h=4, kh=2, s=s, dk=32, dv=32, ps=8, mp=4, lengths=lengths
    )
    want = _oracle(case)
    got_xla = paged_attention_xla(**case)
    got_pallas = paged_attention_pallas(**case, interpret=True)
    np.testing.assert_allclose(np.asarray(got_xla), want,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_pallas), want,
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("s,lengths", [(1, (15, 8)), (4, (6, 20))])
def test_paged_parity_mla(s, lengths, rng):
    # MLA layout: shared latent K/V (kh=1), decoupled rope scores folded
    # in before the softmax, explicit 1/sqrt(dk+dr) scale
    case = _paged_case(
        rng, b=2, h=4, kh=1, s=s, dk=32, dv=32, ps=8, mp=4,
        lengths=lengths, dr=16,
    )
    want = _oracle(case)
    got_xla = paged_attention_xla(**case)
    got_pallas = paged_attention_pallas(**case, interpret=True)
    np.testing.assert_allclose(np.asarray(got_xla), want,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_pallas), want,
                               rtol=1e-4, atol=1e-4)


def test_paged_parity_uneven_final_page(rng):
    # mp*ps leaves the final page partially filled at max length
    case = _paged_case(
        rng, b=2, h=4, kh=2, s=1, dk=32, dv=32, ps=8, mp=3,
        lengths=(17, 23),
    )
    np.testing.assert_allclose(
        np.asarray(paged_attention_pallas(**case, interpret=True)),
        _oracle(case), rtol=1e-4, atol=1e-4,
    )


def test_paged_block_call_dispatches(rng):
    # the registered shelf entries resolve to the same numerics
    from repro.core import blocks

    case = _paged_case(
        rng, b=2, h=4, kh=2, s=1, dk=32, dv=32, ps=8, mp=2, lengths=(5, 9)
    )
    want = _oracle(case)
    for target in ("xla", "pallas"):
        with blocks.bind({"paged_attention": target}):
            got = blocks.call("paged_attention", *(
                case[k] for k in ("q", "k_pool", "v_pool", "pages", "index")
            ))
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=1e-4, atol=1e-4)


# -- page walk + scatter helpers -----------------------------------------------


@pytest.mark.parametrize("mp", [1, 4])
def test_rolled_gather_matches_advanced_indexing(mp, rng):
    pool = jnp.asarray(
        rng.standard_normal((2 * mp + 1, 2, 8, 16)), jnp.float32
    )
    pages = jnp.asarray(
        rng.integers(0, 2 * mp + 1, (2, mp)).astype(np.int32)
    )
    got = gather_kv_pages(pool, pages, seq_axis=2)
    want = np.moveaxis(np.asarray(pool)[np.asarray(pages)], 2, 1)
    want = want.reshape(2, 2, mp * 8, 16)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_scatter_chunk_matches_token_scatter(rng):
    pool = jnp.zeros((5, 2, 4, 8), jnp.float32)
    pages = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    index = jnp.asarray([3, 1], jnp.int32)  # chunk crosses a page boundary
    val = jnp.asarray(rng.standard_normal((2, 2, 3, 8)), jnp.float32)
    got = scatter_chunk_pages(pool, val, pages, index, seq_axis=2)
    want = pool
    for i in range(3):
        want = scatter_token_pages(
            want, val[:, :, i], pages, index + i, seq_axis=2
        )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -- shelf metadata: import-order independence + coverage ----------------------

_SNAPSHOT_SRC = """
import json
{imports}
from repro import kernels
from repro.core import blocks

print(json.dumps({{
    "fingerprint": kernels.SHELF_FINGERPRINT,
    "legality": sorted(",".join(k) for k in kernels.BLOCK_LEGALITY),
    "resources": sorted(",".join(k) for k in kernels.BLOCK_RESOURCES),
    "attention_xla_module": blocks.registry.implementation(
        "attention", "xla").fn.__module__,
    "paged_targets": sorted(blocks.registry.targets("paged_attention")),
}}))
"""


def _shelf_snapshot(imports):
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]
    )
    out = subprocess.run(
        [sys.executable, "-c", _SNAPSHOT_SRC.format(imports=imports)],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert out.returncode == 0, out.stderr
    return json.loads(out.stdout)


def test_shelf_independent_of_import_order():
    """models.attention first vs kernels first must produce the same
    shelf: same fingerprint, same metadata keys, and attention/xla
    resolving to the kernels-owned implementation (the historical bug:
    whichever module imported second silently re-registered it)."""
    a = _shelf_snapshot("import repro.kernels\nimport repro.models.attention")
    b = _shelf_snapshot("import repro.models.attention\nimport repro.kernels")
    assert a == b
    assert a["attention_xla_module"] == "repro.kernels.attention_xla"
    assert "paged_attention,xla" in a["legality"]
    assert "paged_attention,pallas" in a["legality"]
    assert "paged_attention,xla" in a["resources"]
    assert "paged_attention,pallas" in a["resources"]
    assert a["paged_targets"] == ["pallas", "xla"]


def test_shelf_coverage_lint_passes():
    from repro.analysis.resources import lint_shelf_coverage

    assert lint_shelf_coverage() == []


def test_pallas_target_legality_is_tpu_only():
    from repro import kernels

    cons = kernels.BLOCK_LEGALITY[("paged_attention", "pallas")]
    assert cons.requires_platform == ("tpu",)
    # the gather path runs anywhere — it's the measured CPU baseline
    assert not kernels.BLOCK_LEGALITY[
        ("paged_attention", "xla")].requires_platform


# -- static resources: fused walk beats the gathered view ----------------------


def test_fused_decode_peak_live_bytes_below_gather():
    """At serving-scale shapes the fused program's peak live bytes sit
    strictly below the gather path's — the gathered per-slot K/V view is
    the dominant decode intermediate, and the fused kernel never
    materialises it."""
    from repro.analysis.resources import estimate_memory
    from repro.core import blocks
    from repro.offload.zoo import _cell_target

    builder, args, _ = _cell_target(
        "llama3.2-1b", "decode", reduced=True, layers=2, batch=4,
        seq=256, seed=0,
    )
    peaks = {}
    for target in ("xla", "pallas"):
        with blocks.bind({"paged_attention": target}):
            peaks[target] = estimate_memory(builder(), *args).peak_live_bytes
    assert peaks["pallas"] < peaks["xla"], peaks


# -- planner: the decode cell searches the paged block -------------------------


def test_zoo_decode_plan_searches_paged_block(tmp_path):
    """The zoo decode cell exposes ``paged_attention`` as a search axis:
    on CPU the legality pass prunes every pallas candidate statically
    (the fused kernel is TPU-only), the measured winner binds the gather
    implementation, and the committed plan records the block."""
    from repro.offload.zoo import plan_zoo

    results = plan_zoo(
        str(tmp_path), [("llama3.2-1b", "decode")],
        targets=("xla", "pallas"), reduced=True, layers=1, batch=2,
        seq=8, legality=True,
    )
    r = results[("llama3.2-1b", "decode")]
    assert r.mapping["paged_attention"] == "xla"
    assert r.report is not None and r.report.pruned > 0


# -- serve-level: --decode-impl forces the fused kernel ------------------------


def _run_trace(engine, prompts, gens, max_steps=800):
    ids = [
        engine.submit(Request(p, max_new_tokens=g))
        for p, g in zip(prompts, gens)
    ]
    engine.run_until_idle(max_steps=max_steps)
    return [engine.completions[i].tokens for i in ids]


def test_serve_decode_impl_token_identical(rng):
    """A greedy paged trace under ``decode_impl="pallas"`` (interpret
    mode on CPU) is token-for-token identical to the default binding —
    the acceptance bar for trusting the fused kernel in the hot loop."""
    prompts = [
        rng.integers(0, CFG.vocab_size, n).tolist() for n in (5, 9, 4)
    ]
    gens = (6, 4, 5)
    traces = {
        impl: _run_trace(
            ServeEngine(F32, n_slots=3, max_len=32, seed=0, page_size=4,
                        decode_impl=impl),
            prompts, gens,
        )
        for impl in ("auto", "pallas")
    }
    assert traces["pallas"] == traces["auto"]


def test_engine_decode_impl_validation():
    with pytest.raises(ValueError, match="decode_impl"):
        ServeEngine(F32, n_slots=2, max_len=32, seed=0, page_size=4,
                    decode_impl="cuda")
    with pytest.raises(ValueError, match="page"):
        ServeEngine(F32, n_slots=2, max_len=32, seed=0,
                    decode_impl="pallas")  # paged cache required
