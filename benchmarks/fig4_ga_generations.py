"""Paper Fig. 4: best performance (vs all-CPU) per GA generation for the
Fourier-transform application under prior-work loop offloading [33]."""

from __future__ import annotations

import argparse
import warnings

from benchmarks.common import emit


def run(n: int = 192, generations: int = 8, population: int = 8,
        seed: int = 0) -> list[float]:
    warnings.filterwarnings("ignore")
    from repro.apps import fourier
    from repro.core import run_ga

    x = fourier.make_input(n)
    rep = run_ga(
        fourier.build_fft_variant,
        n_genes=len(fourier.FFT_STAGES),
        args=(x,),
        population=population,
        generations=generations,
        repeats=1,
        seed=seed,
    )
    for gen, speedup in enumerate(rep.generations):
        emit(f"fig4.gen{gen}", rep.baseline_seconds / max(speedup, 1e-9),
             f"best_speedup={speedup:.2f}x")
    emit(
        "fig4.final", rep.best_seconds,
        f"best_speedup={rep.best_speedup:.2f}x genome="
        f"{''.join(map(str, rep.best_genome))} evals={rep.evaluations} "
        f"search={rep.search_seconds:.1f}s",
    )
    return rep.generations


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=192)
    ap.add_argument("--generations", type=int, default=8)
    ap.add_argument("--population", type=int, default=8)
    args = ap.parse_args()
    run(args.n, args.generations, args.population)


if __name__ == "__main__":
    main()
