"""Deckard-style similarity detection (B-2) — incl. hypothesis properties."""

import inspect

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import default_db, similarity
from repro.apps import fourier, matrix

DB = default_db()

CODES = [
    fourier.REFERENCE_CODE,
    matrix.REFERENCE_CODE,
    inspect.getsource(fourier.my_fft1d),
    inspect.getsource(fourier.unrelated_helper),
    inspect.getsource(matrix.my_ludcmp),
    "def f(x):\n    return x + 1\n",
    "def g(a, b):\n    for i in range(10):\n        a = a * b\n    return a\n",
]


def test_copied_code_matches_reference():
    src = inspect.getsource(fourier.my_fft2d) + inspect.getsource(fourier.my_fft1d)
    assert similarity.similarity(src, fourier.REFERENCE_CODE) > 0.95


def test_copied_lu_matches_reference():
    src = inspect.getsource(matrix.my_ludcmp)
    assert similarity.similarity(src, matrix.REFERENCE_CODE) > 0.95


def test_unrelated_code_rejected():
    src = inspect.getsource(fourier.unrelated_helper)
    for entry in DB.entries_with_reference():
        assert similarity.similarity(src, entry.reference_code) < 0.7


def test_cross_family_below_threshold():
    # FFT reference vs LU reference: related (loopy numerics) but distinct
    s = similarity.similarity(fourier.REFERENCE_CODE, matrix.REFERENCE_CODE)
    assert s < similarity.DEFAULT_THRESHOLD


@given(st.sampled_from(CODES))
def test_self_similarity_is_one(code):
    assert similarity.similarity(code, code) == pytest.approx(1.0)


@given(st.sampled_from(CODES), st.sampled_from(CODES))
def test_symmetry(a, b):
    assert similarity.similarity(a, b) == pytest.approx(
        similarity.similarity(b, a)
    )


@given(st.sampled_from(CODES), st.sampled_from(CODES))
def test_bounded(a, b):
    s = similarity.similarity(a, b)
    assert 0.0 <= s <= 1.0


@given(st.sampled_from(CODES))
def test_rename_invariance(code):
    import re

    # rename identifiers (word-boundary, avoiding keywords): structure-only
    renamed = re.sub(r"\bdata\b", "zz9", code)
    renamed = re.sub(r"\brow\b", "qq7", renamed)
    renamed = re.sub(r"\bmat\b", "pp8", renamed)
    assert similarity.similarity(code, renamed) == pytest.approx(1.0)


def test_find_similar_end_to_end():
    from repro.core.ast_analysis import FuncDef

    fd = FuncDef(
        name="clone",
        lineno=1,
        source=inspect.getsource(matrix.my_ludcmp),
        kind="function",
        calls=(),
    )
    hits = similarity.find_similar([fd], DB.entries_with_reference())
    assert len(hits) == 1 and hits[0].db_name == "lu"
