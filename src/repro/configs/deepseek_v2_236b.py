"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.
arXiv:2405.04434."""

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,  # MLA: all heads share the compressed kv latent
    d_head=128,
    d_ff=12288,  # the leading dense layer's FFN
    vocab_size=102400,
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        d_expert=1536,
        n_shared=2,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    first_k_dense=1,
    rope_theta=10000.0,
    param_dtype="bfloat16",
    opt_dtype="bfloat16",
)
