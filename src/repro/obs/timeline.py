"""Terminal span summary over an exported trace.

``python -m repro.obs.timeline trace.json`` loads a Chrome/Perfetto
``trace_event`` JSON file (or the JSONL stream form) written by
:class:`repro.obs.Tracer` and prints:

* a per-span-kind table — count, total time, p50/p99 durations — the
  quick "where did the time go" answer without opening a UI;
* the critical path of the worst request: the request whose submit ->
  complete makespan was largest, with its lifecycle spans (queue,
  kv-alloc, prefill, decode steps, preemptions) in time order and the
  gaps between them.

``--check`` additionally validates the file (parseable, every event
carries name/ph/ts, timestamps non-negative and durations non-negative)
and exits non-zero on violations — the CI smoke job's trace gate.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Sequence

__all__ = ["load_events", "span_summary", "worst_request", "main"]


def load_events(path: str) -> list[dict]:
    """Events from a ``{"traceEvents": [...]}`` JSON file or a JSONL
    stream (one event object per line)."""
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        # not one document -> the JSONL stream form, one object per line
        events = [
            json.loads(line) for line in text.splitlines() if line.strip()
        ]
    else:
        events = doc.get("traceEvents", []) if isinstance(doc, dict) else doc
    return [e for e in events if isinstance(e, dict)]


def validate(events: Sequence[dict]) -> list[str]:
    """Structural problems that would break a trace viewer."""
    problems: list[str] = []
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            continue  # metadata events carry no timestamp
        if not ev.get("name"):
            problems.append(f"event {i}: missing name")
        if ph not in ("X", "i", "B", "E"):
            problems.append(f"event {i}: unknown phase {ph!r}")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: bad dur {dur!r}")
    spans = [e for e in events if e.get("ph") == "X"]
    for a, b in zip(spans, spans[1:]):
        if b.get("ts", 0) < a.get("ts", 0):
            problems.append("span timestamps are not monotonically sorted")
            break
    return problems


def _pct(sorted_xs: Sequence[float], q: float) -> float:
    if not sorted_xs:
        return 0.0
    idx = min(int(q * (len(sorted_xs) - 1) + 0.5), len(sorted_xs) - 1)
    return sorted_xs[idx]


def span_summary(events: Sequence[dict]) -> list[dict]:
    """Per span-kind aggregate rows, ordered by total time descending."""
    durs: dict[str, list[float]] = defaultdict(list)
    for ev in events:
        if ev.get("ph") == "X":
            durs[ev["name"]].append(float(ev.get("dur", 0.0)))
    rows = []
    for name, xs in durs.items():
        xs.sort()
        rows.append({
            "name": name,
            "count": len(xs),
            "total_ms": sum(xs) / 1e3,
            "p50_ms": _pct(xs, 0.5) / 1e3,
            "p99_ms": _pct(xs, 0.99) / 1e3,
        })
    rows.sort(key=lambda r: -r["total_ms"])
    return rows


def _request_of(ev: dict) -> Any:
    args = ev.get("args") or {}
    return args.get("request")


def worst_request(events: Sequence[dict]) -> tuple[Any, list[dict]] | None:
    """(request id, its spans in time order) for the request with the
    largest makespan; None when the trace carries no request spans."""
    per_req: dict[Any, list[dict]] = defaultdict(list)
    for ev in events:
        if ev.get("ph") in ("X", "i") and _request_of(ev) is not None:
            per_req[_request_of(ev)].append(ev)
    if not per_req:
        return None

    def makespan(evs: list[dict]) -> float:
        t0 = min(e["ts"] for e in evs)
        t1 = max(e["ts"] + e.get("dur", 0.0) for e in evs)
        return t1 - t0

    worst = max(per_req, key=lambda r: makespan(per_req[r]))
    return worst, sorted(per_req[worst], key=lambda e: e["ts"])


def render(events: Sequence[dict], max_path: int = 40) -> str:
    lines: list[str] = []
    rows = span_summary(events)
    if rows:
        lines.append(
            f"{'span':<16} {'count':>7} {'total ms':>10} "
            f"{'p50 ms':>9} {'p99 ms':>9}"
        )
        for r in rows:
            lines.append(
                f"{r['name']:<16} {r['count']:>7} {r['total_ms']:>10.2f} "
                f"{r['p50_ms']:>9.3f} {r['p99_ms']:>9.3f}"
            )
    else:
        lines.append("no complete spans in trace")

    worst = worst_request(events)
    if worst is not None:
        req, path = worst
        t_origin = path[0]["ts"]
        t_end = max(e["ts"] + e.get("dur", 0.0) for e in path)
        lines.append("")
        lines.append(
            f"critical path of worst request (request={req}, "
            f"makespan {(t_end - t_origin) / 1e3:.2f} ms):"
        )
        prev_end = t_origin
        shown = path[:max_path]
        for ev in shown:
            gap = ev["ts"] - prev_end
            dur = ev.get("dur", 0.0)
            mark = f"  +{gap / 1e3:.3f} ms gap" if gap > 1.0 else ""
            lines.append(
                f"  {ev['name']:<16} @{(ev['ts'] - t_origin) / 1e3:>9.3f} ms"
                f"  dur {dur / 1e3:>8.3f} ms{mark}"
            )
            prev_end = max(prev_end, ev["ts"] + dur)
        if len(path) > len(shown):
            lines.append(f"  ... {len(path) - len(shown)} more spans")
    return "\n".join(lines)


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.timeline",
        description=__doc__.split("\n")[0],
    )
    ap.add_argument("trace", help="trace_event JSON (or JSONL) file")
    ap.add_argument("--check", action="store_true",
                    help="validate the trace structure; non-zero exit on "
                         "violations (CI gate)")
    ap.add_argument("--max-path", type=int, default=40,
                    help="max spans printed for the critical path")
    args = ap.parse_args(argv)

    try:
        events = load_events(args.trace)
    except (OSError, json.JSONDecodeError) as e:
        print(f"timeline: cannot load {args.trace}: {e}", file=sys.stderr)
        return 2
    problems = validate(events)
    if problems:
        for p in problems:
            print(f"timeline: INVALID: {p}", file=sys.stderr)
        if args.check:
            return 1
    elif args.check:
        print(f"timeline: {args.trace} OK "
              f"({sum(1 for e in events if e.get('ph') == 'X')} spans, "
              f"{len(events)} events)")
    print(render(events, max_path=args.max_path))
    return 0


if __name__ == "__main__":
    sys.exit(main())
