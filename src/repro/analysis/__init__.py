"""repro.analysis — static analysis over traced programs (paper Step 1).

Four passes, each producing typed :class:`~repro.analysis.Diagnostic`s:

* **legality** (``repro.analysis.legality``) — classify every shelf-block
  (block, target) binding legal / illegal / unknown before measurement;
  feeds ``BindingSpace.mark_illegal`` so search strategies prune instead
  of timing.
* **resources** (``repro.analysis.resources``) — the paper's FPGA
  resource-fit check (Step 5) for GPU/TPU memory: peak-live-bytes per
  traced program via jaxpr liveness analysis, per-binding fit verdicts
  against a :class:`DeviceEnvelope`, and a static serve capacity planner
  (``plan_serve_capacity`` / ``serve --preflight``).
* **hotpath** (``repro.analysis.hotpath``) — lint jitted serve programs
  for host-sync, retrace-risk, callbacks and constant-capture bloat.
* **paging** (``repro.analysis.paging``) — prove the paged-KV page-table
  operand free of page aliasing and freed-slot writes.

``python -m repro.analysis.lint`` runs all passes over the configs zoo and
live engines, diffing against the checked-in ``analysis_baseline.json``.
"""

from repro.analysis.devices import (  # noqa: F401
    STATIC_ENVELOPES,
    DeviceEnvelope,
    probe_device_envelope,
    resolve_envelope,
)
from repro.analysis.diagnostics import (  # noqa: F401
    AnalysisReport,
    Baseline,
    Diagnostic,
)
from repro.analysis.features import (  # noqa: F401
    ProgramFeatures,
    extract_features,
    trace_features,
)
from repro.analysis.hotpath import (  # noqa: F401
    ProgramSet,
    lint_traced_program,
)
from repro.analysis.legality import (  # noqa: F401
    BlockVerdict,
    LegalityReport,
    TargetConstraints,
    check_binding_space,
)
from repro.analysis.paging import (  # noqa: F401
    PageAliasError,
    assert_page_table,
    check_page_table,
)
from repro.analysis.resources import (  # noqa: F401
    CapacityPlan,
    MemoryEstimate,
    ResourceHint,
    ResourceReport,
    ResourceVerdict,
    check_binding_space_resources,
    estimate_memory,
    lint_shelf_coverage,
    plan_serve_capacity,
)

__all__ = [
    "AnalysisReport",
    "Baseline",
    "Diagnostic",
    "ProgramFeatures",
    "extract_features",
    "trace_features",
    "ProgramSet",
    "lint_traced_program",
    "BlockVerdict",
    "LegalityReport",
    "TargetConstraints",
    "check_binding_space",
    "PageAliasError",
    "assert_page_table",
    "check_page_table",
    "DeviceEnvelope",
    "STATIC_ENVELOPES",
    "probe_device_envelope",
    "resolve_envelope",
    "CapacityPlan",
    "MemoryEstimate",
    "ResourceHint",
    "ResourceReport",
    "ResourceVerdict",
    "check_binding_space_resources",
    "estimate_memory",
    "lint_shelf_coverage",
    "plan_serve_capacity",
]
