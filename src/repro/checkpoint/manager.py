"""Checkpointing: atomic, async, retention-managed.

Layout:  <dir>/step_<n>/  arrays.npz + manifest.json, written to a tmp dir
and renamed into place (rename is atomic on POSIX), so a job killed
mid-write can never leave a half checkpoint that restore would pick up.
Saves run on a background thread (training does not stall on disk);
``wait()`` joins before the next save or at shutdown.  Restore returns the
latest complete step.  Orbax is not available in this container; the
manifest/npz format keeps the same guarantees at the scale we exercise.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3) -> None:
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()
        flat = _flatten(tree)  # device->host copy happens here, in caller
        treedef = jax.tree_util.tree_structure(tree)

        def _write() -> None:
            tmp = self.dir / f".tmp_step_{step}_{os.getpid()}_{time.time_ns()}"
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **flat)
            manifest = {
                "step": step,
                "keys": sorted(flat),
                "treedef": str(treedef),
                "time": time.time(),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            final = self.dir / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._retain()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def steps(self) -> list[int]:
        self.wait()  # an in-flight async save counts once it is complete
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: int | None = None) -> tuple[int, Any]:
        """Restore into the structure of ``like`` (values replaced)."""
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:08d}"
        data = np.load(path / "arrays.npz")
        flat_like = _flatten(like)
        missing = set(flat_like) - set(data.files)
        if missing:
            raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
        leaves, treedef = jax.tree_util.tree_flatten(like)
        keys = [
            "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
            for path_, _ in jax.tree_util.tree_flatten_with_path(like)[0]
        ]
        new_leaves = [data[k] for k in keys]
        # a checkpoint from a *different model config* must fail loudly, not
        # feed mis-shaped arrays into the step function
        bad = [
            (k, data[k].shape, np.shape(l))
            for k, l in zip(keys, leaves)
            if hasattr(l, "shape") and tuple(data[k].shape) != tuple(np.shape(l))
        ]
        if bad:
            k, got, want = bad[0]
            raise ValueError(
                f"checkpoint at step {step} does not match the current model: "
                f"'{k}' has shape {got}, expected {want} "
                f"(+{len(bad)-1} more) — wrong --ckpt-dir?"
            )
        return step, jax.tree_util.tree_unflatten(treedef, new_leaves)
