"""OffloadEngine — the paper's Steps 1-3, end to end.

Given a CPU application (a Python callable), the engine:

  Step 1  analyses the defining module's source (``ast_analysis``) — library
          calls (A-1), local definitions (A-2), loop statements;
  Step 2  discovers offloadable blocks: DB name matching (B-1) and
          Deckard-style similarity (B-2);
          interfaces are reconciled per C-1/C-2 (casts silently, semantic
          changes only with user confirmation);
  Step 3  hands the discovered blocks to ``repro.core.planner``: candidate
          offload patterns are a ``SubsetSpace`` (built by AST call-site
          substitution) searched by a pluggable ``SearchStrategy`` —
          ``SingleThenCombine`` (the paper's procedure) by default, the
          prior-work ``GeneticSearch`` or the roofline-ranked
          ``CostGuidedSearch`` on request — through a shared
          ``MeasurementCache``.  The fastest pattern is numerics-checked
          and returned.

The engine also fronts the framework-native path: selecting function-block
*bindings* (ref/xla/pallas) for the model zoo.  Those paths are thin
wrappers over the same planner: ``measure_block_pattern`` is an
``ExhaustiveSearch`` over a ``BindingSpace``, ``select_block_pattern`` is
``planner.declared_pattern`` (the dry-run/compile-only case), and winning
plans can be persisted via ``planner.PlanStore`` for zero-search startup
in ``launch/serve.py`` / ``launch/train.py``.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from repro.core import ast_analysis, planner, similarity, substitute, verify
from repro.core.blocks import registry as block_registry
from repro.core.interface import (
    Adaptation,
    InterfaceMismatch,
    InterfaceSpec,
    Policy,
    match_interfaces,
    spec_from_arrays,
)
from repro.core.pattern_db import CodePatternDB, ReplacementEntry, default_db


@dataclasses.dataclass
class Discovery:
    kind: str  # "libcall" (A-1/B-1) | "similar" (A-2/B-2)
    source_name: str  # the call name (as written) or local def name
    entry: ReplacementEntry
    score: float = 1.0
    needs_confirmation: bool = False
    confirm_messages: tuple[str, ...] = ()


@dataclasses.dataclass
class AdaptedApp:
    fn: Callable[..., Any]
    discoveries: list[Discovery]
    skipped: list[Discovery]
    verification: verify.VerificationReport
    numerics_ok: bool
    offload_pattern: tuple[str, ...]


@dataclasses.dataclass
class PreparedApp:
    """Steps 1-2 output: the searchable space for an existing application.

    Produced by ``OffloadEngine.prepare``; consumed by
    ``repro.offload.OffloadSession`` (whose ``plan`` stage searches
    ``space`` and whose ``commit`` stage builds the winning variant).
    """

    space: "planner.SubsetSpace"
    discoveries: list[Discovery]
    skipped: list[Discovery]
    source_report: ast_analysis.SourceReport


def _resolve_dotted(ns: Mapping[str, Any], dotted: str) -> Any | None:
    obj: Any = ns.get(dotted.split(".")[0])
    for part in dotted.split(".")[1:]:
        if obj is None:
            return None
        obj = getattr(obj, part, None)
    return obj


def _host(x: Any) -> Any:
    if isinstance(x, tuple):
        return tuple(_host(e) for e in x)
    return np.asarray(x)


def _host_wrap(fn: Callable[..., Any]) -> Callable[..., Any]:
    """Results cross back to the host program after the offloaded block."""

    def wrapped(*args: Any) -> Any:
        return _host(fn(*args))

    wrapped.__name__ = getattr(fn, "__name__", "offloaded")
    return wrapped


class OffloadEngine:
    def __init__(
        self,
        db: CodePatternDB | None = None,
        policy: Policy | None = None,
        similarity_threshold: float = similarity.DEFAULT_THRESHOLD,
    ) -> None:
        self.db = db or default_db()
        self.policy = policy or Policy()
        self.similarity_threshold = similarity_threshold

    # -- Step 1 ---------------------------------------------------------------
    def analyze(self, app_fn: Callable[..., Any]) -> ast_analysis.SourceReport:
        return ast_analysis.analyze_module_of(app_fn, self.db.known_library_names)

    # -- Step 2 ---------------------------------------------------------------
    def discover(
        self, report: ast_analysis.SourceReport, entry_fn: str | None = None
    ) -> list[Discovery]:
        found: dict[str, Discovery] = {}

        # A-1/B-1: library calls matched by name against the DB list.
        for call in report.library_calls:
            if entry_fn is not None and call.enclosing != entry_fn:
                continue
            entry = self.db.lookup_by_call(call.call_name)
            if entry and entry.name not in found:
                found[entry.name] = Discovery(
                    kind="libcall", source_name=call.call_name, entry=entry
                )

        # A-2/B-2: local defs similar to DB reference code.  Skip defs whose
        # *name* is already a DB library name (those are the library itself,
        # handled by A-1).  A function block is compared together with the
        # local helpers it calls (one level), matching how the DB registers
        # reference code for whole blocks.  When the entry function is known,
        # only blocks it calls directly are candidates — the paper replaces
        # blocks *used by the application*.
        lib_names = {
            n.rsplit(".", 1)[-1] for n in self.db.known_library_names
        }
        by_name = {fd.name: fd for fd in report.func_defs}
        allowed: set[str] | None = None
        if entry_fn is not None and entry_fn in by_name:
            allowed = set(by_name[entry_fn].calls)
        candidates = []
        for fd in report.func_defs:
            if fd.name in lib_names or fd.name == entry_fn:
                continue
            if allowed is not None and fd.name not in allowed:
                continue
            aug_source = fd.source
            for callee in dict.fromkeys(fd.calls):
                sub = by_name.get(callee)
                if sub is not None and sub.name != fd.name:
                    aug_source = aug_source + "\n\n" + sub.source
            candidates.append(
                ast_analysis.FuncDef(
                    name=fd.name,
                    lineno=fd.lineno,
                    source=aug_source,
                    kind=fd.kind,
                    calls=fd.calls,
                )
            )
        hits = similarity.find_similar(
            candidates,
            self.db.entries_with_reference(),
            threshold=self.similarity_threshold,
        )
        for hit in hits:
            if hit.db_name not in found:
                found[hit.db_name] = Discovery(
                    kind="similar",
                    source_name=hit.local_name,
                    entry=self.db.get(hit.db_name),
                    score=hit.score,
                )
        return list(found.values())

    # -- C-1 / C-2 -------------------------------------------------------------
    def build_replacement(
        self,
        discovery: Discovery,
        module_ns: Mapping[str, Any],
        recorded: tuple[tuple[Any, ...], tuple[Any, ...]] | None,
    ) -> Callable[..., Any] | None:
        """Resolve, interface-match and wrap the accelerated implementation.

        Returns None when adaptation needs a confirmation the policy denies
        (the discovery is then reported in ``skipped``).
        """
        impl = discovery.entry.resolve()
        dst_spec = discovery.entry.interface
        if recorded is None or dst_spec is None:
            # No observed source interface or no declared replacement
            # interface: C-1 with no adaptation (trust the recipe).
            return _host_wrap(impl)
        args, rets = recorded
        src_spec = spec_from_arrays(args, rets)
        try:
            adaptation = match_interfaces(src_spec, dst_spec, self.policy)
        except InterfaceMismatch as e:
            discovery.needs_confirmation = True
            discovery.confirm_messages = (str(e),)
            return None
        return _host_wrap(adaptation.wrap(impl))

    # -- Steps 1-2, packaged for the session ------------------------------------
    def prepare(
        self,
        app_fn: Callable[..., Any],
        example_args: Sequence[Any],
        report: ast_analysis.SourceReport | None = None,
    ) -> PreparedApp:
        """Analyze + discover + reconcile interfaces, and wrap the result as
        a ``planner.SubsetSpace`` whose candidates are source-substituted
        variants of the application.  ``report`` short-cuts Step 1 when the
        caller (the session's ``analyze`` stage) already parsed the module."""
        module = inspect.getmodule(app_fn)
        if module is None:  # pragma: no cover
            raise ValueError("cannot locate the application's module source")
        module_src = inspect.getsource(module)
        module_ns = vars(module)

        if report is None:
            report = ast_analysis.analyze_source(
                module_src, self.db.known_library_names
            )
        discoveries = self.discover(report, entry_fn=app_fn.__name__)

        # Record each discovered block's observed interface by instrumenting
        # one baseline run (the paper's Step-1 "grasp the program structure").
        recordings: dict[str, tuple[tuple[Any, ...], tuple[Any, ...]]] = {}
        recorders: dict[str, Callable[..., Any]] = {}
        for d in discoveries:
            orig = _resolve_dotted(module_ns, d.source_name)
            if orig is None:
                continue

            def make_rec(name: str, fn: Callable[..., Any]):
                def rec(*args: Any):
                    out = fn(*args)
                    outs = out if isinstance(out, tuple) else (out,)
                    recordings[name] = (args, outs)
                    return out

                return rec

            recorders[d.source_name] = make_rec(d.source_name, orig)
        if recorders:
            ns = substitute.rewrite_calls(module_src, recorders)
            ns[app_fn.__name__](*example_args)

        # Build adapted replacements (C-1/C-2).
        replacements: dict[str, Callable[..., Any]] = {}
        active: list[Discovery] = []
        skipped: list[Discovery] = []
        for d in discoveries:
            adapted = self.build_replacement(
                d, module_ns, recordings.get(d.source_name)
            )
            if adapted is None:
                skipped.append(d)
            else:
                replacements[d.source_name] = adapted
                active.append(d)

        by_entry = {d.entry.name: d for d in active}

        def build_variant(subset: frozenset[str]) -> Callable[..., Any]:
            mapping = {
                by_entry[name].source_name: replacements[by_entry[name].source_name]
                for name in subset
            }
            if not mapping:
                return app_fn
            ns = substitute.rewrite_calls(module_src, mapping)
            return substitute.extract_function(ns, app_fn.__name__)

        space = planner.SubsetSpace(
            build_variant,
            [d.entry.name for d in active],
            tag=f"{app_fn.__module__}.{app_fn.__qualname__}",
        )
        return PreparedApp(
            space=space,
            discoveries=active,
            skipped=skipped,
            source_report=report,
        )

    # -- Step 3 -----------------------------------------------------------------
    def adapt(
        self,
        app_fn: Callable[..., Any],
        example_args: Sequence[Any],
        repeats: int = 3,
        verify_rtol: float = 1e-3,
        strategy: "planner.SearchStrategy | None" = None,
        cache: "planner.MeasurementCache | None" = None,
    ) -> AdaptedApp:
        """Deprecated shim: the full lifecycle in one call, now delegated to
        ``repro.offload.OffloadSession``.  New code should drive the session
        directly (it adds objectives, plan persistence and staged control)."""
        from repro.offload import OffloadSession

        session = OffloadSession(
            app_fn,
            args=example_args,
            engine=self,
            strategy=strategy,
            cache=cache,
            repeats=repeats,
            rtol=verify_rtol,
        )
        result = session.run()
        return AdaptedApp(
            fn=result.fn,
            discoveries=result.discoveries,
            skipped=result.skipped,
            verification=result.verification,
            numerics_ok=bool(result.numerics_ok),
            offload_pattern=result.pattern,
        )

    # -- framework-native path: block bindings for the model zoo ---------------
    def select_block_pattern(
        self, environment: str, blocks: Sequence[str] | None = None
    ) -> dict[str, str]:
        """Declared-environment binding selection (the dry-run case) — thin
        wrapper over ``planner.declared_pattern``."""
        return planner.declared_pattern(
            environment, blocks=blocks, registry=block_registry
        )

    def measure_block_pattern(
        self,
        step_builder: Callable[[], Callable[..., Any]],
        patterns: Sequence[Mapping[str, str]],
        args: Sequence[Any],
        repeats: int = 3,
        cache: "planner.MeasurementCache | None" = None,
        min_seconds: float = 0.0,
    ) -> tuple[dict[str, str], list[tuple[dict[str, str], float]]]:
        """Deprecated shim: measured binding selection over the listed
        patterns, now delegated to ``repro.offload.OffloadSession`` (binding
        mode, exhaustive strategy, numerics stage skipped — the historical
        contract measured only)."""
        from repro.offload import OffloadSession

        space = planner.BindingSpace.from_patterns(
            step_builder, patterns, registry=block_registry
        )
        # closures from one factory share a __qualname__ (the default tag):
        # disambiguate by object identity so two models measured through
        # the same factory never answer each other's cache lookups
        space.tag = (
            f"{getattr(step_builder, '__qualname__', 'step')}"
            f"@{id(step_builder):x}"
        )
        cands = [space.candidate_from_mapping(dict(p)) for p in patterns]
        session = OffloadSession(
            space,
            args=args,
            strategy=planner.ExhaustiveSearch(
                candidates=cands, include_baseline=False
            ),
            cache=cache,
            repeats=repeats,
            min_seconds=min_seconds,
        )
        result = session.run(verify=False, build=False)
        by_key = {t.candidate: t.seconds for t in result.report.trials}
        results = [
            (dict(pat), by_key[cand]) for pat, cand in zip(patterns, cands)
        ]
        best = min(results, key=lambda r: r[1])[0]
        return best, results
