"""MeasurementExecutor — how a batch of candidate trials is timed.

The paper's search loop is dominated by measurement: every candidate
pattern is a compile+run, executed serially.  This module makes the *how*
of that timed work pluggable behind ``MeasurementCache`` so every search
strategy (and ``OffloadSession.plan``) picks parallelism up for free:

  SerialExecutor          one job after another — the historical behaviour
                          and the reference semantics the others must match.
  DeviceParallelExecutor  thread-per-``jax.device``: independent candidates
                          (a GA generation, the single-axis trials of
                          SingleThenCombine) measure concurrently, each
                          trial pinned to its device via ``jax.device_put``
                          so concurrent variants do not contend for one
                          accelerator.
  BatchedExecutor         fuses several short variants into one timed
                          window and apportions the window by per-variant
                          events — amortises timer/dispatch overhead for
                          sub-millisecond kernels.

An executor consumes ``MeasureJob``s (a built variant plus its timing
parameters) and returns one ``verify.Measurement`` per job, in order.  The
``PowerMeter`` hooks ride along: each executor brackets the timed work with
``begin``/``end`` and stamps ``energy_joules`` + ``energy_provenance`` on
the measurement.  Meters whose ``exclusive`` flag is set read device-global
counters, so parallel executors serialise their metered sections —
concurrent trials would otherwise be attributed each other's energy.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Protocol, Sequence, runtime_checkable

from repro.core import verify
from repro.obs import get_tracer


def _trial_args(job: MeasureJob) -> dict:
    """Span attributes for one measured trial (built only when tracing)."""
    out: dict[str, Any] = {"repeats": job.repeats, "warmup": job.warmup}
    if job.candidate is not None:
        out["candidate"] = str(job.candidate)[:120]
    return out


@dataclasses.dataclass
class MeasureJob:
    """One candidate's timed work: the built variant and how to time it.

    ``space``/``candidate`` are carried only for the PowerMeter's ``end``
    hook (meters may attribute draw per candidate); executors never
    interpret them.
    """

    fn: Callable[..., Any]
    args: Sequence[Any]
    repeats: int = 3
    min_seconds: float = 0.0
    warmup: int = 1
    space: Any = None
    candidate: Any = None


@runtime_checkable
class MeasurementExecutor(Protocol):
    """Times a batch of jobs; returns one Measurement per job, in order."""

    def run(
        self, jobs: Sequence[MeasureJob], meter: Any = None
    ) -> list[verify.Measurement]: ...


_METER_LOCK_GUARD = threading.Lock()


def meter_lock(meter: Any) -> threading.Lock | None:
    """The per-meter serialisation lock for ``exclusive`` meters.

    An exclusive meter reads a device-global counter, so its begin/end
    windows must never interleave — across worker threads of one executor
    AND across concurrent ``measure_many`` callers sharing the meter
    through one cache.  The lock therefore lives on the meter itself
    (created lazily, once), not on any single ``run()`` invocation.
    Non-exclusive meters (pure functions of the trial's own measurement)
    need no lock.
    """
    if meter is None or not getattr(meter, "exclusive", True):
        return None
    with _METER_LOCK_GUARD:
        lock = getattr(meter, "_metering_lock", None)
        if lock is None:
            lock = threading.Lock()
            meter._metering_lock = lock
    return lock


def run_job(job: MeasureJob, meter: Any = None) -> verify.Measurement:
    """Measure one job with the meter's begin/end bracketing the timed
    window; exclusive meters are serialised via their per-meter lock.

    Each job runs under a "trial" span on the process tracer (a no-op
    unless someone enabled it) — with ``DeviceParallelExecutor`` the spans
    land on each worker thread's own track, so the exported timeline shows
    the measurement overlap directly."""
    tracer = get_tracer()
    span = (
        tracer.span("trial", **_trial_args(job))
        if tracer.enabled
        else contextlib.nullcontext()
    )
    with span:
        if meter is None:
            return verify.measure(
                job.fn,
                job.args,
                repeats=job.repeats,
                warmup=job.warmup,
                min_seconds=job.min_seconds,
            )
        lock = meter_lock(meter)
        with lock if lock is not None else contextlib.nullcontext():
            meter.begin()
            m = verify.measure(
                job.fn,
                job.args,
                repeats=job.repeats,
                warmup=job.warmup,
                min_seconds=job.min_seconds,
            )
            m.energy_joules = meter.end(
                m, space=job.space, candidate=job.candidate
            )
        if m.energy_joules is not None:
            m.energy_provenance = getattr(meter, "provenance", None)
        return m


class SerialExecutor:
    """One job after another on the caller's thread (reference semantics)."""

    name = "serial"

    def run(
        self, jobs: Sequence[MeasureJob], meter: Any = None
    ) -> list[verify.Measurement]:
        return [run_job(job, meter) for job in jobs]


def _pin_to_device(job: MeasureJob, device: Any) -> MeasureJob:
    """Pin one job's work to a jax device: committed inputs via
    ``device_put`` plus ``default_device`` around the call, so the compiled
    variant runs there.  Non-array args and non-jax workloads pass through
    untouched."""
    if device is None:
        return job
    try:
        import jax
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        return job
    args = tuple(
        jax.device_put(a, device) if isinstance(a, jax.Array) else a
        for a in job.args
    )
    fn = job.fn

    def pinned(*a: Any, **kw: Any) -> Any:
        with jax.default_device(device):
            return fn(*a, **kw)

    return dataclasses.replace(job, fn=pinned, args=args)


class DeviceParallelExecutor:
    """Thread-per-device concurrent measurement.

    Job *i* is pinned to ``devices[i % len(devices)]``; with one worker per
    device, at most one trial runs on an accelerator at a time, so trials
    do not contend for the device they are timing.  On a single-device host
    this degrades to serial execution with identical semantics.

    ``max_workers`` overrides the worker count (useful for sleep-based
    workloads and tests, where concurrency beyond the device count is
    harmless).  With an ``exclusive`` PowerMeter attached, metered sections
    are serialised under the meter's own lock (see :func:`meter_lock`) —
    a device-global counter cannot attribute concurrent trials — so only
    the un-metered portion of the batch parallelises.
    """

    name = "device_parallel"

    def __init__(
        self, devices: Sequence[Any] | None = None, max_workers: int | None = None
    ) -> None:
        self.devices = list(devices) if devices is not None else None
        self.max_workers = max_workers

    def _devices(self) -> list[Any]:
        if self.devices is not None:
            return self.devices
        try:
            import jax

            return list(jax.devices())
        except Exception:  # pragma: no cover - jax is a hard dep in practice
            return [None]

    def run(
        self, jobs: Sequence[MeasureJob], meter: Any = None
    ) -> list[verify.Measurement]:
        jobs = list(jobs)
        if not jobs:
            return []
        devices = self._devices() or [None]
        workers = self.max_workers or len(devices)
        workers = max(1, min(workers, len(jobs)))
        if workers == 1:
            return SerialExecutor().run(jobs, meter=meter)
        pinned = [
            _pin_to_device(job, devices[i % len(devices)])
            for i, job in enumerate(jobs)
        ]
        with ThreadPoolExecutor(max_workers=workers) as pool:
            futures = [pool.submit(run_job, job, meter) for job in pinned]
            return [f.result() for f in futures]


class BatchedExecutor:
    """Fuse up to ``max_fuse`` short variants into one timed window.

    Per repeat, the whole group runs back-to-back inside a single window
    (repeated until ``min_seconds`` of wall time is spanned) and each
    variant's share is taken from per-variant timestamps ("events") inside
    the window.  This amortises timer and dispatch overhead that dominates
    sub-millisecond trials measured one at a time.

    Energy is metered once per fused window and apportioned to variants by
    their time share — an attribution model, so apportioned readings carry
    ``energy_provenance="estimated"`` even under a counter-backed meter.
    A meter whose ``end`` hook *requires* the candidate (per-candidate
    draw models) cannot attribute a multi-variant window at all: it gets
    space/candidate only for single-job groups, and a raising ``end``
    degrades the group's energy to None rather than aborting the search.
    """

    name = "batched"

    def __init__(self, max_fuse: int = 8) -> None:
        if max_fuse < 1:
            raise ValueError("max_fuse must be >= 1")
        self.max_fuse = max_fuse

    def run(
        self, jobs: Sequence[MeasureJob], meter: Any = None
    ) -> list[verify.Measurement]:
        jobs = list(jobs)
        out: list[verify.Measurement] = []
        for start in range(0, len(jobs), self.max_fuse):
            out.extend(self._run_group(jobs[start : start + self.max_fuse], meter))
        return out

    def _run_group(
        self, group: Sequence[MeasureJob], meter: Any = None
    ) -> list[verify.Measurement]:
        if not group:
            return []
        tracer = get_tracer()
        span = (
            tracer.span("trial-group", fused=len(group))
            if tracer.enabled
            else contextlib.nullcontext()
        )
        with span:
            return self._run_group_timed(group, meter)

    def _run_group_timed(
        self, group: Sequence[MeasureJob], meter: Any = None
    ) -> list[verify.Measurement]:
        perf = time.perf_counter
        warm: list[float] = []
        for job in group:
            t0 = perf()
            for _ in range(max(job.warmup, 0)):
                verify._block(job.fn(*job.args))
            warm.append(perf() - t0)
        repeats = max(max(j.repeats for j in group), 1)
        min_seconds = max(j.min_seconds for j in group)

        lock = meter_lock(meter)
        with lock if lock is not None else contextlib.nullcontext():
            if meter is not None:
                meter.begin()
            window_t0 = perf()
            per_variant: list[list[float]] = [[] for _ in group]
            for _ in range(repeats):
                t0 = perf()
                shares = [0.0] * len(group)
                calls = 0
                while True:
                    for i, job in enumerate(group):
                        ti = perf()
                        verify._block(job.fn(*job.args))
                        shares[i] += perf() - ti
                    calls += 1
                    if perf() - t0 >= min_seconds:
                        break
                for i in range(len(group)):
                    per_variant[i].append(shares[i] / calls)
            window_seconds = perf() - window_t0
            window_watts: float | None = None
            if meter is not None:
                window = verify.Measurement(
                    seconds=max(window_seconds, 1e-9),
                    compile_seconds=0.0,
                    repeats=1,
                )
                # a fused window has no single candidate to attribute;
                # per-candidate meters get one only for single-job groups,
                # and a meter that cannot cope degrades to no reading
                kwargs = (
                    dict(space=group[0].space, candidate=group[0].candidate)
                    if len(group) == 1
                    else {}
                )
                try:
                    window_joules = meter.end(window, **kwargs)
                except Exception:  # noqa: BLE001 — degrade, don't abort
                    window_joules = None
                if window_joules is not None:
                    window_watts = window_joules / max(window_seconds, 1e-9)

        out = []
        for i, job in enumerate(group):
            times = sorted(per_variant[i])
            med = times[len(times) // 2]
            m = verify.Measurement(
                seconds=max(med, 1e-9),
                compile_seconds=max(warm[i] - med, 0.0),
                repeats=repeats,
            )
            if window_watts is not None:
                m.energy_joules = window_watts * m.seconds
                # apportioned by time share, never a direct counter read
                m.energy_provenance = "estimated"
            out.append(m)
        return out


_NAMED_EXECUTORS: dict[str, Callable[[], Any]] = {
    "serial": SerialExecutor,
    "device_parallel": DeviceParallelExecutor,
    "device-parallel": DeviceParallelExecutor,
    "batched": BatchedExecutor,
}


def resolve_executor(executor: "MeasurementExecutor | str | None") -> Any:
    """Accept an executor instance, a name, or None (-> SerialExecutor)."""
    if executor is None:
        return SerialExecutor()
    if isinstance(executor, str):
        if executor not in _NAMED_EXECUTORS:
            raise KeyError(
                f"unknown executor '{executor}'; "
                f"known: {sorted(set(_NAMED_EXECUTORS))}"
            )
        return _NAMED_EXECUTORS[executor]()
    if not hasattr(executor, "run"):
        raise TypeError(
            f"executor must provide .run(jobs, meter=None), got "
            f"{type(executor).__name__}"
        )
    return executor
