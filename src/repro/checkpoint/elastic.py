"""Elastic restore: bring a checkpoint up on a *different* mesh.

Checkpoints store full (host) arrays, so elasticity is a placement problem:
given the new mesh and the PartitionSpec tree for the new topology,
``reshard_restore`` device_puts every leaf with its NamedSharding.  Scaling
from 256 chips to 512 (or down to what survived a failure) is then just
``reshard_restore(mgr, like, new_mesh, new_specs)`` — the sharding layer
recomputes specs from the same logical rules, so no per-topology code.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding

from repro.checkpoint.manager import CheckpointManager


def reshard_restore(
    mgr: CheckpointManager,
    like: Any,
    mesh: Mesh,
    specs: Any,
    step: int | None = None,
) -> tuple[int, Any]:
    step, host_tree = mgr.restore(like, step)
    placed = jax.tree.map(
        lambda arr, spec: jax.device_put(arr, NamedSharding(mesh, spec)),
        host_tree,
        specs,
    )
    return step, placed
