"""Planner — the unified offload-pattern search subsystem.

The paper's contribution is *measured search*: candidate offload patterns
are built, run in a verification environment, and the fastest verified
pattern wins.  Historically this repo implemented that idea three times —
``verify.search_offload_pattern`` (single-then-combine over source
substitutions), ``ga.run_ga`` (the prior-work loop GA) and
``OffloadEngine.measure_block_pattern`` (a linear sweep over registry
bindings) — each with its own measurement loop and cache.  This package
factors the common structure into four pieces:

  SearchSpace   *what* is being searched.  ``SubsetSpace`` is the paper's
                binary offload-or-not choice per discovered block;
                ``BindingSpace`` generalises the GPU-vs-FPGA destination
                choice to an n-ary choice among registered targets
                ({ref, xla, pallas}) per function block.
  SearchStrategy  *how* the space is explored.  ``SingleThenCombine`` is the
                paper's Step-3 procedure (§4.2); ``GeneticSearch`` is the
                prior-work GA, now n-ary and space-agnostic;
                ``CostGuidedSearch`` ranks candidates with the HLO roofline
                model and measures only the top-k (the paper's "FPGA
                compilation takes hours — narrow candidates first"
                pre-filter); ``ExhaustiveSearch`` measures a listed set.
  Objective     *what "best" means*.  Every strategy ranks trials via
                ``objective.score(trial)`` (lower = better): ``Latency``
                is the paper's wall-seconds, ``PerfPerWatt`` minimises
                joules per call (the follow-up power-saving work,
                arXiv:2110.11520) fed by a pluggable ``PowerMeter`` with a
                time-proportional fallback, ``WeightedCost`` blends both.
  MeasurementCache  shared, thread-safe memoisation keyed by canonical
                pattern, so no strategy ever re-measures a visited pattern.
                Preserves the compile-time / runtime split per trial (paper
                Fig. 4), and the per-trial energy reading + provenance when
                a PowerMeter is wired.  The timed work itself runs on a
                pluggable ``repro.metering`` executor (serial /
                device-parallel / batched) fed through the strategies' bulk
                ``measure_many`` rounds.
  PlanStore     persistent JSON plans keyed by name + environment
                fingerprint, so a production process (launch/serve.py,
                launch/train.py) can load a previously verified plan and
                bind it with zero search.

``Planner`` ties them together: check the store, otherwise search, then
persist the winner.
"""

from repro.core.planner.cache import MeasurementCache  # noqa: F401
from repro.core.planner.cost import make_roofline_cost_fn, roofline_seconds  # noqa: F401
from repro.core.planner.objectives import (  # noqa: F401
    DEFAULT_DEVICE_WATTS,
    Latency,
    Objective,
    PerfPerWatt,
    PowerMeter,
    TimeProportionalPower,
    WeightedCost,
    resolve_objective,
)
from repro.core.planner.planner import (  # noqa: F401
    Planner,
    declared_pattern,
    plan_compatible,
)
from repro.core.planner.space import (  # noqa: F401
    DEFAULT_TARGET,
    Axis,
    BindingSpace,
    Candidate,
    SearchSpace,
    SubsetSpace,
)
from repro.core.planner.store import (  # noqa: F401
    Plan,
    PlanStore,
    environment_fingerprint,
)
from repro.core.planner.strategies import (  # noqa: F401
    CostGuidedSearch,
    ExhaustiveSearch,
    GeneticSearch,
    PlanReport,
    PlanTrial,
    SearchStrategy,
    SingleThenCombine,
    to_verification_report,
)
