"""Hot-path lints for jitted serve programs.

``ServeEngine`` registers each jitted program (prefill / decode / insert /
extend) with a :class:`ProgramSet` at construction; the returned wrapper
records every *abstract signature* the program is called under (shape,
dtype, weak-type per leaf — cheap per call) and the set lints the programs
it has observed:

* ``host-sync``     — a loop program returns a non-carry output larger than
                      ``sync_bytes``: the driver loop will pull it to host
                      every step (the PR-4/5 contract is that decode's
                      per-step transfer is the sampled token ids only).
* ``callback``      — a callback primitive inside the traced program
                      re-enters Python from device code each call.
* ``retrace-risk``  — more distinct abstract signatures than the program
                      declares (``expected_signatures``): something in the
                      argument stream drifts and every drift is a retrace.
* ``weak-type``     — python-scalar / weak-typed operands in a loop
                      program's signature; dtype promotion differences
                      between call sites silently fork traces.
* ``const-capture`` — a large array baked into the trace as a constant
                      instead of passed as an operand (re-traced programs
                      re-bake it; donation can't reuse its buffer).
"""

from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.analysis import features as features_mod
from repro.analysis.diagnostics import Diagnostic

#: Host-transfer budget per loop-program call (non-carry outputs).  The
#: decode contract is "token ids only": (B,) int32 stays far below this.
DEFAULT_SYNC_BYTES = 32 * 1024

#: A constant this large baked into a trace is a capture bug, not a table.
DEFAULT_CONST_BYTES = 1 << 20


def _leaf_signature(leaf: Any) -> tuple:
    if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
        return (
            tuple(leaf.shape),
            str(leaf.dtype),
            bool(getattr(leaf, "weak_type", False)),
        )
    # python scalar: jit traces it weak-typed; value changes don't retrace
    # but promotion behaviour differs from a committed array operand
    return ("pyscalar", type(leaf).__name__)


def _leaf_struct(leaf: Any) -> Any:
    if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
        return jax.ShapeDtypeStruct(tuple(leaf.shape), leaf.dtype)
    return np.asarray(leaf)


def _aval_bytes(tree: Any) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        shape = getattr(leaf, "shape", ())
        dtype = getattr(leaf, "dtype", None)
        itemsize = getattr(dtype, "itemsize", None) or np.dtype(dtype).itemsize
        total += int(math.prod(shape)) * int(itemsize)
    return total


@dataclasses.dataclass
class ProgramRecord:
    """One registered hot-path program and its observed call signatures."""

    name: str
    fn: Callable[..., Any]
    loop: bool = False  # called once per engine step (the decode loop)
    carry_outputs: tuple[int, ...] = ()  # top-level outputs that stay on device
    expected_signatures: int | None = None  # None = unbounded (e.g. prefill)
    #: trace-span kind covering this program's calls (None = the engine
    #: never span-instruments it — the obs info lint flags that)
    span_kind: str | None = None
    signatures: dict[tuple, tuple] = dataclasses.field(default_factory=dict)
    calls: int = 0
    #: wall seconds spent in the first call of each distinct signature —
    #: trace+compile+dispatch, the retrace cost the timeline should show
    compile_seconds: float = 0.0

    @property
    def retraces(self) -> int:
        """Signatures beyond the first — each one recompiled the program."""
        return max(len(self.signatures) - 1, 0)

    def observe(self, args: tuple) -> bool:
        """Record one call; True when its abstract signature is new."""
        self.calls += 1
        leaves = jax.tree_util.tree_leaves(args)
        sig = tuple(_leaf_signature(leaf) for leaf in leaves)
        if sig not in self.signatures:
            # structs for on-demand abstract tracing; built only for new
            # signatures so the steady-state decode step pays one tuple()
            self.signatures[sig] = jax.tree_util.tree_map(
                _leaf_struct, args
            )
            return True
        return False


class ProgramSet:
    """Registry of one engine's hot-path programs, lintable on demand."""

    def __init__(
        self,
        sync_bytes: int = DEFAULT_SYNC_BYTES,
        const_bytes: int = DEFAULT_CONST_BYTES,
    ) -> None:
        self.records: dict[str, ProgramRecord] = {}
        self.sync_bytes = sync_bytes
        self.const_bytes = const_bytes
        #: optional ``repro.obs`` attachments (set by the engine): a
        #: Tracer that receives a "compile" span per new signature, and a
        #: MetricsRegistry that carries per-program retrace/compile-time
        #: counters.  Both default off — a bare ProgramSet stays analysis-
        #: only with zero obs coupling.
        self.tracer: Any = None
        self.metrics: Any = None

    def register(
        self,
        name: str,
        fn: Callable[..., Any],
        loop: bool = False,
        carry_outputs: Sequence[int] = (),
        expected_signatures: int | None = None,
        span_kind: str | None = None,
    ) -> Callable[..., Any]:
        """Wrap ``fn`` so calls record their abstract signature (and the
        first-call wall time of each new signature — the compile cost).
        Returns the wrapper the caller should invoke instead of ``fn``."""
        rec = ProgramRecord(
            name=name,
            fn=fn,
            loop=loop,
            carry_outputs=tuple(carry_outputs),
            expected_signatures=expected_signatures,
            span_kind=span_kind,
        )
        self.records[name] = rec

        @functools.wraps(fn)
        def observed(*args: Any, **kwargs: Any) -> Any:
            new_sig = rec.observe(
                args if not kwargs else args + tuple(kwargs.values())
            )
            if not new_sig:
                return fn(*args, **kwargs)
            # first call under this signature: jit traces + compiles
            # synchronously inside the call, so its wall time is the
            # retrace cost (execution itself dispatches async)
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            dt = time.perf_counter() - t0
            rec.compile_seconds += dt
            self._on_compile(rec, t0, dt)
            return out

        observed.record = rec  # type: ignore[attr-defined]
        return observed

    def _on_compile(self, rec: ProgramRecord, t0: float, dt: float) -> None:
        tracer = self.tracer
        if tracer is not None and tracer.enabled:
            tracer.add_span(
                "compile", t0, t0 + dt,
                program=rec.name, signature=len(rec.signatures),
            )
        if self.metrics is not None:
            self.metrics.counter(
                "serve_program_retraces_total",
                "distinct abstract signatures per program beyond the first",
                labelnames=("program",),
            ).labels(program=rec.name).inc(0 if len(rec.signatures) == 1
                                           else 1)
            self.metrics.counter(
                "serve_program_compile_seconds_total",
                "wall seconds spent in first-call-per-signature "
                "(trace + compile)",
                labelnames=("program",),
            ).labels(program=rec.name).inc(dt)

    def stats(self) -> dict[str, dict[str, Any]]:
        """Per-program compile/retrace counters for reports and the
        metrics endpoint."""
        return {
            name: {
                "calls": rec.calls,
                "signatures": len(rec.signatures),
                "retraces": rec.retraces,
                "compile_seconds": rec.compile_seconds,
                "span_kind": rec.span_kind,
            }
            for name, rec in self.records.items()
        }

    def observe(self, name: str, *args: Any) -> None:
        """Record a signature without wrapping (tests, ad-hoc programs)."""
        self.records[name].observe(args)

    # -- lints ---------------------------------------------------------------

    def lint(self, names: Sequence[str] | None = None) -> list[Diagnostic]:
        diags: list[Diagnostic] = []
        for name, rec in self.records.items():
            if names is not None and name not in names:
                continue
            diags.extend(self._lint_record(rec))
        return diags

    def _lint_record(self, rec: ProgramRecord) -> list[Diagnostic]:
        diags: list[Diagnostic] = []
        if not rec.signatures:
            return diags  # never called — nothing observed to lint

        if self.tracer is not None and rec.span_kind is None:
            # the engine attached a tracer but this program's calls carry
            # no span kind: its time is invisible in the exported timeline
            diags.append(Diagnostic(
                pass_name="hotpath", code="no-span", severity="info",
                program=rec.name, subject="span-instrumentation",
                message=(
                    "program is registered with a traced engine but has no "
                    "span_kind — its calls won't appear in obs timelines"
                ),
            ))

        if (
            rec.expected_signatures is not None
            and len(rec.signatures) > rec.expected_signatures
        ):
            sigs = len(rec.signatures)
            diags.append(Diagnostic(
                pass_name="hotpath", code="retrace-risk", severity="warning",
                program=rec.name, subject=f"{sigs}-signatures",
                message=(
                    f"{sigs} distinct abstract signatures observed over "
                    f"{rec.calls} calls (declared {rec.expected_signatures})"
                    " — each drift recompiles the program"
                ),
            ))

        first_sig = next(iter(rec.signatures))
        structs = rec.signatures[first_sig]
        if rec.loop:
            for leaf_sig in first_sig:
                if leaf_sig and leaf_sig[0] == "pyscalar":
                    diags.append(Diagnostic(
                        pass_name="hotpath", code="weak-type",
                        severity="warning", program=rec.name,
                        subject=f"pyscalar-{leaf_sig[1]}",
                        message=(
                            f"python {leaf_sig[1]} operand in a loop "
                            "program; pass a committed array to pin dtype "
                            "promotion"
                        ),
                    ))
            diags.extend(self._lint_host_sync(rec, structs))
        diags.extend(self._lint_traced(rec, structs))
        return diags

    def _lint_host_sync(
        self, rec: ProgramRecord, structs: tuple
    ) -> list[Diagnostic]:
        try:
            out = jax.eval_shape(rec.fn, *structs)
        except Exception:  # noqa: BLE001 — unlintable under this signature
            return []
        parts = list(out) if isinstance(out, (tuple, list)) else [out]
        diags = []
        for i, part in enumerate(parts):
            if i in rec.carry_outputs:
                continue
            nbytes = _aval_bytes(part)
            if nbytes > self.sync_bytes:
                diags.append(Diagnostic(
                    pass_name="hotpath", code="host-sync", severity="warning",
                    program=rec.name, subject=f"output[{i}]",
                    message=(
                        f"non-carry output {i} is {nbytes} bytes "
                        f"(> {self.sync_bytes}); the driver loop pulls it "
                        "to host every step — fuse the reduction (e.g. "
                        "sampling) into the program"
                    ),
                ))
        return diags

    def _lint_traced(
        self, rec: ProgramRecord, structs: tuple
    ) -> list[Diagnostic]:
        try:
            feats = features_mod.trace_features(rec.fn, *structs)
        except Exception:  # noqa: BLE001 — unlintable under this signature
            return []
        diags = []
        for cb in feats.callbacks:
            diags.append(Diagnostic(
                pass_name="hotpath", code="callback", severity="warning",
                program=rec.name, subject=cb,
                message=(
                    f"'{cb}' primitive in the traced program re-enters "
                    "Python from device code on every call"
                ),
            ))
        if feats.largest_const_bytes > self.const_bytes:
            diags.append(Diagnostic(
                pass_name="hotpath", code="const-capture", severity="warning",
                program=rec.name,
                subject=f"const-{feats.largest_const_bytes}B",
                message=(
                    f"a {feats.largest_const_bytes}-byte array is baked "
                    "into the trace as a constant; pass it as an operand "
                    "so retraces don't re-bake it"
                ),
            ))
        return diags


def lint_traced_program(
    name: str,
    fn: Callable[..., Any],
    example_args: Sequence[Any],
    sync_bytes: int = DEFAULT_SYNC_BYTES,
    const_bytes: int = DEFAULT_CONST_BYTES,
    loop: bool = False,
    carry_outputs: Sequence[int] = (),
) -> list[Diagnostic]:
    """One-shot lint of a standalone program (zoo cells, CLI sweeps)."""
    ps = ProgramSet(sync_bytes=sync_bytes, const_bytes=const_bytes)
    ps.register(name, fn, loop=loop, carry_outputs=carry_outputs)
    ps.observe(name, *example_args)
    return ps.lint()
