"""repro.metering: executors, meters, cache thread-safety, store-diff report.

Timing-sensitive equivalence tests use sleep-based variants with >=5 ms
gaps between candidates so median-of-1 measurements rank deterministically
under any executor.
"""

import json
import threading
import time

import pytest

from repro.core.planner import (
    ExhaustiveSearch,
    GeneticSearch,
    MeasurementCache,
    Plan,
    PlanStore,
    SingleThenCombine,
    SubsetSpace,
    TimeProportionalPower,
    environment_fingerprint,
)
from repro.core.planner.objectives import PowerMeter
from repro.metering import (
    BatchedExecutor,
    DeviceParallelExecutor,
    MeasureJob,
    SerialExecutor,
    diff_stores,
    render_table,
    resolve_executor,
    resolve_meter,
    search_trace,
)
from repro.metering import meters as meters_mod
from repro.metering import report as report_mod
from repro.offload import OffloadSession

COSTS = {
    frozenset(): 0.040,
    frozenset({"a"}): 0.020,
    frozenset({"b"}): 0.030,
    frozenset({"a", "b"}): 0.008,
}


def sleep_space(costs=None, names=("a", "b"), tag="metering"):
    costs = COSTS if costs is None else costs

    def build(subset):
        seconds = costs[frozenset(subset)]

        def fn(_x):
            time.sleep(seconds)
            return _x

        return fn

    return SubsetSpace(build, list(names), tag=tag)


# -- executors ----------------------------------------------------------------


@pytest.mark.parametrize(
    "executor",
    [
        SerialExecutor(),
        DeviceParallelExecutor(max_workers=4),
        BatchedExecutor(max_fuse=3),
    ],
    ids=["serial", "device_parallel", "batched"],
)
def test_executor_equivalence_same_winner(executor):
    """Acceptance: every executor reproduces the serial search's winner and
    measures the same candidate set."""
    space = sleep_space()
    session = OffloadSession(
        space, args=(0,), strategy=SingleThenCombine(), repeats=1,
        executor=executor,
    )
    session.analyze()
    session.discover()
    plan = session.plan()
    assert plan.pattern == ("a", "b")
    # paper trial set: baseline + each single + the combination
    assert session.cache.evaluations == 4


def test_session_plan_accepts_executor_override():
    space = sleep_space(tag="override")
    session = OffloadSession(
        space, args=(0,), strategy=SingleThenCombine(), repeats=1
    )
    session.analyze()
    session.discover()
    plan = session.plan(executor=DeviceParallelExecutor(max_workers=2))
    assert plan.pattern == ("a", "b")
    assert type(session.cache.executor).__name__ == "DeviceParallelExecutor"


def test_ga_same_winner_parallel_vs_serial():
    results = {}
    for name, executor in [
        ("serial", None),
        ("parallel", DeviceParallelExecutor(max_workers=4)),
    ]:
        space = sleep_space(tag=f"ga-{name}")
        rep = GeneticSearch(
            population=4, generations=3, seed=7
        ).search(
            space,
            (0,),
            cache=MeasurementCache(executor=executor),
            repeats=1,
        )
        results[name] = rep.best.pattern
    assert results["serial"] == results["parallel"]


def test_device_parallel_actually_overlaps_trials():
    """4 independent 50 ms candidates across 4 workers must take well under
    4x the serial wall time."""
    costs = {
        frozenset(): 0.05,
        frozenset({"x"}): 0.05,
        frozenset({"y"}): 0.05,
        frozenset({"x", "y"}): 0.05,
    }
    space = sleep_space(costs, names=("x", "y"), tag="overlap")
    cache = MeasurementCache(executor=DeviceParallelExecutor(max_workers=4))
    cands = list(space.enumerate())
    t0 = time.perf_counter()
    out = cache.measure_many(space, cands, (0,), repeats=1, warmup=0)
    wall = time.perf_counter() - t0
    assert len(out) == 4 and all(not cached for _, cached in out)
    assert wall < 0.15  # serial would be >= 0.20 s


def test_batched_executor_apportions_by_variant():
    slow = MeasureJob(fn=lambda: time.sleep(0.03), args=(), repeats=1, warmup=0)
    fast = MeasureJob(fn=lambda: time.sleep(0.005), args=(), repeats=1, warmup=0)
    m_slow, m_fast = BatchedExecutor().run([slow, fast])
    assert m_slow.seconds > 2 * m_fast.seconds


def test_batched_executor_marks_apportioned_energy_estimated():
    class CounterMeter(PowerMeter):
        provenance = "measured"

        def end(self, measurement, space=None, candidate=None):
            return 5.0 * measurement.seconds

    jobs = [
        MeasureJob(fn=lambda: time.sleep(0.004), args=(), repeats=1, warmup=0)
        for _ in range(2)
    ]
    for m in BatchedExecutor().run(jobs, meter=CounterMeter()):
        assert m.energy_joules is not None and m.energy_joules > 0
        # fused-window attribution is a model, never a direct counter read
        assert m.energy_provenance == "estimated"


def test_shared_cache_executor_conflict_raises():
    shared = MeasurementCache(executor=BatchedExecutor())
    space = sleep_space(tag="conflict")
    with pytest.raises(ValueError):
        OffloadSession(
            space, args=(0,), cache=shared,
            executor=DeviceParallelExecutor(),
        )
    session = OffloadSession(space, args=(0,), cache=shared)
    session.analyze()
    session.discover()
    with pytest.raises(ValueError):
        session.plan(executor=DeviceParallelExecutor())


def test_shared_cache_equal_executor_is_not_a_conflict():
    """Two name-resolved executors with identical configuration are the
    same executor, not a conflict (fresh instances compare by config)."""
    shared = MeasurementCache(executor="serial")
    space = sleep_space(tag="equal-exec")
    session = OffloadSession(space, args=(0,), cache=shared, executor="serial")
    session.analyze()
    session.discover()
    session.plan(executor=SerialExecutor())  # still equal — no error
    with pytest.raises(ValueError):
        session.plan(executor=BatchedExecutor())


def test_zoo_key_canonicalises_arch_spelling():
    from repro.offload.zoo import zoo_key

    assert zoo_key("llama3.2_1b", "train") == "zoo:llama3.2-1b:train"
    assert zoo_key("llama3.2-1b", "train") == "zoo:llama3.2-1b:train"
    # unknown labels pass through (report selftest et al.)
    assert zoo_key("selftest", "app") == "zoo:selftest:app"


def test_cache_rejects_short_executor_return():
    class ShortExecutor:
        def run(self, jobs, meter=None):
            return []

    space = sleep_space(tag="short-exec")
    cache = MeasurementCache(executor=ShortExecutor())
    with pytest.raises(RuntimeError, match="one Measurement per job"):
        cache.measure(space, (0, 0), (0,), repeats=1, warmup=0)
    # the failed claim was released: a good executor can take over
    cache.executor = None
    m, cached = cache.measure(space, (0, 0), (0,), repeats=1, warmup=0)
    assert not cached and m.seconds > 0


def test_report_cli_fail_empty(tmp_path, capsys):
    (tmp_path / "a").mkdir()
    (tmp_path / "b").mkdir()
    assert report_mod.main(
        [str(tmp_path / "a"), str(tmp_path / "b"), "--fail-empty"]
    ) == 1
    assert report_mod.main([str(tmp_path / "a"), str(tmp_path / "b")]) == 0
    capsys.readouterr()


def test_batched_executor_candidate_meter_degrades_not_crashes():
    """A meter whose end() requires the candidate cannot attribute a fused
    multi-variant window: the group's energy degrades to None instead of
    aborting the search; single-job groups still get full attribution."""

    class CandidateWatts(PowerMeter):
        provenance = "measured"
        exclusive = False

        def end(self, measurement, space=None, candidate=None):
            return (10.0 + sum(candidate)) * measurement.seconds

    space = sleep_space(
        {
            frozenset(): 0.002,
            frozenset({"a"}): 0.002,
            frozenset({"b"}): 0.002,
            frozenset({"a", "b"}): 0.002,
        },
        tag="cand-meter",
    )
    cache = MeasurementCache(
        meter=CandidateWatts(), executor=BatchedExecutor(max_fuse=4)
    )
    out = cache.measure_many(
        space, list(space.enumerate()), (0,), repeats=1, warmup=0
    )
    assert all(m.energy_joules is None for m, _ in out)  # fused: no claim
    solo = MeasurementCache(
        meter=CandidateWatts(), executor=BatchedExecutor(max_fuse=1)
    )
    (m, _), = solo.measure_many(space, [(1, 0)], (0,), repeats=1, warmup=0)
    assert m.energy_joules == pytest.approx(11.0 * m.seconds)


def test_exclusive_meter_windows_never_interleave_across_threads():
    """The serialisation lock lives on the meter, so concurrent
    measure_many callers sharing one cache cannot interleave an exclusive
    meter's begin/end windows (stateful counters would corrupt)."""

    class StrictMeter(PowerMeter):
        provenance = "measured"
        exclusive = True

        def __init__(self):
            self.open = False
            self.violations = 0

        def begin(self):
            if self.open:
                self.violations += 1
            self.open = True

        def end(self, measurement, space=None, candidate=None):
            if not self.open:
                self.violations += 1
            self.open = False
            return 1.0

    meter = StrictMeter()
    space = sleep_space(
        {
            frozenset(): 0.001,
            frozenset({"a"}): 0.001,
            frozenset({"b"}): 0.001,
            frozenset({"a", "b"}): 0.001,
        },
        tag="strict-meter",
    )
    cache = MeasurementCache(meter=meter)
    cands = list(space.enumerate())
    threads = [
        threading.Thread(
            target=lambda s=s: cache.measure_many(
                space, cands, (s,), repeats=1, warmup=0
            )
        )
        for s in range(6)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert meter.violations == 0


def test_resolve_executor_names_and_errors():
    assert isinstance(resolve_executor(None), SerialExecutor)
    assert isinstance(resolve_executor("serial"), SerialExecutor)
    assert isinstance(
        resolve_executor("device-parallel"), DeviceParallelExecutor
    )
    assert isinstance(resolve_executor("batched"), BatchedExecutor)
    with pytest.raises(KeyError):
        resolve_executor("warp-drive")
    with pytest.raises(TypeError):
        resolve_executor(object())


# -- cache thread-safety ------------------------------------------------------


def test_cache_concurrent_measure_exact_accounting():
    """N threads hammering overlapping candidates: every candidate is
    measured exactly once, and hits+misses add up with no lost updates."""
    space = sleep_space(
        {
            frozenset(): 0.002,
            frozenset({"a"}): 0.002,
            frozenset({"b"}): 0.002,
            frozenset({"a", "b"}): 0.002,
        },
        tag="race",
    )
    cache = MeasurementCache()
    cands = list(space.enumerate())
    n_threads, per_thread = 8, 12
    errors = []

    def hammer(seed):
        try:
            for i in range(per_thread):
                cand = cands[(seed + i) % len(cands)]
                m, _cached = cache.measure(
                    space, cand, (0,), repeats=1, warmup=0
                )
                assert m.seconds > 0
        except Exception as e:  # noqa: BLE001 — surfaced below
            errors.append(e)

    threads = [
        threading.Thread(target=hammer, args=(s,)) for s in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(cache) == len(cands)
    assert cache.misses == len(cands)  # nothing measured twice
    assert cache.hits + cache.misses == n_threads * per_thread


def test_cache_records_preserve_measurement_order():
    space = sleep_space(tag="order")
    cache = MeasurementCache()
    order = [(0, 0), (1, 1), (0, 1)]
    for cand in order:
        cache.measure(space, cand, (0,), repeats=1, warmup=0)
    recs = cache.records()
    assert [r.seq for r in recs] == [0, 1, 2]
    assert len(recs) == 3


# -- meters -------------------------------------------------------------------


def test_autodetect_fallback_order(monkeypatch):
    calls = []

    def avail(name, result):
        def probe():
            calls.append(name)
            return result

        return probe

    monkeypatch.setattr(
        meters_mod.NvmlMeter, "available", avail("nvml", False)
    )
    monkeypatch.setattr(
        meters_mod.TpuMeter, "available", avail("tpu", False)
    )
    monkeypatch.setattr(
        meters_mod.RaplMeter, "available", avail("rapl", False)
    )
    monkeypatch.setattr(
        meters_mod.PsutilCpuMeter, "available", avail("psutil", False)
    )
    meter = meters_mod.autodetect()
    assert isinstance(meter, TimeProportionalPower)
    # accelerator counters first; TPU telemetry ahead of the CPU models
    assert calls == ["nvml", "tpu", "rapl", "psutil"]


def test_autodetect_stops_at_first_available(monkeypatch):
    monkeypatch.setattr(meters_mod.NvmlMeter, "available", lambda: False)
    monkeypatch.setattr(meters_mod.RaplMeter, "available", lambda: True)
    monkeypatch.setattr(
        meters_mod.RaplMeter, "__init__", lambda self: None
    )
    assert isinstance(meters_mod.autodetect(), meters_mod.RaplMeter)


def test_resolve_meter_names():
    assert resolve_meter(None) is None
    assert resolve_meter("none") is None
    assert isinstance(resolve_meter("time"), TimeProportionalPower)
    tp = TimeProportionalPower()
    assert resolve_meter(tp) is tp
    with pytest.raises(KeyError):
        resolve_meter("geiger")


def test_resolve_meter_explicit_unavailable_raises(monkeypatch):
    monkeypatch.setattr(meters_mod.NvmlMeter, "available", lambda: False)
    with pytest.raises(RuntimeError):
        resolve_meter("nvml")


@pytest.mark.skipif(
    not meters_mod.PsutilCpuMeter.available(), reason="psutil unavailable"
)
def test_psutil_meter_produces_estimate():
    meter = meters_mod.PsutilCpuMeter(tdp_watts=100.0, idle_watts=10.0)
    meter.begin()
    t0 = time.perf_counter()
    x = 0
    while time.perf_counter() - t0 < 0.05:
        x += 1
    from repro.core.verify import Measurement

    m = Measurement(seconds=0.05, compile_seconds=0.0, repeats=1)
    joules = meter.end(m)
    assert joules is not None and joules > 0
    assert meter.provenance == "estimated"


def test_provenance_threads_measurement_to_plan(tmp_path):
    space = sleep_space(tag="provenance")
    session = OffloadSession(
        space,
        args=(0,),
        strategy=ExhaustiveSearch(),
        meter=TimeProportionalPower(watts=100.0),
        store=str(tmp_path),
        key="zoo:prov:train",
        repeats=1,
    )
    result = session.run(verify=False, build=False)
    assert all(t.energy_provenance == "estimated" for t in result.trials)
    stored = PlanStore(str(tmp_path)).load("zoo:prov:train")
    assert stored is not None
    assert stored.best_energy_provenance == "estimated"
    assert stored.best_energy_joules == pytest.approx(
        stored.best_seconds * 100.0
    )


def test_meter_window_telemetry():
    from repro.metering import meter_window

    with meter_window(TimeProportionalPower(watts=50.0)) as tele:
        time.sleep(0.02)
    assert tele.seconds >= 0.02
    assert tele.joules == pytest.approx(tele.seconds * 50.0)
    assert tele.watts == pytest.approx(50.0)
    assert tele.provenance == "estimated"
    with meter_window(None) as tele:
        time.sleep(0.001)
    assert tele.joules is None and tele.seconds > 0


# -- report -------------------------------------------------------------------


def make_plan(key, mapping, seconds, joules, provenance, objective):
    return Plan(
        key=key,
        space="TestSpace()",
        mapping=dict(mapping),
        pattern=tuple(sorted(mapping)),
        baseline_seconds=0.1,
        best_seconds=seconds,
        speedup=0.1 / seconds,
        strategy="exhaustive",
        evaluations=4,
        search_seconds=1.0,
        fingerprint=environment_fingerprint(),
        objective=objective,
        best_energy_joules=joules,
        best_energy_provenance=provenance,
    )


def test_report_diff_golden(tmp_path):
    store_a = PlanStore(tmp_path / "lat")
    store_b = PlanStore(tmp_path / "ppw")
    store_a.save(
        make_plan(
            "zoo:llama:train", {"attention": "pallas"}, 0.01, 5.0,
            "measured", "latency",
        )
    )
    store_b.save(
        make_plan(
            "zoo:llama:train", {"attention": "xla"}, 0.02, 2.0,
            "estimated", "perf_per_watt",
        )
    )
    store_a.save(  # only in A: must not appear in the diff
        make_plan("zoo:llama:decode", {}, 0.01, 1.0, None, "latency")
    )
    rows = diff_stores(store_a, store_b)
    assert len(rows) == 1
    row = rows[0]
    assert (row.arch, row.kind) == ("llama", "train")
    assert not row.agree
    assert row.seconds_delta_pct == pytest.approx(100.0)
    assert row.joules_delta_pct == pytest.approx(-60.0)
    table = render_table(rows, label_a="lat", label_b="ppw")
    assert "attention=pallas" in table
    assert "attention=xla" in table
    assert "5J*" in table  # measured provenance marked
    assert "2J~" in table  # estimated provenance marked
    assert "+100.0%" in table and "-60.0%" in table


def test_report_cli_json(tmp_path, capsys):
    store_a = PlanStore(tmp_path / "a")
    store_b = PlanStore(tmp_path / "b")
    plan = make_plan(
        "zoo:m:train", {"fft2d": "pallas"}, 0.01, 3.0, "measured", "latency"
    )
    store_a.save(plan)
    store_b.save(plan)
    assert report_mod.main(
        [str(tmp_path / "a"), str(tmp_path / "b"), "--json"]
    ) == 0
    rows = json.loads(capsys.readouterr().out)
    assert rows[0]["agree"] is True
    assert rows[0]["provenance_a"] == "measured"


def test_report_selftest_passes(capsys):
    assert report_mod.selftest() == 0
    out = capsys.readouterr().out
    assert "selftest OK" in out
    assert "J*" in out or "J~" in out


def test_search_trace_from_report_and_cache():
    space = sleep_space(tag="trace")
    cache = MeasurementCache()
    rep = ExhaustiveSearch().search(space, (0,), cache=cache, repeats=1)
    points = search_trace(rep)
    assert len(points) == len(rep.trials)
    assert points[-1].best_seconds == min(t.seconds for t in rep.trials)
    # best-so-far is monotonically non-increasing (the Fig. 4 curve)
    assert all(
        p1.best_seconds >= p2.best_seconds
        for p1, p2 in zip(points, points[1:])
    )
    cache_points = search_trace(cache)
    assert len(cache_points) == cache.misses
    # cache-derived traces carry the candidate's axis=choice labels so the
    # curve identifies what each measurement was
    assert any("a=offload" in p.pattern for p in cache_points)
    assert all(p.pattern for p in cache_points)


# -- launch-surface defaults --------------------------------------------------


def test_default_plan_key_requires_stored_plan(tmp_path):
    from repro.offload.zoo import default_plan_key

    assert default_plan_key(str(tmp_path), "llama", "train") is None
    assert default_plan_key(None, "llama", "train") is None
    PlanStore(tmp_path).save(
        make_plan("zoo:llama:train", {}, 0.01, None, None, "latency")
    )
    assert default_plan_key(str(tmp_path), "llama", "train") == (
        "zoo:llama:train"
    )
    assert default_plan_key(str(tmp_path), "llama", "decode") is None
